//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! The build environment has no network access to crates.io, so the
//! repository vendors the small slice of the `anyhow` API the crate
//! actually uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the [`anyhow!`] / [`bail!`] macros. Semantics follow the
//! real crate where it matters here:
//!
//! * `{}` formats the outermost message, `{:#}` the whole cause chain
//!   joined with `": "`, and `{:?}` a report with a `Caused by:` list;
//! * converting a `std::error::Error` captures its `source()` chain;
//! * `.context(..)` / `.with_context(..)` wrap both `Result` (any error
//!   convertible into [`Error`], including [`Error`] itself) and
//!   `Option`.
//!
//! If the real `anyhow` ever becomes available, deleting this vendor
//! directory and pointing the dependency at crates.io is a no-op for
//! callers.

use std::convert::Infallible;
use std::fmt::{self, Display};

/// A catch-all error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost stays last).
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause-chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps the blanket `From` below coherent (same trick as
// the real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::from(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn fails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(1)
        }
        assert_eq!(format!("{}", fails(true).unwrap_err()), "flag was true");
        assert_eq!(fails(false).unwrap(), 1);
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }
}
