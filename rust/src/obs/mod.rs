//! Observability: the trace plane.
//!
//! The serving stack's always-on [`Metrics`](crate::coordinator::Metrics)
//! answer *how much*; this module answers *where the time went*. A
//! [`TracePlane`] threads one shared handle through the whole request
//! path — service handle, router, batcher, dispatch plane, fault
//! wrapper, workers, supervisor, journal retirer — and each stage
//! emits compact [`TraceEvent`]s into lock-free sharded rings
//! ([`ring`]):
//!
//! ```text
//! submit ─ enqueue ─ batch-formed ─ backend-selected ─ exec ─ complete
//!    │         │            │              │             │
//!    │   (queue span)  (batch span)  (failover span) (exec span)
//!    └── reject / shed / failover-hop / respawn / fault-injected /
//!        exec-error / worker-death / batch-failed   (error class)
//! ```
//!
//! Two capture rules:
//!
//! * **1-in-N request sampling** — a request is sampled at submit time
//!   (`id % sample == 0`) and its *entire* lifecycle is then traced:
//!   the four stage spans (queue / batch / exec / failover) tile its
//!   rider-observed latency exactly, so a trace decomposes p99 by
//!   pipeline stage the way the paper decomposes divider cost by
//!   block.
//! * **error class is never sampled and never dropped** — rejects,
//!   sheds, failovers, respawns, injected faults, executor errors,
//!   worker deaths and rider-visible batch failures bypass the rings
//!   into an unbounded side store; ring overflow (counted in
//!   [`TracePlane::drops`]) can only lose sampled lifecycle events.
//!
//! [`export`] renders the plane as Chrome `trace_event` JSON or flat
//! JSONL (`serve --trace-out PATH --trace-sample N`) and renders the
//! per-(op, format) and per-shard stage breakdown tables
//! (`goldschmidt trace-report`). [`drain`] streams the plane to disk
//! *while serving* — the `fpu-trace-drainer` thread pumps the rings on
//! an interval into rotating JSONL segments
//! (`--trace-rotate-mb`) and re-merges them at shutdown, so a
//! multi-hour soak never outlives its rings.

pub mod drain;
pub mod export;
pub mod ring;

pub use drain::{segment_path, DrainConfig, DrainReport, TraceDrainer};
pub use export::{
    chrome_trace, chrome_trace_named, jsonl, merge_segments, parse_jsonl_event, trace_report,
    write_trace, write_trace_named,
};
pub use ring::{EventRing, TraceConfig, TraceEvent, TraceKind, TracePlane, NO_BACKEND, NO_SHARD};
