//! [`TracePlane`]: sharded lock-free event rings + sampling policy.
//!
//! The hot path (workers, dispatcher, router) emits compact
//! [`TraceEvent`]s into fixed-capacity rings with an atomic write
//! cursor — no locks, no allocation, and a full ring *drops* the event
//! (counted) rather than blocking a worker on an observer. Error-class
//! events (rejects, sheds, failovers, injected faults, worker deaths)
//! bypass the rings into a mutex-guarded side store so overflow can
//! only ever drop sampled lifecycle events, never the forensic ones.
//!
//! Sampling is per *request id*: `id % sample == 0` marks a request
//! sampled at submit time, and the flag rides the
//! [`WorkItem`](crate::coordinator::WorkItem) through every stage, so
//! one request's whole lifecycle is either fully traced or fully
//! untraced (a 1-in-N sample of complete span chains, not 1-in-N of
//! individual events). Error-class events ignore the sample entirely.
//!
//! Timestamps are nanosecond offsets from the plane's monotonic epoch
//! ([`Instant`] at construction), so exported traces start near zero
//! and are immune to wall-clock steps.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::request::OpKind;
use crate::formats::FormatKind;

/// Event rings per [`TracePlane`] (requests hash over them by id, so
/// concurrent emitters rarely contend on one write cursor).
const SHARDS: usize = 8;

/// Marker for "no backend attributed" in [`TraceEvent::backend`].
pub const NO_BACKEND: u8 = u8::MAX;

/// Marker for "no coordinator shard attributed" in
/// [`TraceEvent::shard`].
pub const NO_SHARD: u16 = u16::MAX;

/// What a [`TraceEvent`] records. Three classes:
///
/// * lifecycle **instants** (sampled): one point in a request's life;
/// * per-request **stage spans** (sampled): `dur_ns > 0`, tiled so the
///   four stages of one request sum to its rider-observed latency;
/// * **error-class** events: always captured regardless of the sample
///   rate, and stored outside the overflow-prone rings.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// Request accepted by the service handle.
    Submit,
    /// Request entered its (op, format) queue.
    Enqueue,
    /// A batch was formed from the queue (id = first rider's id).
    BatchFormed,
    /// The dispatch plane picked a backend for a batch (`arg` = 1 for
    /// a probe of an open breaker).
    BackendSelected,
    /// A journal record was appended (`arg`: 0 = pending, 1 = done,
    /// 2 = failed).
    JournalAppend,
    /// Request completed; `arg` = rider-observed latency in ns.
    Complete,
    /// Stage span: submit → batch formation (queue wait).
    StageQueue,
    /// Stage span: batch formation → execution start, minus failover
    /// (dispatch + worker-queue time).
    StageBatch,
    /// Stage span: time burned on failed attempts before the
    /// successful one.
    StageFailover,
    /// Stage span: the successful executor run.
    StageExec,
    /// Error-class: submission rejected before queueing.
    Reject,
    /// Error-class: a queued request shed at its deadline.
    Shed,
    /// Error-class: a failed batch re-routed to another backend
    /// (`backend` = the backend that failed it, `arg` = the next one).
    FailoverHop,
    /// Error-class: the supervisor respawned a dead worker.
    Respawn,
    /// Error-class: a fault-plan rule fired (`arg` = site index in
    /// [`FaultSite::ALL`](crate::fault::FaultSite::ALL)).
    FaultInjected,
    /// Error-class: an executor returned an error for a batch.
    ExecError,
    /// Error-class: a worker died (panic or injected death).
    WorkerDeath,
    /// Error-class: a batch failed on every candidate backend (riders
    /// observed the error).
    BatchFailed,
}

impl TraceKind {
    /// Every kind (label round-trip support for segment re-merging).
    pub const ALL: [TraceKind; 18] = [
        TraceKind::Submit,
        TraceKind::Enqueue,
        TraceKind::BatchFormed,
        TraceKind::BackendSelected,
        TraceKind::JournalAppend,
        TraceKind::Complete,
        TraceKind::StageQueue,
        TraceKind::StageBatch,
        TraceKind::StageFailover,
        TraceKind::StageExec,
        TraceKind::Reject,
        TraceKind::Shed,
        TraceKind::FailoverHop,
        TraceKind::Respawn,
        TraceKind::FaultInjected,
        TraceKind::ExecError,
        TraceKind::WorkerDeath,
        TraceKind::BatchFailed,
    ];

    /// The kind whose [`label`](TraceKind::label) is `s` (the inverse
    /// mapping, used when parsing exported JSONL back into events).
    pub fn from_label(s: &str) -> Option<TraceKind> {
        TraceKind::ALL.into_iter().find(|k| k.label() == s)
    }

    /// Stable lowercase label (exported names; stage spans use the
    /// queue/batch/exec/failover vocabulary of the report table).
    pub fn label(self) -> &'static str {
        match self {
            TraceKind::Submit => "submit",
            TraceKind::Enqueue => "enqueue",
            TraceKind::BatchFormed => "batch-formed",
            TraceKind::BackendSelected => "backend-selected",
            TraceKind::JournalAppend => "journal-append",
            TraceKind::Complete => "complete",
            TraceKind::StageQueue => "queue",
            TraceKind::StageBatch => "batch",
            TraceKind::StageFailover => "failover",
            TraceKind::StageExec => "exec",
            TraceKind::Reject => "reject",
            TraceKind::Shed => "shed",
            TraceKind::FailoverHop => "failover-hop",
            TraceKind::Respawn => "respawn",
            TraceKind::FaultInjected => "fault-injected",
            TraceKind::ExecError => "exec-error",
            TraceKind::WorkerDeath => "worker-death",
            TraceKind::BatchFailed => "batch-failed",
        }
    }

    /// Whether this kind is captured unconditionally (and stored
    /// outside the drop-prone rings).
    pub fn is_error_class(self) -> bool {
        matches!(
            self,
            TraceKind::Reject
                | TraceKind::Shed
                | TraceKind::FailoverHop
                | TraceKind::Respawn
                | TraceKind::FaultInjected
                | TraceKind::ExecError
                | TraceKind::WorkerDeath
                | TraceKind::BatchFailed
        )
    }

    /// Whether this kind is a duration span (exported as a Chrome
    /// `ph: "X"` complete event; everything else is an instant).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            TraceKind::StageQueue
                | TraceKind::StageBatch
                | TraceKind::StageFailover
                | TraceKind::StageExec
        )
    }
}

/// One compact trace event (`Copy`, fixed size — rings hold them
/// inline).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since the plane's epoch.
    pub t_ns: u64,
    /// Span duration in ns (0 for instants).
    pub dur_ns: u64,
    /// Event kind.
    pub kind: TraceKind,
    /// Request id (or the first rider's id for batch-scoped events;
    /// 0 when no request is attributable).
    pub id: u64,
    /// Operation.
    pub op: OpKind,
    /// IEEE format.
    pub format: FormatKind,
    /// Backend index ([`NO_BACKEND`] when not attributable).
    pub backend: u8,
    /// Coordinator shard index ([`NO_SHARD`] when not attributable),
    /// so stage latency can be blamed on the shard that served it.
    pub shard: u16,
    /// Live lanes involved.
    pub lanes: u32,
    /// Kind-specific payload (see each [`TraceKind`] variant).
    pub arg: u64,
}

impl TraceEvent {
    /// A blank event of `kind` at `t_ns` (divide/f32 placeholders, no
    /// backend, no lanes) — finish it with the builder methods.
    pub fn new(kind: TraceKind, t_ns: u64) -> Self {
        Self {
            t_ns,
            dur_ns: 0,
            kind,
            id: 0,
            op: OpKind::Divide,
            format: FormatKind::F32,
            backend: NO_BACKEND,
            shard: NO_SHARD,
            lanes: 0,
            arg: 0,
        }
    }

    /// Attribute a request: id + its (op, format) slot.
    pub fn req(mut self, id: u64, op: OpKind, format: FormatKind) -> Self {
        self.id = id;
        self.op = op;
        self.format = format;
        self
    }

    /// Attribute a backend index.
    pub fn on_backend(mut self, backend: usize) -> Self {
        self.backend = backend.min(NO_BACKEND as usize) as u8;
        self
    }

    /// Attribute a coordinator shard index.
    pub fn on_shard(mut self, shard: usize) -> Self {
        self.shard = shard.min(NO_SHARD as usize) as u16;
        self
    }

    /// Record the live lane count.
    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes.min(u32::MAX as usize) as u32;
        self
    }

    /// Make this a span of `dur_ns` nanoseconds.
    pub fn spanning(mut self, dur_ns: u64) -> Self {
        self.dur_ns = dur_ns;
        self
    }

    /// Attach the kind-specific payload.
    pub fn with_arg(mut self, arg: u64) -> Self {
        self.arg = arg;
        self
    }
}

struct Slot {
    seq: AtomicUsize,
    val: UnsafeCell<TraceEvent>,
}

/// One fixed-capacity multi-producer event ring (bounded MPMC queue in
/// the Vyukov style: a per-slot sequence number arbitrates between
/// producers and the draining consumer without locks). A push into a
/// full ring *drops* the event and counts the drop — the hot path
/// never waits for an observer.
pub struct EventRing {
    slots: Box<[Slot]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: slot payloads are only written by the producer that won the
// slot's sequence CAS and only read after the matching release store,
// exactly the Vyukov bounded-queue protocol.
unsafe impl Send for EventRing {}
unsafe impl Sync for EventRing {}

impl std::fmt::Debug for EventRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventRing")
            .field("capacity", &self.slots.len())
            .field("dropped", &self.dropped.load(Ordering::Relaxed))
            .finish()
    }
}

impl EventRing {
    /// Ring with `capacity` slots (rounded up to a power of two, min 8).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(8).next_power_of_two();
        let slots: Vec<Slot> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(TraceEvent::new(TraceKind::Submit, 0)),
            })
            .collect();
        Self {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Push one event; on a full ring the event is dropped (counted)
    /// and `false` is returned. Lock-free: at most one CAS retry loop.
    pub fn push(&self, ev: TraceEvent) -> bool {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS grants exclusive
                        // write access to this slot until the release
                        // store below publishes it.
                        unsafe { *slot.val.get() = ev };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop the oldest event (`None` when empty). Used by the draining
    /// observer, off the hot path.
    pub fn pop(&self) -> Option<TraceEvent> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos.wrapping_add(1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS grants exclusive
                        // read access; the slot was published by the
                        // producer's release store.
                        let ev = unsafe { *slot.val.get() };
                        slot.seq
                            .store(pos.wrapping_add(self.mask).wrapping_add(1), Ordering::Release);
                        return Some(ev);
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return None;
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Events dropped on overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Trace plane configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Sample 1 in `sample` requests (1 = trace everything; clamped to
    /// at least 1).
    pub sample: u64,
    /// Slots per event ring shard (the plane keeps a handful of
    /// shards; error-class events are stored outside the rings and
    /// never subject to this cap).
    pub capacity: usize,
}

impl Default for TraceConfig {
    /// 1-in-64 sampling, 8192-slot shards.
    fn default() -> Self {
        Self { sample: 64, capacity: 8192 }
    }
}

/// The shared tracing state: a monotonic epoch, sharded lifecycle
/// rings, and the always-on error-class side store. One `Arc` of this
/// is threaded through the handle, router, batcher, dispatch plane,
/// workers and supervisor.
#[derive(Debug)]
pub struct TracePlane {
    epoch: Instant,
    shards: Vec<EventRing>,
    /// Error-class events: never sampled, never dropped on ring
    /// overflow (a mutex is fine here — these are rare by definition).
    errors: Mutex<Vec<TraceEvent>>,
    /// Lifecycle events already pumped out of the rings.
    collected: Mutex<Vec<TraceEvent>>,
    sample: u64,
    /// Counter for id-less sampled sites (e.g. backend selection).
    tick: AtomicU64,
}

impl TracePlane {
    /// New plane; the epoch (t = 0) is *now*.
    pub fn new(config: TraceConfig) -> Self {
        Self {
            epoch: Instant::now(),
            shards: (0..SHARDS).map(|_| EventRing::new(config.capacity)).collect(),
            errors: Mutex::new(Vec::new()),
            collected: Mutex::new(Vec::new()),
            sample: config.sample.max(1),
            tick: AtomicU64::new(0),
        }
    }

    /// The configured sample modulus.
    pub fn sample_rate(&self) -> u64 {
        self.sample
    }

    /// Whether request `id` is in the 1-in-N sample.
    pub fn sampled(&self, id: u64) -> bool {
        id % self.sample == 0
    }

    /// Sampling gate for sites with no request id (one tick per
    /// consideration; every N-th returns true).
    pub fn tick_sampled(&self) -> bool {
        self.tick.fetch_add(1, Ordering::Relaxed) % self.sample == 0
    }

    /// Nanoseconds from the epoch to `t` (0 for pre-epoch instants).
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Nanoseconds from the epoch to now.
    pub fn now_ns(&self) -> u64 {
        self.ns_of(Instant::now())
    }

    /// Emit one event. Error-class kinds go to the unbounded side
    /// store (always captured); everything else rides the ring its id
    /// hashes to and may be dropped (counted) on overflow.
    pub fn emit(&self, ev: TraceEvent) {
        if ev.kind.is_error_class() {
            self.errors.lock().expect("trace error store poisoned").push(ev);
        } else {
            self.shards[(ev.id as usize) % self.shards.len()].push(ev);
        }
    }

    /// Total lifecycle events dropped on ring overflow.
    pub fn drops(&self) -> u64 {
        self.shards.iter().map(EventRing::dropped).sum()
    }

    /// Error-class events captured so far.
    pub fn error_count(&self) -> usize {
        self.errors.lock().expect("trace error store poisoned").len()
    }

    /// Drain the rings into the collected store (called periodically
    /// by the stats emitter and at export, so a long run does not have
    /// to fit in ring capacity).
    pub fn pump(&self) {
        let mut collected = self.collected.lock().expect("trace store poisoned");
        for ring in &self.shards {
            while let Some(ev) = ring.pop() {
                collected.push(ev);
            }
        }
    }

    /// Every event captured so far (pumped lifecycle + error-class),
    /// sorted by timestamp.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.pump();
        let mut out = self.collected.lock().expect("trace store poisoned").clone();
        out.extend(self.errors.lock().expect("trace error store poisoned").iter().copied());
        out.sort_by_key(|e| (e.t_ns, e.id));
        out
    }

    /// Pump the rings and *take* every collected lifecycle event,
    /// leaving the store empty. The streaming drainer
    /// ([`TraceDrainer`](super::drain::TraceDrainer)) calls this on an
    /// interval so a long run never has to fit in ring capacity — each
    /// event is handed out exactly once.
    pub fn take_collected(&self) -> Vec<TraceEvent> {
        self.pump();
        std::mem::take(&mut *self.collected.lock().expect("trace store poisoned"))
    }

    /// Error-class events captured at index `from` onward. Errors stay
    /// in the plane (they are the forensic record — `error_count` and
    /// shutdown summaries must keep seeing all of them); a streaming
    /// consumer advances its own cursor by the returned length.
    pub fn errors_since(&self, from: usize) -> Vec<TraceEvent> {
        let errors = self.errors.lock().expect("trace error store poisoned");
        errors.get(from..).map(<[TraceEvent]>::to_vec).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ev(kind: TraceKind, id: u64, t: u64) -> TraceEvent {
        TraceEvent::new(kind, t).req(id, OpKind::Divide, FormatKind::F32)
    }

    #[test]
    fn ring_fifo_and_capacity() {
        let r = EventRing::new(8);
        assert_eq!(r.capacity(), 8);
        for i in 0..8 {
            assert!(r.push(ev(TraceKind::Enqueue, i, i)));
        }
        // full: the ninth push drops, counted
        assert!(!r.push(ev(TraceKind::Enqueue, 8, 8)));
        assert_eq!(r.dropped(), 1);
        for i in 0..8 {
            assert_eq!(r.pop().unwrap().id, i);
        }
        assert!(r.pop().is_none());
        // space reclaimed: pushes succeed again
        assert!(r.push(ev(TraceKind::Enqueue, 9, 9)));
        assert_eq!(r.pop().unwrap().id, 9);
    }

    #[test]
    fn ring_concurrent_producers_conserve_events() {
        let r = Arc::new(EventRing::new(1024));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut pushed = 0u64;
                for i in 0..10_000u64 {
                    if r.push(ev(TraceKind::Enqueue, t * 10_000 + i, i)) {
                        pushed += 1;
                    }
                }
                pushed
            }));
        }
        let pushed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let mut popped = 0u64;
        while r.pop().is_some() {
            popped += 1;
        }
        assert_eq!(pushed + r.dropped(), 40_000, "every push accepted or counted dropped");
        assert_eq!(popped, pushed, "accepted events all drain");
        assert!(r.dropped() > 0, "1024 slots cannot hold 40k events");
    }

    #[test]
    fn sampling_is_per_id_and_error_class_ignores_it() {
        let p = TracePlane::new(TraceConfig { sample: 64, capacity: 64 });
        assert!(p.sampled(0));
        assert!(p.sampled(64));
        assert!(!p.sampled(1));
        let all = TracePlane::new(TraceConfig { sample: 1, capacity: 64 });
        assert!(all.sampled(7));
        // sample never reaches 0 (would divide by zero)
        let clamped = TracePlane::new(TraceConfig { sample: 0, capacity: 64 });
        assert_eq!(clamped.sample_rate(), 1);
    }

    #[test]
    fn overflow_drops_only_sampled_lifecycle_events() {
        // tiny rings, everything hashed to overflow; error-class events
        // must all survive regardless
        let p = TracePlane::new(TraceConfig { sample: 1, capacity: 8 });
        for i in 0..1000u64 {
            p.emit(ev(TraceKind::Enqueue, i, i));
        }
        for i in 0..100u64 {
            p.emit(ev(TraceKind::ExecError, i, i).on_backend(1));
        }
        assert!(p.drops() > 0, "tiny rings must overflow");
        assert_eq!(p.error_count(), 100, "error-class events bypass the rings");
        let events = p.events();
        let errors = events.iter().filter(|e| e.kind == TraceKind::ExecError).count();
        assert_eq!(errors, 100);
        let lifecycle = events.iter().filter(|e| e.kind == TraceKind::Enqueue).count() as u64;
        assert_eq!(lifecycle + p.drops(), 1000, "drops account for every lost event");
    }

    #[test]
    fn pump_makes_room_and_events_sort_by_time() {
        let p = TracePlane::new(TraceConfig { sample: 1, capacity: 8 });
        for round in 0..10u64 {
            for i in 0..8u64 {
                p.emit(ev(TraceKind::Enqueue, round * 8 + i, 1000 - (round * 8 + i)));
            }
            p.pump();
        }
        assert_eq!(p.drops(), 0, "pumping between bursts prevents overflow");
        let events = p.events();
        assert_eq!(events.len(), 80);
        assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "sorted by timestamp");
    }

    #[test]
    fn tick_sampling_fires_once_per_period() {
        let p = TracePlane::new(TraceConfig { sample: 4, capacity: 8 });
        let fired = (0..16).filter(|_| p.tick_sampled()).count();
        assert_eq!(fired, 4);
    }

    #[test]
    fn event_builders_fill_fields() {
        let e = TraceEvent::new(TraceKind::StageExec, 10)
            .req(7, OpKind::Sqrt, FormatKind::BF16)
            .on_backend(2)
            .on_shard(3)
            .with_lanes(64)
            .spanning(500)
            .with_arg(3);
        assert_eq!(e.t_ns, 10);
        assert_eq!(e.id, 7);
        assert_eq!(e.op, OpKind::Sqrt);
        assert_eq!(e.format, FormatKind::BF16);
        assert_eq!(e.backend, 2);
        assert_eq!(e.shard, 3);
        assert_eq!(e.lanes, 64);
        assert_eq!(e.dur_ns, 500);
        assert_eq!(e.arg, 3);
        assert!(e.kind.is_span());
        assert!(!e.kind.is_error_class());
        assert!(TraceKind::WorkerDeath.is_error_class());
        assert_eq!(TraceEvent::new(TraceKind::Submit, 0).shard, NO_SHARD);
    }

    #[test]
    fn kind_labels_round_trip() {
        for kind in TraceKind::ALL {
            assert_eq!(TraceKind::from_label(kind.label()), Some(kind));
        }
        assert_eq!(TraceKind::from_label("warp-core-breach"), None);
    }

    #[test]
    fn take_collected_consumes_and_errors_since_cursors() {
        let p = TracePlane::new(TraceConfig { sample: 1, capacity: 64 });
        for i in 0..10u64 {
            p.emit(ev(TraceKind::Enqueue, i, i));
        }
        p.emit(ev(TraceKind::Shed, 100, 100));
        assert_eq!(p.take_collected().len(), 10);
        assert!(p.take_collected().is_empty(), "second take sees nothing new");
        assert_eq!(p.errors_since(0).len(), 1);
        assert!(p.errors_since(1).is_empty());
        p.emit(ev(TraceKind::Shed, 101, 101));
        assert_eq!(p.errors_since(1).len(), 1);
        assert_eq!(p.error_count(), 2, "errors stay in the plane after streaming");
        // new lifecycle emissions after a take are still collected
        p.emit(ev(TraceKind::Enqueue, 11, 11));
        assert_eq!(p.take_collected().len(), 1);
    }
}
