//! Trace export and the per-stage latency breakdown report.
//!
//! Two on-disk formats, both derived from the same [`TraceEvent`]
//! stream:
//!
//! * **Chrome `trace_event` JSON** (`.json`) — an object with a
//!   `traceEvents` array; stage spans export as `ph: "X"` complete
//!   events (one timeline track per stage, and one **per backend** for
//!   backend-blamed spans — see [`chrome_trace_named`]), everything
//!   else as `ph: "i"` instants. Loads directly in `chrome://tracing` /
//!   Perfetto.
//! * **flat JSONL** (`.jsonl`) — one self-describing object per line,
//!   the grep/`jq`-friendly form.
//!
//! [`trace_report`] reads either format back (via the crate's own
//! [`Json`] parser) and prints the per-(op, format) stage table:
//! queue / batch / exec / failover share of end-to-end latency, with
//! p50/p99 per stage — the measurement analogue of the paper's
//! block-level cost breakdown.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::request::OpKind;
use crate::formats::FormatKind;
use crate::util::json::Json;
use crate::util::stats::Summary;

use super::ring::{TraceEvent, TraceKind, NO_BACKEND, NO_SHARD};

/// Stage labels in report display order.
const STAGES: [&str; 4] = ["queue", "batch", "exec", "failover"];

fn event_args(ev: &TraceEvent) -> Json {
    let mut args = vec![
        ("id", Json::from(ev.id)),
        ("op", Json::from(ev.op.label())),
        ("format", Json::from(ev.format.label())),
        ("lanes", Json::from(u64::from(ev.lanes))),
        ("arg", Json::from(ev.arg)),
    ];
    if ev.backend != NO_BACKEND {
        args.push(("backend", Json::from(u64::from(ev.backend))));
    }
    if ev.shard != NO_SHARD {
        args.push(("shard", Json::from(u64::from(ev.shard))));
    }
    Json::obj(args)
}

/// First tid of the per-backend track block (tracks 1..=4 belong to
/// the stages, 0 to lifecycle instants).
const BACKEND_TRACK_BASE: u64 = 16;

/// Build the Chrome `trace_event` document for an event stream.
///
/// Shorthand for [`chrome_trace_named`] with no backend names: backend
/// tracks are labeled `backend <index>`.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    chrome_trace_named(events, &[])
}

/// Build the Chrome `trace_event` document with named tracks.
///
/// Events that blame a backend (exec/failover spans, exec-error and
/// worker-death instants) land on a **per-backend track**
/// (`tid = BACKEND_TRACK_BASE + index`), so `chrome://tracing` shows
/// each backend's serving timeline side by side; everything else keeps
/// the per-stage tracks. `thread_name` metadata rows label every track
/// that is actually used, resolving backend indices through
/// `backend_names` (the order `FpuService::backend_names` reports).
pub fn chrome_trace_named(events: &[TraceEvent], backend_names: &[String]) -> Json {
    let mut used: BTreeMap<u64, String> = BTreeMap::new();
    let rows: Vec<Json> = events
        .iter()
        .map(|ev| {
            // one track (tid) per stage keeps span rows from stacking;
            // backend-blamed events group under their backend's track;
            // remaining instants share track 0
            let (tid, track) = if ev.backend != NO_BACKEND {
                let name = backend_names
                    .get(usize::from(ev.backend))
                    .map_or_else(|| format!("backend {}", ev.backend), |n| format!("backend {n}"));
                (BACKEND_TRACK_BASE + u64::from(ev.backend), name)
            } else {
                match STAGES.iter().position(|&s| s == ev.kind.label()) {
                    Some(i) => (i as u64 + 1, format!("stage {}", STAGES[i])),
                    None => (0, "lifecycle".to_string()),
                }
            };
            used.entry(tid).or_insert(track);
            let cat = if ev.kind.is_error_class() {
                "error"
            } else if ev.kind.is_span() {
                "stage"
            } else {
                "lifecycle"
            };
            let mut fields = vec![
                ("name", Json::from(ev.kind.label())),
                ("cat", Json::from(cat)),
                ("ph", Json::from(if ev.kind.is_span() { "X" } else { "i" })),
                ("ts", Json::Num(ev.t_ns as f64 / 1_000.0)),
                ("pid", Json::from(1u64)),
                ("tid", Json::from(tid)),
                ("args", event_args(ev)),
            ];
            if ev.kind.is_span() {
                fields.push(("dur", Json::Num(ev.dur_ns as f64 / 1_000.0)));
            } else {
                fields.push(("s", Json::from("t"))); // instant scope: thread
            }
            Json::obj(fields)
        })
        .collect();
    // name every used track; metadata rows (ph: "M") are invisible to
    // trace_report, which only reduces ph: "X" spans
    let meta = used.into_iter().map(|(tid, name)| {
        Json::obj([
            ("name", Json::from("thread_name")),
            ("ph", Json::from("M")),
            ("pid", Json::from(1u64)),
            ("tid", Json::from(tid)),
            ("args", Json::obj([("name", Json::from(name.as_str()))])),
        ])
    });
    Json::obj([("traceEvents", Json::arr(meta.chain(rows)))])
}

/// Render the flat JSONL form (one object per line, raw nanoseconds).
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        let mut fields = vec![
            ("kind", Json::from(ev.kind.label())),
            ("t_ns", Json::from(ev.t_ns)),
            ("id", Json::from(ev.id)),
            ("op", Json::from(ev.op.label())),
            ("format", Json::from(ev.format.label())),
            ("lanes", Json::from(u64::from(ev.lanes))),
            ("arg", Json::from(ev.arg)),
        ];
        if ev.kind.is_span() {
            fields.push(("dur_ns", Json::from(ev.dur_ns)));
        }
        if ev.backend != NO_BACKEND {
            fields.push(("backend", Json::from(u64::from(ev.backend))));
        }
        if ev.shard != NO_SHARD {
            fields.push(("shard", Json::from(u64::from(ev.shard))));
        }
        let _ = writeln!(out, "{}", Json::obj(fields).to_string());
    }
    out
}

/// Parse one JSONL trace line back into a [`TraceEvent`] — the inverse
/// of [`jsonl`], used by the streaming drainer's segment merge.
pub fn parse_jsonl_event(line: &str) -> Result<TraceEvent> {
    let row = Json::parse(line).map_err(|e| anyhow!("bad trace JSONL: {e}"))?;
    let str_of = |key: &str| {
        row.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace line missing {key:?}: {line}"))
    };
    let num_of = |key: &str| row.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let kind_s = str_of("kind")?;
    let kind = TraceKind::from_label(kind_s)
        .ok_or_else(|| anyhow!("unknown trace kind {kind_s:?}"))?;
    let op_s = str_of("op")?;
    let op = OpKind::ALL
        .into_iter()
        .find(|o| o.label() == op_s)
        .ok_or_else(|| anyhow!("unknown trace op {op_s:?}"))?;
    let format_s = str_of("format")?;
    let format = FormatKind::ALL
        .into_iter()
        .find(|f| f.label() == format_s)
        .ok_or_else(|| anyhow!("unknown trace format {format_s:?}"))?;
    let mut ev = TraceEvent::new(kind, num_of("t_ns"))
        .req(num_of("id"), op, format)
        .with_lanes(num_of("lanes") as usize)
        .spanning(num_of("dur_ns"))
        .with_arg(num_of("arg"));
    if row.get("backend").is_some() {
        ev = ev.on_backend(num_of("backend") as usize);
    }
    if row.get("shard").is_some() {
        ev = ev.on_shard(num_of("shard") as usize);
    }
    Ok(ev)
}

/// Re-merge rotated JSONL segment files into one trace at `target`
/// (`.jsonl` → flat, anything else → the Chrome document), sorted by
/// timestamp. Returns the merged event count. Missing segment files
/// are an error — a merge must never silently present a partial run
/// as complete.
pub fn merge_segments(
    segments: &[PathBuf],
    target: &Path,
    backend_names: &[String],
) -> Result<usize> {
    let mut events = Vec::new();
    for seg in segments {
        let body = std::fs::read_to_string(seg)
            .with_context(|| format!("reading trace segment {}", seg.display()))?;
        for (n, line) in body.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            events.push(
                parse_jsonl_event(line)
                    .with_context(|| format!("{} line {}", seg.display(), n + 1))?,
            );
        }
    }
    events.sort_by_key(|e| (e.t_ns, e.id));
    write_trace_named(target, &events, backend_names)?;
    Ok(events.len())
}

/// Write an event stream to `path`: `.jsonl` extension selects the
/// flat form, anything else the Chrome trace document.
pub fn write_trace(path: &Path, events: &[TraceEvent]) -> Result<()> {
    write_trace_named(path, events, &[])
}

/// [`write_trace`] with backend names for the Chrome form's per-backend
/// track labels (ignored by the JSONL form, which carries raw indices).
pub fn write_trace_named(
    path: &Path,
    events: &[TraceEvent],
    backend_names: &[String],
) -> Result<()> {
    let body = if path.extension().is_some_and(|e| e == "jsonl") {
        jsonl(events)
    } else {
        chrome_trace_named(events, backend_names).to_string()
    };
    std::fs::write(path, body).with_context(|| format!("writing trace to {}", path.display()))
}

/// One parsed stage-span sample.
struct StageSample {
    op: String,
    format: String,
    stage: usize,
    dur_us: f64,
    /// Coordinator shard the span was served on, when the trace
    /// carries one (traces predating the shard field simply omit it).
    shard: Option<u64>,
}

fn field_str(obj: &Json, key: &str) -> Option<String> {
    obj.get(key).and_then(Json::as_str).map(str::to_string)
}

fn stage_index(name: &str) -> Option<usize> {
    STAGES.iter().position(|&s| s == name)
}

/// Pull the stage spans out of a parsed trace document (either form).
fn stage_samples(doc_is_chrome: bool, rows: &[Json]) -> Vec<StageSample> {
    let mut out = Vec::new();
    for row in rows {
        let (name, dur_us, src) = if doc_is_chrome {
            if field_str(row, "ph").as_deref() != Some("X") {
                continue;
            }
            let Some(dur) = row.get("dur").and_then(Json::as_f64) else { continue };
            let Some(args) = row.get("args") else { continue };
            (field_str(row, "name"), dur, args)
        } else {
            let Some(dur_ns) = row.get("dur_ns").and_then(Json::as_f64) else { continue };
            (field_str(row, "kind"), dur_ns / 1_000.0, row)
        };
        let Some(stage) = name.as_deref().and_then(stage_index) else { continue };
        let (Some(op), Some(format)) = (field_str(src, "op"), field_str(src, "format")) else {
            continue;
        };
        let shard = src.get("shard").and_then(Json::as_f64).map(|s| s as u64);
        out.push(StageSample { op, format, stage, dur_us, shard });
    }
    out
}

fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], out: &mut String| {
        for (i, cell) in cells.iter().enumerate() {
            let pad = widths[i] - cell.len();
            if i == 0 {
                // first column left-aligned, the rest right-aligned
                let _ = write!(out, "{cell}{}", " ".repeat(pad));
            } else {
                let _ = write!(out, "  {}{cell}", " ".repeat(pad));
            }
        }
        out.push('\n');
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(), &mut out);
    let rule: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
    out.push_str(&"-".repeat(rule));
    out.push('\n');
    for row in rows {
        line(row, &mut out);
    }
    out
}

/// Read a trace file (Chrome JSON or JSONL) and render the per-stage
/// latency breakdown table: for every traced (op, format), each
/// stage's share of the summed end-to-end latency and its p50/p99.
pub fn trace_report(path: &Path) -> Result<String> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace from {}", path.display()))?;
    let trimmed = body.trim_start();
    let (is_chrome, rows): (bool, Vec<Json>) = if trimmed.starts_with('{') {
        let doc = Json::parse(&body).map_err(|e| anyhow!("bad trace JSON: {e}"))?;
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("no traceEvents array in {}", path.display()))?;
        (true, events.to_vec())
    } else {
        let mut rows = Vec::new();
        for (n, line) in body.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            rows.push(
                Json::parse(line).map_err(|e| anyhow!("bad JSONL at line {}: {e}", n + 1))?,
            );
        }
        (false, rows)
    };
    let samples = stage_samples(is_chrome, &rows);
    if samples.is_empty() {
        return Ok(format!(
            "no stage spans in {} (sampled requests: 0 — lower --trace-sample?)\n",
            path.display()
        ));
    }
    // (op, format) -> one Summary per stage, in STAGES order
    let mut slots: BTreeMap<(String, String), [Summary; 4]> = BTreeMap::new();
    // shard -> one Summary per stage (spans carrying a shard only)
    let mut shards: BTreeMap<u64, [Summary; 4]> = BTreeMap::new();
    for s in samples {
        if let Some(shard) = s.shard {
            shards.entry(shard).or_default()[s.stage].add(s.dur_us);
        }
        let entry = slots.entry((s.op, s.format)).or_default();
        entry[s.stage].add(s.dur_us);
    }
    let mut out = String::new();
    let mut rows = Vec::new();
    let mut spans = 0usize;
    for ((op, format), stages) in &slots {
        let total: f64 = stages.iter().map(Summary::sum).sum();
        for (i, stage) in STAGES.iter().enumerate() {
            let s = &stages[i];
            spans += s.count();
            let share = if total > 0.0 { 100.0 * s.sum() / total } else { 0.0 };
            rows.push(vec![
                format!("{op}/{format}"),
                stage.to_string(),
                s.count().to_string(),
                format!("{share:.1}%"),
                format!("{:.1}", s.percentile(50.0)),
                format!("{:.1}", s.percentile(99.0)),
            ]);
        }
    }
    let _ = writeln!(out, "per-stage latency breakdown ({spans} stage spans)");
    out.push_str(&render_table(
        &["op/format", "stage", "spans", "share", "p50 us", "p99 us"],
        &rows,
    ));
    // spans that carry a shard also get a per-shard attribution table,
    // making skew between shards (the thing the steal policy fixes)
    // directly visible from a trace file
    if !shards.is_empty() {
        let mut rows = Vec::new();
        let mut shard_spans = 0usize;
        for (shard, stages) in &shards {
            let total: f64 = stages.iter().map(Summary::sum).sum();
            for (i, stage) in STAGES.iter().enumerate() {
                let s = &stages[i];
                if s.count() == 0 {
                    continue;
                }
                shard_spans += s.count();
                let share = if total > 0.0 { 100.0 * s.sum() / total } else { 0.0 };
                rows.push(vec![
                    format!("shard{shard}"),
                    stage.to_string(),
                    s.count().to_string(),
                    format!("{share:.1}%"),
                    format!("{:.1}", s.percentile(50.0)),
                    format!("{:.1}", s.percentile(99.0)),
                ]);
            }
        }
        let _ = writeln!(out, "\nper-shard stage attribution ({shard_spans} stage spans)");
        out.push_str(&render_table(
            &["shard", "stage", "spans", "share", "p50 us", "p99 us"],
            &rows,
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::OpKind;
    use crate::formats::FormatKind;

    fn span(kind: TraceKind, id: u64, t: u64, dur: u64) -> TraceEvent {
        TraceEvent::new(kind, t)
            .req(id, OpKind::Divide, FormatKind::F32)
            .spanning(dur)
            .with_lanes(1)
    }

    fn sample_events() -> Vec<TraceEvent> {
        let mut evs = Vec::new();
        for id in 0..10u64 {
            let t = id * 10_000;
            evs.push(
                TraceEvent::new(TraceKind::Submit, t).req(id, OpKind::Divide, FormatKind::F32),
            );
            evs.push(span(TraceKind::StageQueue, id, t, 4_000));
            evs.push(span(TraceKind::StageBatch, id, t + 4_000, 1_000));
            evs.push(span(TraceKind::StageExec, id, t + 5_000, 5_000).on_backend(0));
            evs.push(
                TraceEvent::new(TraceKind::Complete, t + 10_000)
                    .req(id, OpKind::Divide, FormatKind::F32)
                    .with_arg(10_000),
            );
        }
        evs.push(
            TraceEvent::new(TraceKind::ExecError, 123)
                .req(3, OpKind::Divide, FormatKind::F32)
                .on_backend(1),
        );
        evs
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("goldschmidt-obs-{}-{name}", std::process::id()))
    }

    #[test]
    fn chrome_trace_shape() {
        let doc = chrome_trace(&sample_events());
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 51 event rows + 5 thread_name metadata rows (lifecycle, two
        // stage tracks, two backend tracks)
        assert_eq!(events.len(), 56);
        let spans: Vec<&Json> =
            events.iter().filter(|e| field_str(e, "ph").as_deref() == Some("X")).collect();
        assert_eq!(spans.len(), 30, "three stage spans per request");
        // spans tile: ts+dur of queue == ts of batch (request 0)
        let q = &spans[0];
        assert_eq!(field_str(q, "name").as_deref(), Some("queue"));
        assert_eq!(q.get("ts").and_then(Json::as_f64), Some(0.0));
        assert_eq!(q.get("dur").and_then(Json::as_f64), Some(4.0));
        // round-trips through the crate's own parser
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("traceEvents").and_then(Json::as_arr).unwrap().len(), 56);
    }

    #[test]
    fn backend_blamed_events_get_named_tracks() {
        let names = vec!["native".to_string(), "u128".to_string()];
        let doc = chrome_trace_named(&sample_events(), &names);
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let meta: Vec<&Json> =
            events.iter().filter(|e| field_str(e, "ph").as_deref() == Some("M")).collect();
        let labels: Vec<String> = meta
            .iter()
            .filter_map(|m| m.get("args").and_then(|a| field_str(a, "name")))
            .collect();
        assert!(labels.contains(&"backend native".to_string()), "{labels:?}");
        assert!(labels.contains(&"backend u128".to_string()), "{labels:?}");
        assert!(labels.contains(&"stage queue".to_string()), "{labels:?}");
        assert!(labels.contains(&"lifecycle".to_string()), "{labels:?}");
        // exec spans moved off the stage block onto backend 0's track
        let exec = events
            .iter()
            .find(|e| field_str(e, "name").as_deref() == Some("exec"))
            .unwrap();
        assert_eq!(
            exec.get("tid").and_then(Json::as_f64),
            Some(BACKEND_TRACK_BASE as f64),
        );
        // and the breakdown report still reduces the same spans
        let p = tmp("backend-tracks.json");
        std::fs::write(&p, doc.to_string()).unwrap();
        let report = trace_report(&p).unwrap();
        assert!(report.contains("exec"), "{report}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn report_from_both_formats_agrees() {
        let chrome = tmp("report.json");
        let flat = tmp("report.jsonl");
        write_trace(&chrome, &sample_events()).unwrap();
        write_trace(&flat, &sample_events()).unwrap();
        let a = trace_report(&chrome).unwrap();
        let b = trace_report(&flat).unwrap();
        assert_eq!(a, b, "both formats reduce to the same table");
        assert!(a.contains("divide/f32"), "{a}");
        assert!(a.contains("queue"), "{a}");
        // exec is 5000 of 10000 ns per request -> 50% share, p50 5.0 us
        assert!(a.contains("50.0%"), "{a}");
        assert!(a.contains("5.0"), "{a}");
        std::fs::remove_file(&chrome).ok();
        std::fs::remove_file(&flat).ok();
    }

    #[test]
    fn report_without_spans_says_so() {
        let p = tmp("empty.json");
        write_trace(&p, &[TraceEvent::new(TraceKind::Submit, 0)]).unwrap();
        let r = trace_report(&p).unwrap();
        assert!(r.contains("no stage spans"), "{r}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn jsonl_round_trips_through_parse() {
        let mut evs = sample_events();
        evs.push(span(TraceKind::StageExec, 42, 99, 777).on_backend(1).on_shard(2).with_arg(5));
        let body = jsonl(&evs);
        let parsed: Vec<TraceEvent> =
            body.lines().map(|l| parse_jsonl_event(l).unwrap()).collect();
        assert_eq!(parsed.len(), evs.len());
        for (a, b) in evs.iter().zip(&parsed) {
            assert_eq!((a.kind, a.t_ns, a.id, a.op, a.format), (b.kind, b.t_ns, b.id, b.op, b.format));
            assert_eq!((a.dur_ns, a.backend, a.shard, a.lanes, a.arg), (b.dur_ns, b.backend, b.shard, b.lanes, b.arg));
        }
        assert!(parse_jsonl_event("{\"kind\":\"no-such-kind\",\"op\":\"divide\",\"format\":\"f32\"}").is_err());
        assert!(parse_jsonl_event("not json").is_err());
    }

    #[test]
    fn merge_segments_rebuilds_a_sorted_chrome_trace() {
        let evs = sample_events();
        // split out of timestamp order across two segments
        let seg_a = tmp("merge-a.jsonl");
        let seg_b = tmp("merge-b.jsonl");
        std::fs::write(&seg_a, jsonl(&evs[evs.len() / 2..])).unwrap();
        std::fs::write(&seg_b, jsonl(&evs[..evs.len() / 2])).unwrap();
        let target = tmp("merged.json");
        let n = merge_segments(
            &[seg_a.clone(), seg_b.clone()],
            &target,
            &["native".to_string(), "u128".to_string()],
        )
        .unwrap();
        assert_eq!(n, evs.len());
        // the merged document is a valid Chrome trace the report parses
        let doc = Json::parse(&std::fs::read_to_string(&target).unwrap()).unwrap();
        let rows = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let data_rows: Vec<&Json> =
            rows.iter().filter(|r| field_str(r, "ph").as_deref() != Some("M")).collect();
        assert_eq!(data_rows.len(), evs.len());
        let ts: Vec<f64> =
            data_rows.iter().filter_map(|r| r.get("ts").and_then(Json::as_f64)).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "merged events sorted by time");
        assert!(trace_report(&target).unwrap().contains("divide/f32"));
        // a missing segment is an error, not a silent partial merge
        assert!(merge_segments(&[tmp("nope.jsonl")], &target, &[]).is_err());
        for p in [seg_a, seg_b, target] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn report_attributes_stage_latency_by_shard() {
        let mut evs = Vec::new();
        for id in 0..8u64 {
            let shard = (id % 2) as usize;
            // shard 1 is twice as slow in exec — the report should show it
            let exec = if shard == 1 { 8_000 } else { 4_000 };
            evs.push(span(TraceKind::StageQueue, id, id * 100, 1_000).on_shard(shard));
            evs.push(span(TraceKind::StageExec, id, id * 100 + 10, exec).on_shard(shard));
        }
        let p = tmp("shard-report.jsonl");
        write_trace(&p, &evs).unwrap();
        let report = trace_report(&p).unwrap();
        assert!(report.contains("per-shard stage attribution"), "{report}");
        assert!(report.contains("shard0"), "{report}");
        assert!(report.contains("shard1"), "{report}");
        assert!(report.contains("8.0"), "shard 1 exec p50 visible: {report}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn jsonl_lines_parse_individually() {
        let body = jsonl(&sample_events());
        for line in body.lines() {
            let row = Json::parse(line).unwrap();
            assert!(row.get("kind").is_some());
            assert!(row.get("t_ns").is_some());
        }
        // error-class row keeps its backend blame
        let last = Json::parse(body.lines().last().unwrap()).unwrap();
        assert_eq!(field_str(&last, "kind").as_deref(), Some("exec-error"));
        assert_eq!(last.get("backend").and_then(Json::as_f64), Some(1.0));
    }
}
