//! [`TraceDrainer`]: streaming trace export for long-lived serving.
//!
//! The trace plane's rings are sized for *bursts*, not for a run's
//! whole history — before this module, events were drained at
//! shutdown, so any serve longer than ring capacity silently lost its
//! past. The drainer is a background thread (`fpu-trace-drainer`) that
//! pumps the rings on an interval while the service runs and appends
//! each batch as JSONL to a **rotating segment file**
//! (`trace.seg0.jsonl`, `trace.seg1.jsonl`, ... beside the target
//! path, a new segment whenever the current one passes the configured
//! byte threshold). At [`finish`](TraceDrainer::finish) the segments
//! are re-merged — parsed back through
//! [`parse_jsonl_event`](super::export::parse_jsonl_event), sorted,
//! and written to the target in its native form (Chrome document for
//! `.json`, flat for `.jsonl`).
//!
//! Buffering is bounded end to end: the rings themselves are the
//! in-flight buffer (a slow writer backs pressure up into ring drops,
//! which the plane counts exactly), each pump hands out at most one
//! ring's worth per shard plus the new error-class events, and a
//! failing writer *counts* every event it could not persist
//! ([`DrainReport::io_drops`]) instead of stalling the hot path.
//! Error-class events are never dropped by the drainer: they are
//! cursor-copied out of the plane's unbounded side store, so the only
//! way to lose one is an I/O failure, which is accounted.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::export::{jsonl, merge_segments};
use super::ring::TracePlane;

/// Streaming export configuration.
#[derive(Clone, Debug)]
pub struct DrainConfig {
    /// Final merged output path. `.jsonl` selects the flat form,
    /// anything else the Chrome trace document. Segments live beside
    /// it as `<stem>.segN.jsonl`.
    pub path: PathBuf,
    /// Rotate to a new segment once the current one passes this many
    /// bytes (clamped to at least 4 KiB).
    pub rotate_bytes: u64,
    /// Pump period (clamped to at least 1 ms).
    pub interval: Duration,
    /// Backend names for the merged Chrome document's track labels.
    pub backend_names: Vec<String>,
}

impl Default for DrainConfig {
    /// 64 MiB segments, 200 ms pump period, `trace.json` target.
    fn default() -> Self {
        Self {
            path: PathBuf::from("trace.json"),
            rotate_bytes: 64 << 20,
            interval: Duration::from_millis(200),
            backend_names: Vec::new(),
        }
    }
}

/// Counters shared between the drainer thread and its handle.
#[derive(Debug, Default)]
struct DrainShared {
    /// Events persisted to segment files.
    written: AtomicU64,
    /// Events lost to segment I/O failures (write/open errors) — the
    /// drainer keeps running, the loss is accounted here.
    io_drops: AtomicU64,
    /// Segments opened so far.
    segments: AtomicU64,
}

/// What a finished drainer streamed, merged, and lost.
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Events appended to segment files over the run.
    pub events_written: u64,
    /// Segment files the run rotated through.
    pub segments: u64,
    /// Events lost to segment I/O failures (write/open errors).
    pub io_drops: u64,
    /// Lifecycle events the *rings* dropped while the writer lagged
    /// (`TracePlane::drops` at finish; error-class events are never
    /// subject to this).
    pub ring_drops: u64,
    /// Events in the final merged document.
    pub merged_events: usize,
    /// The merged output path.
    pub path: PathBuf,
}

/// Handle to the `fpu-trace-drainer` thread. Call
/// [`finish`](TraceDrainer::finish) after the service has shut down
/// (so nothing is still emitting) to flush, merge, and collect the
/// [`DrainReport`]; dropping without finishing stops the thread and
/// leaves the segments on disk un-merged.
#[derive(Debug)]
pub struct TraceDrainer {
    plane: Arc<TracePlane>,
    config: DrainConfig,
    stop: Arc<AtomicBool>,
    shared: Arc<DrainShared>,
    thread: Option<JoinHandle<()>>,
}

/// Segment path `i` for a merge target: `trace.json` →
/// `trace.seg<i>.jsonl` in the same directory.
pub fn segment_path(target: &Path, index: u64) -> PathBuf {
    let stem = target.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    target.with_file_name(format!("{stem}.seg{index}.jsonl"))
}

/// One open segment file with its byte budget.
struct Segment {
    file: File,
    bytes: u64,
}

fn open_segment(target: &Path, index: u64) -> Result<Segment> {
    let path = segment_path(target, index);
    let file = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .open(&path)
        .with_context(|| format!("open trace segment {}", path.display()))?;
    Ok(Segment { file, bytes: 0 })
}

impl TraceDrainer {
    /// Spawn the drainer over `plane`. The thread opens its first
    /// segment eagerly so a permission problem surfaces here, not
    /// minutes into a soak.
    pub fn start(plane: Arc<TracePlane>, config: DrainConfig) -> Result<TraceDrainer> {
        if config.path.as_os_str().is_empty() {
            bail!("trace drain path is empty");
        }
        let rotate = config.rotate_bytes.max(4 << 10);
        let interval = config.interval.max(Duration::from_millis(1));
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(DrainShared::default());
        let mut segment = open_segment(&config.path, 0)?;
        shared.segments.store(1, Ordering::Relaxed);
        let thread = {
            let (plane, stop, shared) = (plane.clone(), stop.clone(), shared.clone());
            let target = config.path.clone();
            std::thread::Builder::new()
                .name("fpu-trace-drainer".into())
                .spawn(move || {
                    let mut error_cursor = 0usize;
                    loop {
                        // read the flag *before* draining: everything
                        // emitted up to a stop request still flushes on
                        // the final pass
                        let stopping = stop.load(Ordering::Acquire);
                        let mut events = plane.take_collected();
                        let errors = plane.errors_since(error_cursor);
                        error_cursor += errors.len();
                        events.extend(errors);
                        if !events.is_empty() {
                            events.sort_by_key(|e| (e.t_ns, e.id));
                            let body = jsonl(&events);
                            match segment.file.write_all(body.as_bytes()).and_then(|()| segment.file.flush()) {
                                Ok(()) => {
                                    segment.bytes += body.len() as u64;
                                    shared.written.fetch_add(events.len() as u64, Ordering::Relaxed);
                                }
                                Err(_) => {
                                    shared.io_drops.fetch_add(events.len() as u64, Ordering::Relaxed);
                                }
                            }
                            if segment.bytes >= rotate && !stopping {
                                let next = shared.segments.load(Ordering::Relaxed);
                                match open_segment(&target, next) {
                                    Ok(s) => {
                                        segment = s;
                                        shared.segments.store(next + 1, Ordering::Relaxed);
                                    }
                                    // keep appending to the full
                                    // segment rather than lose events
                                    Err(_) => {}
                                }
                            }
                        }
                        if stopping {
                            return;
                        }
                        // sleep in slices so a stop request is honored
                        // promptly even with long pump intervals
                        let mut left = interval;
                        while !left.is_zero() && !stop.load(Ordering::Acquire) {
                            let slice = left.min(Duration::from_millis(20));
                            std::thread::sleep(slice);
                            left = left.saturating_sub(slice);
                        }
                    }
                })
                .context("spawn fpu-trace-drainer")?
        };
        Ok(TraceDrainer { plane, config, stop, shared, thread: Some(thread) })
    }

    /// Events persisted to segments so far (live gauge).
    pub fn events_written(&self) -> u64 {
        self.shared.written.load(Ordering::Relaxed)
    }

    /// Events lost to segment I/O failures so far (live gauge).
    pub fn io_drops(&self) -> u64 {
        self.shared.io_drops.load(Ordering::Relaxed)
    }

    /// Stop the thread (final flush pass included), merge the segments
    /// into the target path, and report the accounting. Call after the
    /// emitting service has shut down.
    pub fn finish(mut self) -> Result<DrainReport> {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        let segments = self.shared.segments.load(Ordering::Relaxed);
        let paths: Vec<PathBuf> =
            (0..segments).map(|i| segment_path(&self.config.path, i)).collect();
        let merged_events =
            merge_segments(&paths, &self.config.path, &self.config.backend_names)?;
        Ok(DrainReport {
            events_written: self.shared.written.load(Ordering::Relaxed),
            segments,
            io_drops: self.shared.io_drops.load(Ordering::Relaxed),
            ring_drops: self.plane.drops(),
            merged_events,
            path: self.config.path.clone(),
        })
    }
}

impl Drop for TraceDrainer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::OpKind;
    use crate::formats::FormatKind;
    use crate::obs::export::trace_report;
    use crate::obs::ring::{TraceConfig, TraceEvent, TraceKind};
    use crate::util::json::Json;

    fn ev(kind: TraceKind, id: u64, t: u64) -> TraceEvent {
        TraceEvent::new(kind, t).req(id, OpKind::Divide, FormatKind::F32)
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("goldschmidt-drain-{}-{name}", std::process::id()))
    }

    fn cleanup(report: &DrainReport) {
        std::fs::remove_file(&report.path).ok();
        for i in 0..report.segments {
            std::fs::remove_file(segment_path(&report.path, i)).ok();
        }
    }

    /// The acceptance property: far more events stream through than the
    /// rings can hold, with exact accounting.
    #[test]
    fn streaming_outlives_ring_capacity() {
        // 8 shards x 8 slots = 64 in-flight events maximum
        let plane = Arc::new(TracePlane::new(TraceConfig { sample: 1, capacity: 8 }));
        let drainer = TraceDrainer::start(
            plane.clone(),
            DrainConfig {
                path: tmp("stream.json"),
                interval: Duration::from_millis(1),
                ..DrainConfig::default()
            },
        )
        .unwrap();
        let total_capacity = 64u64;
        let emitted = 640u64;
        for round in 0..10u64 {
            for i in 0..64u64 {
                let id = round * 64 + i;
                plane.emit(ev(TraceKind::Enqueue, id, id));
            }
            // give the drainer time to pump between bursts — this is
            // the streaming the shutdown-drain model could not do
            std::thread::sleep(Duration::from_millis(25));
        }
        let report = drainer.finish().unwrap();
        assert_eq!(report.io_drops, 0);
        assert_eq!(
            report.merged_events as u64 + report.ring_drops,
            emitted,
            "every event persisted or counted dropped: {report:?}"
        );
        assert!(
            report.merged_events as u64 > total_capacity,
            "streamed more than ring capacity ({report:?}) — shutdown-drain could not"
        );
        let doc = Json::parse(&std::fs::read_to_string(&report.path).unwrap()).unwrap();
        assert!(doc.get("traceEvents").and_then(Json::as_arr).is_some());
        cleanup(&report);
    }

    /// Overflow during a slow drainer loses only sampled lifecycle
    /// events; error-class events always land.
    #[test]
    fn slow_drainer_never_loses_error_class_events() {
        let plane = Arc::new(TracePlane::new(TraceConfig { sample: 1, capacity: 8 }));
        let drainer = TraceDrainer::start(
            plane.clone(),
            DrainConfig {
                path: tmp("slow.jsonl"),
                // effectively never pumps during the test: everything
                // rides the final flush pass
                interval: Duration::from_secs(3600),
                ..DrainConfig::default()
            },
        )
        .unwrap();
        for i in 0..1000u64 {
            plane.emit(ev(TraceKind::Enqueue, i, i));
        }
        for i in 0..50u64 {
            plane.emit(ev(TraceKind::ExecError, i, 2000 + i).on_backend(0));
        }
        let report = drainer.finish().unwrap();
        assert!(report.ring_drops > 0, "tiny rings must overflow under a stalled drainer");
        let body = std::fs::read_to_string(&report.path).unwrap();
        let mut errors = 0u64;
        let mut lifecycle = 0u64;
        for line in body.lines() {
            let row = Json::parse(line).unwrap();
            match row.get("kind").and_then(Json::as_str) {
                Some("exec-error") => errors += 1,
                Some("enqueue") => lifecycle += 1,
                other => panic!("unexpected kind {other:?}"),
            }
        }
        assert_eq!(errors, 50, "error-class events are never dropped");
        assert_eq!(lifecycle + report.ring_drops, 1000, "drop accounting is exact");
        cleanup(&report);
    }

    /// Small rotation threshold produces multiple segments that
    /// re-merge into one valid, report-parseable Chrome trace.
    #[test]
    fn rotated_segments_remerge_into_valid_trace() {
        let plane = Arc::new(TracePlane::new(TraceConfig { sample: 1, capacity: 1024 }));
        let drainer = TraceDrainer::start(
            plane.clone(),
            DrainConfig {
                path: tmp("rotate.json"),
                rotate_bytes: 1, // clamped to 4 KiB — still tiny
                interval: Duration::from_millis(1),
                backend_names: vec!["native".to_string()],
            },
        )
        .unwrap();
        for round in 0..20u64 {
            for i in 0..50u64 {
                let id = round * 50 + i;
                plane.emit(
                    TraceEvent::new(TraceKind::StageExec, id * 10)
                        .req(id, OpKind::Divide, FormatKind::F32)
                        .spanning(500)
                        .on_backend(0)
                        .on_shard((id % 4) as usize)
                        .with_lanes(1),
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let report = drainer.finish().unwrap();
        assert!(report.segments > 1, "4 KiB threshold must rotate: {report:?}");
        assert_eq!(report.ring_drops, 0, "1024-slot rings with a 1 ms pump never overflow here");
        assert_eq!(report.merged_events, 1000);
        assert_eq!(report.events_written, 1000);
        // the merged Chrome document parses and the report reduces it,
        // including per-shard attribution
        let rendered = trace_report(&report.path).unwrap();
        assert!(rendered.contains("divide/f32"), "{rendered}");
        assert!(rendered.contains("per-shard stage attribution"), "{rendered}");
        assert!(rendered.contains("shard3"), "{rendered}");
        cleanup(&report);
    }
}
