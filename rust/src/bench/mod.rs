//! Benchmark harness (the offline environment has no `criterion`; this
//! module is the crate's measurement substrate, used by every target in
//! `benches/`, each of which is built with `harness = false`).
//!
//! Method: warm up for a fixed duration, then run timed batches until a
//! target measurement time elapses, recording per-iteration wall time.
//! Reports mean / p50 / p99 / min plus derived throughput. Batch sizing
//! auto-calibrates so each sample costs ~1ms, keeping timer overhead
//! negligible for nanosecond-scale bodies.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::tablefmt::{fmt_ns, Align, Table};

/// One benchmark's collected measurements (per-iteration nanoseconds).
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark id, e.g. `"sim/feedback/k=3"`.
    pub name: String,
    /// Per-iteration wall time statistics (ns).
    pub ns: Summary,
    /// Total iterations measured.
    pub iters: u64,
}

impl Measurement {
    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        self.ns.mean()
    }

    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.ns.mean() == 0.0 { 0.0 } else { 1e9 / self.ns.mean() }
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Warmup wall time before measuring.
    pub warmup: Duration,
    /// Total measurement wall time budget.
    pub measure: Duration,
    /// Upper bound on recorded samples.
    pub max_samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 4096,
        }
    }
}

impl Config {
    /// Fast configuration for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            max_samples: 512,
        }
    }

    /// Honour `BENCH_QUICK=1` for fast runs of the full bench suite.
    pub fn from_env() -> Self {
        match std::env::var("BENCH_QUICK").as_deref() {
            Ok("1") | Ok("true") => Self::quick(),
            _ => Self::default(),
        }
    }
}

/// A group of related benchmarks that prints one consolidated table.
pub struct Bencher {
    config: Config,
    results: Vec<Measurement>,
    group: String,
}

impl Bencher {
    /// New bench group with the given name.
    pub fn new<S: Into<String>>(group: S) -> Self {
        Self { config: Config::from_env(), results: Vec::new(), group: group.into() }
    }

    /// Override the configuration.
    pub fn with_config(mut self, config: Config) -> Self {
        self.config = config;
        self
    }

    /// Measure `f`, which performs exactly one logical iteration per call.
    /// Returns the measurement (also retained for [`Bencher::report`]).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters: u64 = 0;
        while start.elapsed() < self.config.warmup {
            f();
            warm_iters += 1;
        }
        // Calibrate batch size: target ~1ms per sample.
        let probe_start = Instant::now();
        f();
        let probe = probe_start.elapsed().as_nanos().max(1) as u64;
        let batch = (1_000_000 / probe).clamp(1, 1_000_000);

        let mut ns = Summary::new();
        let mut iters: u64 = warm_iters + 1;
        let deadline = Instant::now() + self.config.measure;
        while Instant::now() < deadline && ns.count() < self.config.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / batch as f64;
            ns.add(per_iter);
            iters += batch;
        }
        self.results.push(Measurement { name: name.to_string(), ns, iters });
        self.results.last().expect("just pushed")
    }

    /// Measure a function that reports its own amount of work per call
    /// (e.g. simulated cycles); throughput is then work-units/second.
    pub fn bench_with_work<F: FnMut() -> u64>(&mut self, name: &str, mut f: F) -> (f64, f64) {
        let mut work: u64 = 0;
        let start = Instant::now();
        while start.elapsed() < self.config.warmup {
            work = work.wrapping_add(f());
        }
        let mut total_work: u64 = 0;
        let t0 = Instant::now();
        let deadline = t0 + self.config.measure;
        let mut calls = 0u64;
        while Instant::now() < deadline {
            total_work += f();
            calls += 1;
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let per_call_ns = elapsed * 1e9 / calls.max(1) as f64;
        let work_per_sec = total_work as f64 / elapsed;
        let mut ns = Summary::new();
        ns.add(per_call_ns);
        self.results.push(Measurement { name: name.to_string(), ns, iters: calls });
        (per_call_ns, work_per_sec)
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render the consolidated results table.
    pub fn report(&self) -> String {
        let mut t = Table::new(
            format!("bench: {}", self.group),
            &["name", "mean", "p50", "p99", "min", "iters/s"],
        )
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for m in &self.results {
            t.row(&[
                m.name.clone(),
                fmt_ns(m.ns.mean()),
                fmt_ns(m.ns.median()),
                fmt_ns(m.ns.percentile(99.0)),
                fmt_ns(m.ns.min()),
                format!("{:.0}", m.throughput()),
            ]);
        }
        t.render()
    }

    /// Print the consolidated results table to stdout. If `BENCH_JSON`
    /// names a directory, also append a machine-readable report there
    /// (`<group>.json`, one JSON object per run).
    pub fn print_report(&self) {
        print!("{}", self.report());
        if let Ok(dir) = std::env::var("BENCH_JSON") {
            if let Err(e) = self.write_json(std::path::Path::new(&dir)) {
                eprintln!("BENCH_JSON write failed: {e}");
            }
        }
    }

    /// Serialize all measurements as JSON into `dir/<group>.json`.
    pub fn write_json(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let json = Json::obj([
            ("group", Json::from(self.group.clone())),
            (
                "results",
                Json::arr(self.results.iter().map(|m| {
                    Json::obj([
                        ("name", Json::from(m.name.clone())),
                        ("mean_ns", Json::from(m.ns.mean())),
                        ("p50_ns", Json::from(m.ns.median())),
                        ("p99_ns", Json::from(m.ns.percentile(99.0))),
                        ("min_ns", Json::from(m.ns.min())),
                        ("iters", Json::from(m.iters)),
                        ("throughput_per_s", Json::from(m.throughput())),
                    ])
                })),
            ),
        ]);
        let name = self.group.replace('/', "_");
        std::fs::write(dir.join(format!("{name}.json")), json.to_string())
    }
}

/// Prevent the optimizer from deleting a computed value (stable-rust
/// equivalent of `std::hint::black_box` — which is used underneath).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test").with_config(Config::quick());
        let m = b.bench("noop-ish", || {
            black_box(1u64 + 1);
        });
        assert!(m.iters > 0);
        assert!(m.ns.count() > 0);
        assert!(m.mean_ns() >= 0.0);
    }

    #[test]
    fn slower_body_measures_slower() {
        let mut b = Bencher::new("test").with_config(Config::quick());
        let fast = b
            .bench("fast", || {
                black_box((0..10u64).sum::<u64>());
            })
            .mean_ns();
        let slow = b
            .bench("slow", || {
                black_box((0..10_000u64).sum::<u64>());
            })
            .mean_ns();
        assert!(slow > fast, "slow {slow} !> fast {fast}");
    }

    #[test]
    fn report_contains_all_rows() {
        let mut b = Bencher::new("grp").with_config(Config::quick());
        b.bench("one", || {
            black_box(0u8);
        });
        b.bench("two", || {
            black_box(0u8);
        });
        let rep = b.report();
        assert!(rep.contains("bench: grp"));
        assert!(rep.contains("one"));
        assert!(rep.contains("two"));
    }

    #[test]
    fn json_report_round_trips_structure() {
        let dir = std::env::temp_dir().join("gs_bench_json_test");
        let mut b = Bencher::new("grp/sub").with_config(Config::quick());
        b.bench("thing", || {
            black_box(1u8);
        });
        b.write_json(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("grp_sub.json")).unwrap();
        assert!(text.contains("\"group\":\"grp/sub\""));
        assert!(text.contains("\"name\":\"thing\""));
        assert!(text.contains("mean_ns"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_with_work_reports_throughput() {
        let mut b = Bencher::new("w").with_config(Config::quick());
        let (per_call, per_sec) = b.bench_with_work("work", || {
            black_box((0..100u64).sum::<u64>());
            100
        });
        assert!(per_call > 0.0);
        assert!(per_sec > 0.0);
    }
}
