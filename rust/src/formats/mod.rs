//! The multi-precision format plane: IEEE-754 geometry as data, serving
//! f16 / bf16 / f32 / f64 through one datapath implementation.
//!
//! # Why a formats subsystem
//!
//! The paper's reorganized datapath (one ROM, two parallel multipliers,
//! a complement block) is *geometry-agnostic*: nothing in the
//! Goldschmidt iteration depends on the IEEE container — only the
//! sign/exponent/mantissa split at the boundary does. This module
//! captures that boundary once, generically:
//!
//! * [`FloatFormat`] — a zero-sized type per IEEE format carrying the
//!   field geometry as associated constants (monomorphized, so the
//!   pack/unpack code compiles to straight-line bit twiddling per
//!   format, with no runtime dispatch).
//! * [`FormatKind`] — the matching runtime tag the coordinator threads
//!   through requests, queues, batches and metrics.
//! * [`classify`] / [`unpack`] / [`pack`] — the shared FPU boundary:
//!   classification, subnormal-normalizing decomposition into a
//!   [`Fixed`] mantissa in `[1, 2)`, and round-to-nearest-even
//!   recomposition (overflow to infinity, graceful subnormal underflow).
//! * [`divide_via_bits`] / [`sqrt_via_bits`] / [`rsqrt_via_bits`] — the
//!   IEEE special-case envelopes around a mantissa-core closure, shared
//!   by the scalar reference paths and the batch kernels.
//! * [`Value`] — a format-tagged scalar for the request/response plane
//!   (f16/bf16 carried as raw bit patterns; Rust has no native type).
//! * [`plane`] — width-true operand/result planes ([`PlaneBuf`] /
//!   [`PlaneRef`] / [`PlaneRefMut`]): `u32` lanes for f16/bf16, `u64`
//!   for f32/f64 (each format's [`FloatFormat::Plane`] /
//!   [`FormatKind::plane_width`] geometry), so half-precision batches
//!   move half the bytes end to end.
//!
//! # Geometry -> paper hardware mapping
//!
//! Each format instantiates the paper's datapath at a different word
//! width **and ROM size** — `table_p` is part of the per-format
//! configuration, so a format only pays for the lookup accuracy its
//! mantissa actually needs. The per-format derivation is:
//!
//! | format | mant bits | table_p (ROM)     | datapath frac | multiplier width | steps (bound) |
//! |--------|-----------|-------------------|---------------|------------------|---------------|
//! | bf16   | 7         |  5 (32 entries)   | 20 (13 guard) | 22 x 22          | 2 (1)         |
//! | f16    | 10        | 10 (1024 entries) | 20 (10 guard) | 22 x 22          | 2 (1)         |
//! | f32    | 23        | 10 (1024 entries) | 30 ( 7 guard) | 32 x 32          | 3 (2)         |
//! | f64    | 52        | 10 (1024 entries) | 58 ( 6 guard) | 60 x 60          | 4 (3)         |
//!
//! "multiplier width" is `frac + 2` (the Q2.frac datapath word — the
//! paper's MULT 1 / MULT 2 operand width); "steps" is the programmed
//! logic-block counter, the paper's §III knob, set one above the
//! analytic bound from [`Config::steps_for_accuracy`] (quadratic
//! convergence from the table error `1.5 * 2^-(p+1)`) so rounding noise
//! in the narrowed products never surfaces. bf16's 8-bit result only
//! needs a p=5 seed (error `1.5 * 2^-6`, squared once to `5.5e-4`,
//! twice to `3e-7` — far under its half-ulp `2^-9`), so its ROM shrinks
//! 32x in entry count (~55x in bits vs the p=10 table) at the cost of
//! one extra refinement step — the paper's area-vs-steps trade applied
//! across the format plane. [`FormatKind::datapath_config`] encodes
//! this table; `crate::area::format_rom_rows` prices it.

use crate::arith::fixed::{narrow_u128, Fixed, Rounding};
use crate::arith::limb::PlaneWord;
use crate::goldschmidt::config::Config;

pub mod plane;

pub use plane::{PlaneBuf, PlaneExtract, PlaneRef, PlaneRefMut, PlaneWidth};

/// Classification of inputs the mantissa datapath does not handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpClass {
    /// Normal or subnormal nonzero finite value (datapath-eligible;
    /// subnormals are normalized with an exponent adjustment).
    Finite,
    /// Positive or negative zero.
    Zero,
    /// Infinity.
    Inf,
    /// Not a number.
    Nan,
}

/// Runtime format tag: the routing key the coordinator carries alongside
/// [`OpKind`](crate::coordinator::request::OpKind).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FormatKind {
    /// IEEE binary16 (half precision).
    F16,
    /// bfloat16 (f32's exponent range, 7 mantissa bits).
    BF16,
    /// IEEE binary32 (single precision).
    F32,
    /// IEEE binary64 (double precision — EIMMW-2000's native format).
    F64,
}

impl FormatKind {
    /// All formats, in routing order.
    pub const ALL: [FormatKind; 4] = [
        FormatKind::F16,
        FormatKind::BF16,
        FormatKind::F32,
        FormatKind::F64,
    ];

    /// Dense index (for per-format tables: queues, metrics, contexts).
    pub fn index(self) -> usize {
        match self {
            FormatKind::F16 => 0,
            FormatKind::BF16 => 1,
            FormatKind::F32 => 2,
            FormatKind::F64 => 3,
        }
    }

    /// Stable label for metrics/tables/CLI.
    pub fn label(self) -> &'static str {
        match self {
            FormatKind::F16 => "f16",
            FormatKind::BF16 => "bf16",
            FormatKind::F32 => "f32",
            FormatKind::F64 => "f64",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "f16" | "half" => Ok(FormatKind::F16),
            "bf16" | "bfloat16" => Ok(FormatKind::BF16),
            "f32" | "single" => Ok(FormatKind::F32),
            "f64" | "double" => Ok(FormatKind::F64),
            other => Err(format!("unknown format {other:?} (f16|bf16|f32|f64)")),
        }
    }

    /// Container width in bits.
    pub fn total_bits(self) -> u32 {
        match self {
            FormatKind::F16 | FormatKind::BF16 => 16,
            FormatKind::F32 => 32,
            FormatKind::F64 => 64,
        }
    }

    /// Width-true plane-word geometry: the storage word one SoA lane of
    /// this format occupies, end to end (kernel mantissa planes and the
    /// coordinator's operand/result planes alike). Half-precision lanes
    /// ride `u32` words — their 16-bit containers and 22-bit Q2.20
    /// datapath words both fit, halving plane memory traffic vs the old
    /// universal `u64` word — while f32 (Q2.30 = 32-bit datapath words
    /// alongside 32-bit containers) and f64 keep `u64`.
    pub fn plane_width(self) -> PlaneWidth {
        match self {
            FormatKind::F16 | FormatKind::BF16 => PlaneWidth::W32,
            FormatKind::F32 | FormatKind::F64 => PlaneWidth::W64,
        }
    }

    /// Mantissa field width in bits.
    pub fn mant_bits(self) -> u32 {
        match self {
            FormatKind::F16 => F16::MANT_BITS,
            FormatKind::BF16 => BF16::MANT_BITS,
            FormatKind::F32 => F32::MANT_BITS,
            FormatKind::F64 => F64::MANT_BITS,
        }
    }

    /// The bit pattern of `1.0` in this format (the batcher's neutral
    /// padding operand).
    pub fn one_bits(self) -> u64 {
        match self {
            FormatKind::F16 => (F16::BIAS as u64) << F16::MANT_BITS,
            FormatKind::BF16 => (BF16::BIAS as u64) << BF16::MANT_BITS,
            FormatKind::F32 => (F32::BIAS as u64) << F32::MANT_BITS,
            FormatKind::F64 => (F64::BIAS as u64) << F64::MANT_BITS,
        }
    }

    /// The paper's datapath instantiated for this format: per-format
    /// ROM width (`table_p`), fraction width (mantissa + guard bits)
    /// and refinement count (one above the analytic
    /// [`Config::steps_for_accuracy`] bound — see the module table).
    /// bf16 reaches its accuracy bound from a p=5 seed, so its ROM is
    /// 32 entries instead of 1024.
    pub fn datapath_config(self) -> Config {
        match self {
            FormatKind::F16 => Config::default().with_frac(20).with_steps(2),
            FormatKind::BF16 => {
                Config::default().with_table_p(5).with_frac(20).with_steps(2)
            }
            FormatKind::F32 => Config::default(),
            FormatKind::F64 => Config::double(),
        }
    }
}

impl std::fmt::Display for FormatKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// IEEE-754 field geometry as associated constants. Implementors are
/// zero-sized tags; every helper in this module monomorphizes over them
/// so each format gets branch-free pack/unpack code.
///
/// Raw bit patterns travel as `u64` regardless of container width (the
/// upper bits are zero) — one plane type serves every format in the SoA
/// kernels and the coordinator.
pub trait FloatFormat: Copy + Send + Sync + 'static {
    /// The matching runtime tag.
    const KIND: FormatKind;
    /// Container width in bits (16 / 32 / 64).
    const BITS: u32;
    /// Exponent field width.
    const EXP_BITS: u32;
    /// Mantissa (fraction) field width.
    const MANT_BITS: u32;
    /// Width-true plane word: the storage type of one SoA lane of this
    /// format (raw container bits and mantissa datapath words both fit).
    /// Must agree with [`FormatKind::plane_width`].
    type Plane: PlaneWord;

    // ---- derived geometry (never override) ----------------------------
    /// Exponent bias.
    const BIAS: i32 = (1i32 << (Self::EXP_BITS - 1)) - 1;
    /// Minimum normal exponent.
    const EXP_MIN: i32 = 1 - Self::BIAS;
    /// Maximum normal exponent.
    const EXP_MAX: i32 = Self::BIAS;
    /// Exponent field mask (in place at bit 0).
    const EXP_MASK: u64 = (1u64 << Self::EXP_BITS) - 1;
    /// Mantissa field mask.
    const MANT_MASK: u64 = (1u64 << Self::MANT_BITS) - 1;
    /// Sign bit mask.
    const SIGN_MASK: u64 = 1u64 << (Self::BITS - 1);
    /// Positive infinity bit pattern.
    const INF: u64 = Self::EXP_MASK << Self::MANT_BITS;
    /// Canonical quiet NaN bit pattern.
    const QNAN: u64 = (Self::EXP_MASK << Self::MANT_BITS) | (1u64 << (Self::MANT_BITS - 1));
}

/// IEEE binary16.
#[derive(Clone, Copy, Debug, Default)]
pub struct F16;
impl FloatFormat for F16 {
    const KIND: FormatKind = FormatKind::F16;
    const BITS: u32 = 16;
    const EXP_BITS: u32 = 5;
    const MANT_BITS: u32 = 10;
    type Plane = u32;
}

/// bfloat16: f32 truncated to 16 bits (same exponent range, 7 mantissa
/// bits).
#[derive(Clone, Copy, Debug, Default)]
pub struct BF16;
impl FloatFormat for BF16 {
    const KIND: FormatKind = FormatKind::BF16;
    const BITS: u32 = 16;
    const EXP_BITS: u32 = 8;
    const MANT_BITS: u32 = 7;
    type Plane = u32;
}

/// IEEE binary32.
#[derive(Clone, Copy, Debug, Default)]
pub struct F32;
impl FloatFormat for F32 {
    const KIND: FormatKind = FormatKind::F32;
    const BITS: u32 = 32;
    const EXP_BITS: u32 = 8;
    const MANT_BITS: u32 = 23;
    type Plane = u64;
}

/// IEEE binary64.
#[derive(Clone, Copy, Debug, Default)]
pub struct F64;
impl FloatFormat for F64 {
    const KIND: FormatKind = FormatKind::F64;
    const BITS: u32 = 64;
    const EXP_BITS: u32 = 11;
    const MANT_BITS: u32 = 52;
    type Plane = u64;
}

/// Sign bit of a raw word.
#[inline]
pub fn sign_bit<F: FloatFormat>(bits: u64) -> bool {
    bits & F::SIGN_MASK != 0
}

/// Signed-zero bit pattern.
#[inline]
pub fn zero_bits<F: FloatFormat>(negative: bool) -> u64 {
    if negative { F::SIGN_MASK } else { 0 }
}

/// Signed-infinity bit pattern.
#[inline]
pub fn inf_bits<F: FloatFormat>(negative: bool) -> u64 {
    F::INF | zero_bits::<F>(negative)
}

/// Classify a raw word for dispatch before the datapath.
#[inline]
pub fn classify<F: FloatFormat>(bits: u64) -> FpClass {
    let exp = (bits >> F::MANT_BITS) & F::EXP_MASK;
    let mant = bits & F::MANT_MASK;
    if exp == F::EXP_MASK {
        if mant == 0 { FpClass::Inf } else { FpClass::Nan }
    } else if exp == 0 && mant == 0 {
        FpClass::Zero
    } else {
        FpClass::Finite
    }
}

/// A decomposed finite, nonzero value:
/// `value = (-1)^sign * mant * 2^exp` with `mant` a [`Fixed`] in `[1, 2)`.
#[derive(Clone, Copy, Debug)]
pub struct Unpacked {
    /// Sign bit.
    pub sign: bool,
    /// Unbiased exponent of the leading bit.
    pub exp: i32,
    /// Mantissa in `[1, 2)` at the requested fraction width.
    pub mant: Fixed,
}

/// Unpack a finite nonzero word into sign/exponent/mantissa-in-`[1,2)`
/// at `frac` fraction bits. Subnormals are normalized (their leading
/// zeros move into the exponent), exactly as a hardware pre-normalizer
/// does. A `frac` narrower than the mantissa field rounds (nearest) —
/// the narrow-datapath sweeps use this.
pub fn unpack<F: FloatFormat>(bits: u64, frac: u32) -> Unpacked {
    assert!(
        classify::<F>(bits) == FpClass::Finite,
        "unpack::<{}>({bits:#x}) on non-finite",
        F::KIND
    );
    let sign = sign_bit::<F>(bits);
    let biased = ((bits >> F::MANT_BITS) & F::EXP_MASK) as i32;
    let raw = bits & F::MANT_MASK;
    let (exp, field) = if biased == 0 {
        // subnormal: value = raw * 2^(EXP_MIN - MANT_BITS); normalize the
        // leading 1 out of the field
        let lz = raw.leading_zeros() - (64 - F::MANT_BITS);
        (F::EXP_MIN - 1 - lz as i32, (raw << (lz + 1)) & F::MANT_MASK)
    } else {
        (biased - F::BIAS, raw)
    };
    let full = (1u64 << F::MANT_BITS) | field; // 1.field at MANT_BITS frac
    let mant = if frac >= F::MANT_BITS {
        Fixed::from_bits(full << (frac - F::MANT_BITS), frac)
    } else {
        let rounded = narrow_u128(full as u128, F::MANT_BITS - frac, Rounding::Nearest) as u64;
        Fixed::from_bits(rounded, frac)
    };
    Unpacked { sign, exp, mant }
}

/// Repack sign/exponent/mantissa into a raw word with
/// round-to-nearest-even. The mantissa may lie anywhere in `(0, 4)` (the
/// exponent is renormalized); zero packs to a signed zero. Overflow
/// saturates to infinity; underflow rounds into the subnormal range (a
/// single RNE rounding at the subnormal quantum) and then to zero.
pub fn pack<F: FloatFormat>(sign: bool, exp: i32, mant: &Fixed) -> u64 {
    let bits = mant.bits();
    if bits == 0 {
        return zero_bits::<F>(sign);
    }
    let msb = 63 - bits.leading_zeros() as i32; // bit index of the leading 1
    let mut e = exp + (msb - mant.frac() as i32); // exponent of the leading 1
    // Bits to drop so MANT_BITS fraction bits remain after the leading 1;
    // below the normal range the target quantum coarsens by the deficit.
    let mut shift = msb - F::MANT_BITS as i32;
    if e < F::EXP_MIN {
        shift += F::EXP_MIN - e;
    }
    let mut sig: u64 = if shift <= 0 {
        bits << (-shift) as u32 // exact: result msb stays below 2^(MANT_BITS+1)
    } else if shift >= 126 {
        0 // deep underflow: rem < half is guaranteed (bits has < 64 bits)
    } else {
        let sh = shift as u32;
        let wide = bits as u128;
        let keep = (wide >> sh) as u64;
        let half = 1u128 << (sh - 1);
        let rem = wide & ((1u128 << sh) - 1);
        let round_up = rem > half || (rem == half && keep & 1 == 1);
        keep + round_up as u64
    };
    // rounding may carry out of the significand: renormalize
    if sig >= 1u64 << (F::MANT_BITS + 1) {
        sig >>= 1;
        e += 1;
    }
    if e < F::EXP_MIN {
        // subnormal result (biased exponent 0); a round-up to exactly
        // 2^MANT_BITS is the minimum normal
        return if sig >= 1u64 << F::MANT_BITS {
            zero_bits::<F>(sign) | (1u64 << F::MANT_BITS) | (sig & F::MANT_MASK)
        } else {
            zero_bits::<F>(sign) | sig
        };
    }
    if e > F::EXP_MAX {
        return inf_bits::<F>(sign);
    }
    zero_bits::<F>(sign) | (((e + F::BIAS) as u64) << F::MANT_BITS) | (sig & F::MANT_MASK)
}

// -------------------------------------------------------------------------
// IEEE special-case envelopes around a mantissa core.
//
// These are the single source of truth for special handling across the
// scalar reference paths and the batch kernels: the typed f32/f64
// wrappers in `arith::fp` / `arith::fp64` delegate here, so every
// format — and both the scalar and batch sides of the bit-for-bit
// contract — shares one set of arms. (This rewrite also fixed the
// seed's sign handling for quotients involving signed zeros: IEEE
// requires inf / -0 = -inf and -0 / inf = -0.)

/// Divide through a mantissa-division closure: IEEE specials handled
/// around the `[1,2) x [1,2) -> (1/2, 2)` core the datapath provides.
pub fn divide_via_bits<F, C>(n: u64, d: u64, frac: u32, core: C) -> u64
where
    F: FloatFormat,
    C: FnOnce(Fixed, Fixed) -> Fixed,
{
    let (cn, cd) = (classify::<F>(n), classify::<F>(d));
    // IEEE 754: the sign of every non-NaN quotient is the XOR of the raw
    // operand sign bits — signed zeros included (inf / -0 is -inf).
    let sign = sign_bit::<F>(n) ^ sign_bit::<F>(d);
    match (cn, cd) {
        (FpClass::Nan, _) | (_, FpClass::Nan) => F::QNAN,
        (FpClass::Inf, FpClass::Inf) | (FpClass::Zero, FpClass::Zero) => F::QNAN,
        (FpClass::Inf, _) | (_, FpClass::Zero) => inf_bits::<F>(sign),
        (_, FpClass::Inf) | (FpClass::Zero, _) => zero_bits::<F>(sign),
        (FpClass::Finite, FpClass::Finite) => {
            let un = unpack::<F>(n, frac);
            let ud = unpack::<F>(d, frac);
            let q = core(un.mant, ud.mant);
            pack::<F>(sign, un.exp - ud.exp, &q)
        }
    }
}

/// Fold the exponent parity for the sqrt family: `x = m * 2^e` with
/// `m in [1,2)` becomes `d in [1,4)` and a halved exponent.
#[inline]
fn fold_parity(u: &Unpacked, frac: u32) -> (Fixed, i32) {
    if u.exp % 2 == 0 {
        (u.mant, u.exp / 2)
    } else {
        (Fixed::from_bits(u.mant.bits() << 1, frac), (u.exp - 1) / 2)
    }
}

/// Square root through a mantissa closure (`d in [1,4) -> sqrt(d)`).
/// Negative inputs give NaN, zeros pass through signed, +inf gives +inf.
pub fn sqrt_via_bits<F, C>(x: u64, frac: u32, core: C) -> u64
where
    F: FloatFormat,
    C: FnOnce(Fixed) -> Fixed,
{
    match classify::<F>(x) {
        FpClass::Nan => F::QNAN,
        FpClass::Zero => x, // sqrt(+-0) = +-0
        FpClass::Inf => {
            if sign_bit::<F>(x) { F::QNAN } else { F::INF }
        }
        FpClass::Finite if sign_bit::<F>(x) => F::QNAN,
        FpClass::Finite => {
            let u = unpack::<F>(x, frac);
            let (d, half_exp) = fold_parity(&u, frac);
            pack::<F>(false, half_exp, &core(d))
        }
    }
}

/// Reciprocal square root through a mantissa closure
/// (`d in [1,4) -> 1/sqrt(d)`). Zero gives +inf, +inf gives +0,
/// negatives give NaN.
pub fn rsqrt_via_bits<F, C>(x: u64, frac: u32, core: C) -> u64
where
    F: FloatFormat,
    C: FnOnce(Fixed) -> Fixed,
{
    match classify::<F>(x) {
        FpClass::Nan => F::QNAN,
        FpClass::Zero => F::INF,
        FpClass::Inf => {
            if sign_bit::<F>(x) { F::QNAN } else { 0 }
        }
        FpClass::Finite if sign_bit::<F>(x) => F::QNAN,
        FpClass::Finite => {
            let u = unpack::<F>(x, frac);
            let (d, half_exp) = fold_parity(&u, frac);
            pack::<F>(false, -half_exp, &core(d))
        }
    }
}

// -------------------------------------------------------------------------
// Format-tagged scalar values.

/// A scalar tagged with its format: the unit the request/response plane
/// carries. f16/bf16 travel as raw bit patterns (Rust has no native
/// half types); f32/f64 keep their native representation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Value {
    /// IEEE binary16, raw bits.
    F16(u16),
    /// bfloat16, raw bits.
    BF16(u16),
    /// IEEE binary32.
    F32(f32),
    /// IEEE binary64.
    F64(f64),
}

impl Value {
    /// The value's format tag.
    pub fn format(self) -> FormatKind {
        match self {
            Value::F16(_) => FormatKind::F16,
            Value::BF16(_) => FormatKind::BF16,
            Value::F32(_) => FormatKind::F32,
            Value::F64(_) => FormatKind::F64,
        }
    }

    /// Raw bit pattern, widened to the universal `u64` plane word.
    pub fn bits(self) -> u64 {
        match self {
            Value::F16(b) | Value::BF16(b) => b as u64,
            Value::F32(v) => v.to_bits() as u64,
            Value::F64(v) => v.to_bits(),
        }
    }

    /// Rebuild from a plane word (the executor's output path).
    pub fn from_bits(kind: FormatKind, bits: u64) -> Self {
        match kind {
            FormatKind::F16 => Value::F16(bits as u16),
            FormatKind::BF16 => Value::BF16(bits as u16),
            FormatKind::F32 => Value::F32(f32::from_bits(bits as u32)),
            FormatKind::F64 => Value::F64(f64::from_bits(bits)),
        }
    }

    /// Encode an f64 into the format with a single round-to-nearest-even
    /// (specials map across; overflow saturates to infinity).
    pub fn from_f64(kind: FormatKind, x: f64) -> Self {
        fn encode<F: FloatFormat>(x: f64) -> u64 {
            let bits = x.to_bits();
            match classify::<F64>(bits) {
                FpClass::Nan => F::QNAN,
                FpClass::Inf => inf_bits::<F>(sign_bit::<F64>(bits)),
                FpClass::Zero => zero_bits::<F>(sign_bit::<F64>(bits)),
                FpClass::Finite => {
                    let u = unpack::<F64>(bits, F64::MANT_BITS);
                    pack::<F>(u.sign, u.exp, &u.mant)
                }
            }
        }
        match kind {
            FormatKind::F16 => Value::F16(encode::<F16>(x) as u16),
            FormatKind::BF16 => Value::BF16(encode::<BF16>(x) as u16),
            FormatKind::F32 => Value::F32(x as f32),
            FormatKind::F64 => Value::F64(x),
        }
    }

    /// Exact decode to f64 (every supported format embeds losslessly).
    pub fn to_f64(self) -> f64 {
        fn decode<F: FloatFormat>(bits: u64) -> f64 {
            match classify::<F>(bits) {
                FpClass::Nan => f64::NAN,
                FpClass::Inf => {
                    if sign_bit::<F>(bits) { f64::NEG_INFINITY } else { f64::INFINITY }
                }
                FpClass::Zero => {
                    if sign_bit::<F>(bits) { -0.0 } else { 0.0 }
                }
                FpClass::Finite => {
                    let u = unpack::<F>(bits, F::MANT_BITS);
                    // mant has <= 53 significant bits: exact in f64
                    let m = u.mant.to_f64() * 2f64.powi(u.exp);
                    if u.sign { -m } else { m }
                }
            }
        }
        match self {
            Value::F16(b) => decode::<F16>(b as u64),
            Value::BF16(b) => decode::<BF16>(b as u64),
            Value::F32(v) => v as f64,
            Value::F64(v) => v,
        }
    }

    /// Convenience view as f32 (exact for F32, rounded otherwise).
    pub fn f32(self) -> f32 {
        match self {
            Value::F32(v) => v,
            other => other.to_f64() as f32,
        }
    }

    /// True for a NaN of any format.
    pub fn is_nan(self) -> bool {
        match self {
            Value::F16(b) => classify::<F16>(b as u64) == FpClass::Nan,
            Value::BF16(b) => classify::<BF16>(b as u64) == FpClass::Nan,
            Value::F32(v) => v.is_nan(),
            Value::F64(v) => v.is_nan(),
        }
    }

    /// `1.0` in the given format.
    pub fn one(kind: FormatKind) -> Self {
        Value::from_bits(kind, kind.one_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn geometry_constants() {
        assert_eq!(F16::BIAS, 15);
        assert_eq!(BF16::BIAS, 127);
        assert_eq!(F32::BIAS, 127);
        assert_eq!(F64::BIAS, 1023);
        assert_eq!(F32::INF, 0x7F80_0000);
        assert_eq!(F32::QNAN, f32::NAN.to_bits() as u64);
        assert_eq!(F64::QNAN, f64::NAN.to_bits());
        assert_eq!(F16::INF, 0x7C00);
        assert_eq!(F16::QNAN, 0x7E00);
        assert_eq!(BF16::INF, 0x7F80);
        assert_eq!(FormatKind::F16.one_bits(), 0x3C00);
        assert_eq!(FormatKind::BF16.one_bits(), 0x3F80);
        assert_eq!(FormatKind::F32.one_bits(), 1.0f32.to_bits() as u64);
        assert_eq!(FormatKind::F64.one_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn classify_matches_std_f32() {
        for bits in [0u32, 0x8000_0000, 1, 0x7F80_0000, 0xFF80_0000, 0x7FC0_0001, 0x3F80_0000] {
            let x = f32::from_bits(bits);
            let want = if x.is_nan() {
                FpClass::Nan
            } else if x.is_infinite() {
                FpClass::Inf
            } else if x == 0.0 {
                FpClass::Zero
            } else {
                FpClass::Finite
            };
            assert_eq!(classify::<F32>(bits as u64), want, "bits {bits:#x}");
        }
    }

    #[test]
    fn pack_unpack_roundtrip_every_format() {
        fn roundtrip<F: FloatFormat>(g: &mut crate::check::Gen) -> Result<(), String> {
            let bits = g.bits() & (F::SIGN_MASK | F::INF | F::MANT_MASK);
            if classify::<F>(bits) != FpClass::Finite {
                return Ok(());
            }
            let frac = F::MANT_BITS + 6;
            let u = unpack::<F>(bits, frac);
            let back = pack::<F>(u.sign, u.exp, &u.mant);
            ensure(back == bits, format!("{}: {bits:#x} -> {back:#x}", F::KIND))
        }
        check::property("pack(unpack(x)) == x for all formats", |g| {
            roundtrip::<F16>(g)?;
            roundtrip::<BF16>(g)?;
            roundtrip::<F32>(g)?;
            roundtrip::<F64>(g)
        });
    }

    #[test]
    fn unpack_normalizes_subnormals() {
        // smallest f16 subnormal: 2^-24
        let u = unpack::<F16>(0x0001, 20);
        assert_eq!(u.exp, -24);
        assert_eq!(u.mant.bits(), 1u64 << 20);
        // 3 * 2^-24 = 1.5 * 2^-23
        let u = unpack::<F16>(0x0003, 20);
        assert_eq!(u.exp, -23);
        assert!((u.mant.to_f64() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn pack_overflow_and_underflow() {
        let m = Fixed::from_f64(1.5, 20);
        assert_eq!(pack::<F16>(false, 100, &m), F16::INF);
        assert_eq!(pack::<F16>(true, 100, &m), F16::INF | F16::SIGN_MASK);
        assert_eq!(pack::<F16>(false, -100, &m), 0);
        assert_eq!(pack::<F16>(true, -100, &m), F16::SIGN_MASK);
        // f16 max finite is 65504 = 1.9990234375 * 2^15
        let v = Value::from_f64(FormatKind::F16, 65504.0);
        assert_eq!(v.to_f64(), 65504.0);
        // halfway above max rounds to infinity
        let v = Value::from_f64(FormatKind::F16, 65536.0);
        assert_eq!(v.bits(), F16::INF);
    }

    #[test]
    fn pack_subnormal_rne() {
        // value exactly half an f16-subnormal ulp above zero rounds to
        // even (zero); just above rounds up to the minimum subnormal
        let half_ulp = Fixed::from_f64(1.0, 30); // 1.0 * 2^-25 below
        assert_eq!(pack::<F16>(false, -25, &half_ulp), 0x0000);
        let above = Fixed::from_bits((1u64 << 30) + 1, 30);
        assert_eq!(pack::<F16>(false, -25, &above), 0x0001);
        // 1.5 * 2^-24 is halfway between subnormals 1 and 2: ties to even
        let m = Fixed::from_f64(1.5, 30);
        assert_eq!(pack::<F16>(false, -24, &m), 0x0002);
    }

    #[test]
    fn value_encode_decode_known_points() {
        assert_eq!(Value::from_f64(FormatKind::F16, 1.5).bits(), 0x3E00);
        assert_eq!(Value::from_f64(FormatKind::BF16, 1.5).bits(), 0x3FC0);
        assert_eq!(Value::from_f64(FormatKind::F16, -2.0).bits(), 0xC000);
        assert_eq!(Value::from_f64(FormatKind::F16, 1.5).to_f64(), 1.5);
        assert!(Value::from_f64(FormatKind::BF16, f64::NAN).is_nan());
        assert_eq!(Value::from_f64(FormatKind::F16, f64::INFINITY).bits(), 0x7C00);
        assert_eq!(Value::one(FormatKind::BF16).to_f64(), 1.0);
        assert_eq!(Value::from_f64(FormatKind::F32, 0.1).f32(), 0.1f32);
    }

    #[test]
    fn bf16_encode_matches_f32_truncation_rounding() {
        // bf16 is the top 16 bits of f32 with RNE: check across a sweep
        let mut x = 0.001f64;
        while x < 1e4 {
            let f = x as f32;
            let bits = f.to_bits();
            // RNE on the low 16 bits of the f32 pattern
            let keep = bits >> 16;
            let rem = bits & 0xFFFF;
            let up = rem > 0x8000 || (rem == 0x8000 && keep & 1 == 1);
            let want = keep + up as u32;
            // only valid when f32 itself is exact enough not to double-round:
            // compare through the f32 value, which the sweep keeps finite
            let got = Value::from_f64(FormatKind::BF16, f as f64).bits();
            assert_eq!(got, want as u64, "x={x}");
            x *= 3.7;
        }
    }

    #[test]
    fn divide_via_bits_specials_match_ieee() {
        // pin the special arms against Rust's native (IEEE 754) division,
        // signed zeros and infinities included
        let core = |n: Fixed, d: Fixed| {
            let q = n.to_f64() / d.to_f64();
            Fixed::from_f64(q, n.frac())
        };
        let cases: [(f32, f32); 12] = [
            (f32::NAN, 1.0),
            (1.0, f32::NAN),
            (f32::INFINITY, f32::INFINITY),
            (0.0, 0.0),
            (f32::INFINITY, -2.0),
            (3.0, f32::INFINITY),
            (0.0, 5.0),
            (-1.0, 0.0),
            (1.0, -0.0),
            (f32::INFINITY, -0.0),
            (-0.0, f32::INFINITY),
            (f32::NEG_INFINITY, 0.0),
        ];
        for (n, d) in cases {
            let got = divide_via_bits::<F32, _>(n.to_bits() as u64, d.to_bits() as u64, 30, core);
            let native = n / d;
            if native.is_nan() {
                // hardware NaN payloads vary; require a NaN of some kind
                assert_eq!(classify::<F32>(got), FpClass::Nan, "{n} / {d}");
            } else {
                assert_eq!(got as u32, native.to_bits(), "{n} / {d}");
            }
            // and the typed f32 wrapper is the same envelope
            let typed = crate::arith::fp::divide_via(n, d, 30, core);
            assert_eq!(got as u32, typed.to_bits(), "wrapper {n} / {d}");
        }
    }

    #[test]
    fn format_kind_parse_label() {
        for kind in FormatKind::ALL {
            assert_eq!(FormatKind::parse(kind.label()).unwrap(), kind);
        }
        assert!(FormatKind::parse("f128").is_err());
        assert_eq!(FormatKind::parse("double").unwrap(), FormatKind::F64);
    }

    #[test]
    fn datapath_configs_validate_and_cover_accuracy() {
        for kind in FormatKind::ALL {
            let cfg = kind.datapath_config();
            assert!(cfg.validate().is_ok(), "{kind}");
            // frac must hold the mantissa (plus guard bits)
            assert!(cfg.frac >= kind.mant_bits() + 4, "{kind}");
            // programmed steps at least the analytic bound
            let bound = Config::steps_for_accuracy(cfg.table_p, kind.mant_bits() + 1);
            assert!(cfg.steps >= bound, "{kind}: {} < {bound}", cfg.steps);
        }
    }

    #[test]
    fn plane_words_agree_with_plane_width() {
        // the compile-time Plane type and the runtime width tag must
        // describe the same geometry, or the executor's width dispatch
        // would hand kernels the wrong planes
        fn bits_of<F: FloatFormat>() -> u32 {
            <F::Plane as PlaneWord>::BITS
        }
        assert_eq!(bits_of::<F16>(), 32);
        assert_eq!(bits_of::<BF16>(), 32);
        assert_eq!(bits_of::<F32>(), 64);
        assert_eq!(bits_of::<F64>(), 64);
        for kind in FormatKind::ALL {
            let width_bits = kind.plane_width().lane_bytes() as u32 * 8;
            let type_bits = match kind {
                FormatKind::F16 => bits_of::<F16>(),
                FormatKind::BF16 => bits_of::<BF16>(),
                FormatKind::F32 => bits_of::<F32>(),
                FormatKind::F64 => bits_of::<F64>(),
            };
            assert_eq!(width_bits, type_bits, "{kind}");
            // every plane word holds the format's container and its
            // Q2.frac datapath word
            assert!(kind.total_bits() <= type_bits, "{kind}");
            assert!(kind.datapath_config().frac + 2 <= type_bits, "{kind}");
        }
    }

    #[test]
    fn bf16_rom_is_right_sized() {
        // the per-format ROM sizing item: bf16 runs a p=5 seed table
        // (32 entries vs 1024) and still clears its accuracy bound
        let cfg = FormatKind::BF16.datapath_config();
        assert_eq!(cfg.table_p, 5);
        assert_eq!(1u64 << cfg.table_p, 32);
        // the seed error squared through the programmed steps lands far
        // below bf16's half-ulp (2^-9)
        assert!(cfg.predicted_error() < 2f64.powi(-9) / 4.0, "{}", cfg.predicted_error());
        // every other format keeps the paper's p=10 table
        for kind in [FormatKind::F16, FormatKind::F32, FormatKind::F64] {
            assert_eq!(kind.datapath_config().table_p, 10, "{kind}");
        }
    }
}
