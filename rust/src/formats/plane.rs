//! Width-true operand/result planes: the storage unit the coordinator
//! queues, the batcher pads, and the executor contract moves.
//!
//! A plane used to be a `Vec<u64>` regardless of format, so every
//! f16/bf16 lane wasted 48 bits of storage and memory bandwidth on the
//! flush path. [`PlaneBuf`] is the runtime-tagged replacement: a `u32`
//! vector for half-precision formats, a `u64` vector for f32/f64 (see
//! [`FormatKind::plane_width`]) — halving half-precision plane traffic
//! through the router, the batcher's pad path and the executor, while
//! the kernels consume the planes directly at their native width via
//! [`PlaneRef`].
//!
//! The widening/narrowing boundary lives at the edges (client `u64`
//! words in, ticket `u64` words out); everything between runs
//! width-true.

use crate::formats::FormatKind;

/// The storage width of one plane word.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlaneWidth {
    /// 32-bit plane words (f16 / bf16 lanes).
    W32,
    /// 64-bit plane words (f32 / f64 lanes).
    W64,
}

impl PlaneWidth {
    /// Bytes per lane at this width.
    pub fn lane_bytes(self) -> usize {
        match self {
            PlaneWidth::W32 => 4,
            PlaneWidth::W64 => 8,
        }
    }

    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            PlaneWidth::W32 => "u32",
            PlaneWidth::W64 => "u64",
        }
    }
}

/// An owned width-true plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlaneBuf {
    /// 32-bit lanes.
    W32(Vec<u32>),
    /// 64-bit lanes.
    W64(Vec<u64>),
}

impl Default for PlaneBuf {
    /// An empty 64-bit plane (the universal-word default).
    fn default() -> Self {
        PlaneBuf::W64(Vec::new())
    }
}

impl PlaneBuf {
    /// Empty plane of the given width.
    pub fn new(width: PlaneWidth) -> Self {
        match width {
            PlaneWidth::W32 => PlaneBuf::W32(Vec::new()),
            PlaneWidth::W64 => PlaneBuf::W64(Vec::new()),
        }
    }

    /// Empty plane at a format's native width.
    pub fn for_format(format: FormatKind) -> Self {
        Self::new(format.plane_width())
    }

    /// Build a width-true plane from universal `u64` words (the client
    /// submission boundary). Words must fit the target width — raw
    /// half-precision containers always do.
    pub fn from_u64_slice(width: PlaneWidth, words: &[u64]) -> Self {
        let mut plane = Self::new(width);
        plane.extend_from_u64(words);
        plane
    }

    /// This plane's word width.
    pub fn width(&self) -> PlaneWidth {
        match self {
            PlaneBuf::W32(_) => PlaneWidth::W32,
            PlaneBuf::W64(_) => PlaneWidth::W64,
        }
    }

    /// Lane count.
    pub fn len(&self) -> usize {
        match self {
            PlaneBuf::W32(v) => v.len(),
            PlaneBuf::W64(v) => v.len(),
        }
    }

    /// True when no lanes are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Retained lane capacity.
    pub fn capacity(&self) -> usize {
        match self {
            PlaneBuf::W32(v) => v.capacity(),
            PlaneBuf::W64(v) => v.capacity(),
        }
    }

    /// Heap bytes currently reserved (the memory-traffic accounting the
    /// width-true representation halves for half-precision).
    pub fn heap_bytes(&self) -> usize {
        self.capacity() * self.width().lane_bytes()
    }

    /// Drop all lanes, keeping capacity.
    pub fn clear(&mut self) {
        match self {
            PlaneBuf::W32(v) => v.clear(),
            PlaneBuf::W64(v) => v.clear(),
        }
    }

    /// Reserve room for `additional` more lanes.
    pub fn reserve(&mut self, additional: usize) {
        match self {
            PlaneBuf::W32(v) => v.reserve(additional),
            PlaneBuf::W64(v) => v.reserve(additional),
        }
    }

    /// Append one lane given as a universal `u64` word (must fit).
    pub fn push(&mut self, word: u64) {
        match self {
            PlaneBuf::W32(v) => {
                debug_assert!(word <= u32::MAX as u64, "{word:#x} overflows a u32 lane");
                v.push(word as u32);
            }
            PlaneBuf::W64(v) => v.push(word),
        }
    }

    /// Resize to `lanes`, filling new lanes with `word`.
    pub fn resize(&mut self, lanes: usize, word: u64) {
        match self {
            PlaneBuf::W32(v) => {
                debug_assert!(word <= u32::MAX as u64);
                v.resize(lanes, word as u32);
            }
            PlaneBuf::W64(v) => v.resize(lanes, word),
        }
    }

    /// One lane widened to `u64`.
    pub fn get(&self, lane: usize) -> u64 {
        match self {
            PlaneBuf::W32(v) => v[lane] as u64,
            PlaneBuf::W64(v) => v[lane],
        }
    }

    /// Append universal `u64` words (narrowing for 32-bit planes).
    /// Panics on a word that does not fit a 32-bit lane — this is the
    /// untrusted narrowing boundary (vectored group construction), so
    /// the check is unconditional: silent truncation here would turn a
    /// bad submission into a wrong answer. (The service rejects such
    /// words with a typed error before reaching this point; the panic
    /// guards direct `WorkItem::group` callers.)
    pub fn extend_from_u64(&mut self, words: &[u64]) {
        match self {
            PlaneBuf::W32(v) => {
                v.reserve(words.len());
                for &w in words {
                    assert!(w <= u32::MAX as u64, "{w:#x} overflows a u32 lane");
                    v.push(w as u32);
                }
            }
            PlaneBuf::W64(v) => v.extend_from_slice(words),
        }
    }

    /// Append a window of another plane. Same-width copies are straight
    /// `memcpy`s (the hot path — both sides derive their width from the
    /// same format); mixed widths convert per lane.
    pub fn extend_window(&mut self, src: &PlaneBuf, start: usize, len: usize) {
        match (self, src) {
            (PlaneBuf::W32(dst), PlaneBuf::W32(s)) => dst.extend_from_slice(&s[start..start + len]),
            (PlaneBuf::W64(dst), PlaneBuf::W64(s)) => dst.extend_from_slice(&s[start..start + len]),
            (dst, src) => {
                dst.reserve(len);
                for lane in start..start + len {
                    dst.push(src.get(lane));
                }
            }
        }
    }

    /// Widen a window into a `u64` buffer (the ticket-completion
    /// boundary; the result plane stays width-true, only the per-client
    /// copy widens).
    pub fn widen_into(&self, out: &mut Vec<u64>) {
        out.clear();
        match self {
            PlaneBuf::W32(v) => out.extend(v.iter().map(|&w| w as u64)),
            PlaneBuf::W64(v) => out.extend_from_slice(v),
        }
    }

    /// Borrowed view.
    pub fn as_ref(&self) -> PlaneRef<'_> {
        match self {
            PlaneBuf::W32(v) => PlaneRef::W32(v),
            PlaneBuf::W64(v) => PlaneRef::W64(v),
        }
    }

    /// Mutable borrowed view.
    pub fn as_mut(&mut self) -> PlaneRefMut<'_> {
        match self {
            PlaneBuf::W32(v) => PlaneRefMut::W32(v),
            PlaneBuf::W64(v) => PlaneRefMut::W64(v),
        }
    }
}

/// A borrowed width-true plane (the executor-contract operand view).
#[derive(Clone, Copy, Debug)]
pub enum PlaneRef<'a> {
    /// 32-bit lanes.
    W32(&'a [u32]),
    /// 64-bit lanes.
    W64(&'a [u64]),
}

impl<'a> PlaneRef<'a> {
    /// Word width.
    pub fn width(&self) -> PlaneWidth {
        match *self {
            PlaneRef::W32(_) => PlaneWidth::W32,
            PlaneRef::W64(_) => PlaneWidth::W64,
        }
    }

    /// Lane count.
    pub fn len(&self) -> usize {
        match *self {
            PlaneRef::W32(v) => v.len(),
            PlaneRef::W64(v) => v.len(),
        }
    }

    /// True when no lanes are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One lane widened to `u64`.
    pub fn get(&self, lane: usize) -> u64 {
        match *self {
            PlaneRef::W32(v) => v[lane] as u64,
            PlaneRef::W64(v) => v[lane],
        }
    }

    /// The 32-bit lanes, if this is a 32-bit plane.
    pub fn as_w32(&self) -> Option<&'a [u32]> {
        match *self {
            PlaneRef::W32(v) => Some(v),
            PlaneRef::W64(_) => None,
        }
    }

    /// The 64-bit lanes, if this is a 64-bit plane.
    pub fn as_w64(&self) -> Option<&'a [u64]> {
        match *self {
            PlaneRef::W64(v) => Some(v),
            PlaneRef::W32(_) => None,
        }
    }
}

/// A mutable borrowed width-true plane (the executor-contract output
/// view).
#[derive(Debug)]
pub enum PlaneRefMut<'a> {
    /// 32-bit lanes.
    W32(&'a mut [u32]),
    /// 64-bit lanes.
    W64(&'a mut [u64]),
}

impl PlaneRefMut<'_> {
    /// Word width.
    pub fn width(&self) -> PlaneWidth {
        match self {
            PlaneRefMut::W32(_) => PlaneWidth::W32,
            PlaneRefMut::W64(_) => PlaneWidth::W64,
        }
    }

    /// Lane count.
    pub fn len(&self) -> usize {
        match self {
            PlaneRefMut::W32(v) => v.len(),
            PlaneRefMut::W64(v) => v.len(),
        }
    }

    /// True when no lanes are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reborrow (so callers can pass the view on without consuming it).
    pub fn reborrow(&mut self) -> PlaneRefMut<'_> {
        match self {
            PlaneRefMut::W32(v) => PlaneRefMut::W32(&mut **v),
            PlaneRefMut::W64(v) => PlaneRefMut::W64(&mut **v),
        }
    }

    /// The 32-bit lanes, if this is a 32-bit plane.
    pub fn as_w32(&mut self) -> Option<&mut [u32]> {
        match self {
            PlaneRefMut::W32(v) => Some(&mut **v),
            PlaneRefMut::W64(_) => None,
        }
    }

    /// The 64-bit lanes, if this is a 64-bit plane.
    pub fn as_w64(&mut self) -> Option<&mut [u64]> {
        match self {
            PlaneRefMut::W64(v) => Some(&mut **v),
            PlaneRefMut::W32(_) => None,
        }
    }
}

/// Width-true slice extraction from the runtime plane views, per plane
/// word: lets executor code stay generic over a format's `Plane` type
/// instead of duplicating a match arm per width. Returns `None` when
/// the view carries the other width (a contract violation the caller
/// reports as a typed error).
pub trait PlaneExtract: Sized {
    /// The native slice behind a borrowed plane, if the width matches.
    fn from_ref(plane: PlaneRef<'_>) -> Option<&[Self]>;

    /// The native mutable slice behind an output plane, if the width
    /// matches.
    fn from_mut<'a>(plane: &'a mut PlaneRefMut<'_>) -> Option<&'a mut [Self]>;
}

impl PlaneExtract for u32 {
    fn from_ref(plane: PlaneRef<'_>) -> Option<&[Self]> {
        plane.as_w32()
    }

    fn from_mut<'a>(plane: &'a mut PlaneRefMut<'_>) -> Option<&'a mut [Self]> {
        plane.as_w32()
    }
}

impl PlaneExtract for u64 {
    fn from_ref(plane: PlaneRef<'_>) -> Option<&[Self]> {
        plane.as_w64()
    }

    fn from_mut<'a>(plane: &'a mut PlaneRefMut<'_>) -> Option<&'a mut [Self]> {
        plane.as_w64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_widths_are_width_true() {
        assert_eq!(FormatKind::F16.plane_width(), PlaneWidth::W32);
        assert_eq!(FormatKind::BF16.plane_width(), PlaneWidth::W32);
        assert_eq!(FormatKind::F32.plane_width(), PlaneWidth::W64);
        assert_eq!(FormatKind::F64.plane_width(), PlaneWidth::W64);
        assert_eq!(PlaneWidth::W32.lane_bytes(), 4);
        assert_eq!(PlaneWidth::W64.lane_bytes(), 8);
    }

    #[test]
    fn half_precision_planes_halve_memory() {
        let mut half = PlaneBuf::for_format(FormatKind::F16);
        let mut full = PlaneBuf::for_format(FormatKind::F32);
        half.resize(1024, 0x3C00);
        full.resize(1024, 0x3F80_0000);
        assert!(half.heap_bytes() * 2 <= full.heap_bytes());
        assert_eq!(half.width().label(), "u32");
    }

    #[test]
    fn push_get_roundtrip_both_widths() {
        for width in [PlaneWidth::W32, PlaneWidth::W64] {
            let mut p = PlaneBuf::new(width);
            for w in [0u64, 1, 0x3C00, 0xFFFF] {
                p.push(w);
            }
            assert_eq!(p.len(), 4);
            assert_eq!(p.get(2), 0x3C00);
            assert_eq!(p.as_ref().get(3), 0xFFFF);
            p.clear();
            assert!(p.is_empty());
            assert!(p.capacity() >= 4, "clear keeps capacity");
        }
        // 64-bit planes carry full-width words
        let mut p = PlaneBuf::new(PlaneWidth::W64);
        p.push(u64::MAX);
        assert_eq!(p.get(0), u64::MAX);
    }

    #[test]
    fn extend_window_same_and_cross_width() {
        let src = PlaneBuf::from_u64_slice(PlaneWidth::W32, &[1, 2, 3, 4, 5]);
        let mut same = PlaneBuf::new(PlaneWidth::W32);
        same.extend_window(&src, 1, 3);
        assert_eq!(same, PlaneBuf::W32(vec![2, 3, 4]));
        // cross-width falls back to per-lane conversion
        let mut wide = PlaneBuf::new(PlaneWidth::W64);
        wide.extend_window(&src, 0, 2);
        assert_eq!(wide, PlaneBuf::W64(vec![1, 2]));
    }

    #[test]
    fn widen_into_reuses_buffer() {
        let p = PlaneBuf::from_u64_slice(PlaneWidth::W32, &[7, 8, 9]);
        let mut out = vec![99u64; 64];
        p.widen_into(&mut out);
        assert_eq!(out, vec![7, 8, 9]);
    }

    #[test]
    fn ref_views_expose_native_slices() {
        let mut p = PlaneBuf::from_u64_slice(PlaneWidth::W32, &[10, 20]);
        assert_eq!(p.as_ref().as_w32(), Some(&[10u32, 20][..]));
        assert!(p.as_ref().as_w64().is_none());
        assert_eq!(p.as_mut().as_w32().unwrap().len(), 2);
        let mut q = PlaneBuf::from_u64_slice(PlaneWidth::W64, &[10, 20]);
        assert_eq!(q.as_ref().as_w64(), Some(&[10u64, 20][..]));
        assert!(q.as_mut().as_w32().is_none());
        let mut m = q.as_mut();
        assert_eq!(m.reborrow().len(), 2);
        assert_eq!(m.width(), PlaneWidth::W64);
        assert!(!m.is_empty());
    }
}
