//! Baseline division algorithms the paper's introduction positions
//! Goldschmidt against (Oberman–Flynn's taxonomy, refs [2][3]):
//!
//! * **Digit recurrence** — [`restoring`], [`nonrestoring`], and
//!   [`srt4`] (radix-4 SRT with quotient digit selection): one quotient
//!   digit per cycle, linear convergence.
//! * **Functional iteration** — [`newton`] (Newton–Raphson reciprocal,
//!   self-correcting, two dependent multiplies per step) versus
//!   Goldschmidt (two *independent* multiplies per step — the property
//!   the paper's pipelined/feedback schedules exploit).
//!
//! Each routine reports its cycle cost under the same accounting used by
//! [`crate::sim`] so `benches/baseline_comparison.rs` can regenerate the
//! intro's comparison as a table.

pub mod newton;
pub mod recurrence;
pub mod srt4;

pub use newton::newton_divide;
pub use recurrence::{nonrestoring_divide, restoring_divide};
pub use srt4::srt4_divide;

/// Result of a baseline division: quotient mantissa plus cost metadata.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    /// Quotient approximation (same fixed-point format as the input).
    pub quotient: crate::arith::Fixed,
    /// Cycle count under the crate's unified accounting
    /// (multiplier pass = 4 cycles, table lookup = 1, adder/CPA = 1/bit-row).
    pub cycles: u64,
    /// Number of multiplier passes issued (0 for digit recurrence).
    pub mult_passes: u32,
}
