//! Restoring and non-restoring binary division: the simplest digit
//! recurrence baselines (one quotient bit per cycle).
//!
//! Operands are mantissas in `[1, 2)` as [`Fixed`]; the quotient is
//! produced to the full datapath fraction width, so a divide costs
//! `frac + 1` cycles (one per quotient bit; the leading-zero alignment
//! is free in hardware) — the linear-convergence cost the iterative
//! methods beat.

use crate::arith::fixed::Fixed;

use super::BaselineResult;

/// Restoring division: shift in a dividend bit, trial-subtract, keep or
/// restore. Computes the exact floor quotient `q = floor(n/d * 2^frac)`.
pub fn restoring_divide(n: &Fixed, d: &Fixed) -> BaselineResult {
    assert_eq!(n.frac(), d.frac());
    let frac = n.frac();
    let nn: u128 = (n.bits() as u128) << frac; // dividend, 2*frac+2 bits
    let dd: u128 = d.bits() as u128;
    let width = 2 * frac + 2;
    let mut rem: u128 = 0;
    let mut q: u128 = 0;
    for i in (0..width).rev() {
        rem = (rem << 1) | ((nn >> i) & 1);
        q <<= 1;
        if rem >= dd {
            rem -= dd; // subtract held: quotient bit 1
            q |= 1;
        } // else: restore (the trial subtract is not committed)
    }
    BaselineResult {
        quotient: Fixed::from_bits(q as u64, frac),
        // hardware cycles: one per *quotient* bit (1 integer + frac
        // fraction); the leading zero bits are alignment, not cycles
        cycles: frac as u64 + 1,
        mult_passes: 0,
    }
}

/// Non-restoring division: add-or-subtract every cycle (no restore
/// bubble). The remainder register is allowed to go negative; each cycle
/// adds or subtracts the divisor depending on the remainder's sign, and
/// the quotient bit is the resulting sign. Produces the same floor
/// quotient as [`restoring_divide`] (asserted by property test) with a
/// simpler per-cycle critical path.
pub fn nonrestoring_divide(n: &Fixed, d: &Fixed) -> BaselineResult {
    assert_eq!(n.frac(), d.frac());
    let frac = n.frac();
    let nn: i128 = (n.bits() as i128) << frac;
    let dd: i128 = d.bits() as i128;
    let width = 2 * frac + 2;
    let mut rem: i128 = 0;
    let mut q: u128 = 0;
    for i in (0..width).rev() {
        let bit = (nn >> i) & 1;
        rem = (rem << 1) + bit;
        if rem >= 0 {
            rem -= dd;
        } else {
            rem += dd;
        }
        q <<= 1;
        if rem >= 0 {
            q |= 1;
        }
    }
    // final restore is not needed for the quotient: the 0-bits already
    // recorded the overshoot cycles
    BaselineResult {
        quotient: Fixed::from_bits(q as u64, frac),
        cycles: frac as u64 + 1,
        mult_passes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::ulp::rel_err;
    use crate::check::{self, ensure};
    use crate::util::rng::Xoshiro256;

    const FRAC: u32 = 30;

    #[test]
    fn restoring_exact_cases() {
        let n = Fixed::from_f64(1.5, FRAC);
        let d = Fixed::from_f64(1.5, FRAC);
        let r = restoring_divide(&n, &d);
        assert!((r.quotient.to_f64() - 1.0).abs() < 1e-9);
        assert_eq!(r.cycles, FRAC as u64 + 1);
        assert_eq!(r.mult_passes, 0);
    }

    #[test]
    fn restoring_is_exact_floor_property() {
        check::property("restoring == floor division", |g| {
            let n = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let d = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let q = restoring_divide(&n, &d).quotient.bits() as u128;
            let want = ((n.bits() as u128) << FRAC) / d.bits() as u128;
            ensure(q == want, format!("n={} d={}", n.to_f64(), d.to_f64()))
        });
    }

    #[test]
    fn restoring_random_sweep() {
        let mut rng = Xoshiro256::new(41);
        for _ in 0..1000 {
            let nf = rng.range_f64(1.0, 2.0);
            let df = rng.range_f64(1.0, 2.0);
            let r = restoring_divide(&Fixed::from_f64(nf, FRAC), &Fixed::from_f64(df, FRAC));
            let err = rel_err(r.quotient.to_f64(), nf / df);
            assert!(err < 4.0 * 2f64.powi(-(FRAC as i32)), "{nf}/{df}: {err}");
        }
    }

    #[test]
    fn nonrestoring_matches_restoring_property() {
        check::property("nonrestoring == restoring", |g| {
            let n = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let d = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let a = restoring_divide(&n, &d).quotient.bits();
            let b = nonrestoring_divide(&n, &d).quotient.bits();
            ensure(
                a == b,
                format!("n={} d={} a={a:#x} b={b:#x}", n.to_f64(), d.to_f64()),
            )
        });
    }

    #[test]
    fn quotient_is_floor_accurate() {
        check::property("restoring is floor-accurate", |g| {
            let n = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let d = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let q = restoring_divide(&n, &d).quotient.to_f64();
            let exact = n.to_f64() / d.to_f64();
            ensure(
                q <= exact + 1e-15 && exact - q < 2.0 * 2f64.powi(-(FRAC as i32)),
                format!("q={q} exact={exact}"),
            )
        });
    }

    #[test]
    fn linear_cost_scales_with_width() {
        let n20 = Fixed::from_f64(1.9, 20);
        let d20 = Fixed::from_f64(1.1, 20);
        let n40 = Fixed::from_f64(1.9, 40);
        let d40 = Fixed::from_f64(1.1, 40);
        assert_eq!(restoring_divide(&n20, &d20).cycles, 21);
        assert_eq!(restoring_divide(&n40, &d40).cycles, 41);
        assert_eq!(nonrestoring_divide(&n40, &d40).cycles, 41);
    }

    #[test]
    fn edge_operands() {
        // n = d -> q = 1 exactly; n just below 2, d = 1 -> q = n
        let one = Fixed::one(FRAC);
        let r = restoring_divide(&one, &one);
        assert_eq!(r.quotient.bits(), one.bits());
        let nmax = Fixed::from_bits((1u64 << (FRAC + 1)) - 1, FRAC);
        let r = restoring_divide(&nmax, &one);
        assert_eq!(r.quotient.bits(), nmax.bits());
    }
}
