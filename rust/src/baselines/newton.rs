//! Newton–Raphson reciprocal division: the classic functional-iteration
//! baseline.
//!
//! `x_{i+1} = x_i * (2 - d * x_i)` converges quadratically to `1/d`;
//! the quotient is `q = n * x_final`. Each step needs **two dependent
//! multiplications** (`d*x_i`, then `x_i * (...)`), unlike Goldschmidt's
//! two independent ones — which is exactly why Goldschmidt pipelines
//! better and why the paper's feedback trick targets it.

use crate::arith::fixed::Fixed;
use crate::arith::twos::ComplementBlock;
use crate::tables::ReciprocalTable;

use super::BaselineResult;
use crate::goldschmidt::Config;

/// Newton–Raphson division on mantissas `n, d in [1, 2)`.
///
/// Uses the same ROM, complement block and rounding as the Goldschmidt
/// datapath so the comparison isolates the *algorithm*, not the
/// substrate. `cfg.steps` refinement steps.
pub fn newton_divide(
    n: &Fixed,
    d: &Fixed,
    table: &ReciprocalTable,
    cfg: &Config,
) -> BaselineResult {
    assert_eq!(n.frac(), cfg.frac);
    assert_eq!(d.frac(), cfg.frac);
    let complement = ComplementBlock::new(cfg.frac, cfg.complement);

    let mut cycles = 1u64; // ROM lookup
    let mut passes = 0u32;
    let mut x = table.lookup(d); // x0 ~= 1/d

    for _ in 0..cfg.steps {
        let dx = d.mul(&x, cfg.rounding); // multiplier pass 1
        let corr = complement.apply(&dx); // 2 - d*x (combinational)
        x = x.mul(&corr, cfg.rounding); // multiplier pass 2 (dependent!)
        passes += 2;
        cycles += 2 * 4; // two *serial* 4-cycle multiplies per step
    }
    let q = n.mul(&x, cfg.rounding); // final quotient multiply
    passes += 1;
    cycles += 4;
    BaselineResult { quotient: q, cycles, mult_passes: passes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::ulp::rel_err;
    use crate::check::{self, ensure};
    use crate::util::rng::Xoshiro256;

    fn setup() -> (ReciprocalTable, Config) {
        let cfg = Config::default();
        (ReciprocalTable::new(cfg.table_p), cfg)
    }

    #[test]
    fn converges_to_quotient() {
        let (table, cfg) = setup();
        let mut rng = Xoshiro256::new(31);
        for _ in 0..1000 {
            let nf = rng.range_f64(1.0, 2.0);
            let df = rng.range_f64(1.0, 2.0);
            let n = Fixed::from_f64(nf, cfg.frac);
            let d = Fixed::from_f64(df, cfg.frac);
            let r = newton_divide(&n, &d, &table, &cfg);
            let err = rel_err(r.quotient.to_f64(), nf / df);
            assert!(err < 1e-8, "n={nf} d={df} err={err}");
        }
    }

    #[test]
    fn quadratic_convergence_property() {
        check::property("NR error shrinks quadratically", |g| {
            let cfg = Config::default().with_frac(60);
            let table = ReciprocalTable::new(cfg.table_p);
            let d = Fixed::from_f64(g.f64_in(1.0, 2.0), cfg.frac);
            let n = Fixed::one(cfg.frac);
            let e1 = rel_err(
                newton_divide(&n, &d, &table, &cfg.with_steps(1)).quotient.to_f64(),
                1.0 / d.to_f64(),
            );
            let e2 = rel_err(
                newton_divide(&n, &d, &table, &cfg.with_steps(2)).quotient.to_f64(),
                1.0 / d.to_f64(),
            );
            ensure(e2 <= e1 * e1 * 4.0 + 1e-15, format!("e1={e1} e2={e2}"))
        });
    }

    #[test]
    fn cycle_accounting() {
        let (table, cfg) = setup();
        let one = Fixed::one(cfg.frac);
        let r = newton_divide(&one, &one, &table, &cfg);
        // 1 (ROM) + steps * 8 (two serial multiplies) + 4 (final q)
        assert_eq!(r.cycles, 1 + cfg.steps as u64 * 8 + 4);
        assert_eq!(r.mult_passes, cfg.steps * 2 + 1);
    }

    #[test]
    fn same_substrate_as_goldschmidt() {
        // same table/rounding: step-0 result must equal Goldschmidt q1
        // for n = 1 (both are just K1)
        let (table, cfg0) = setup();
        let cfg = cfg0.with_steps(0);
        let one = Fixed::one(cfg.frac);
        let d = Fixed::from_f64(1.37, cfg.frac);
        let nr = newton_divide(&one, &d, &table, &cfg);
        let gs = crate::goldschmidt::divide_mantissa(&one, &d, &table, &cfg);
        assert_eq!(nr.quotient.bits(), gs.quotient().bits());
    }
}
