//! SRT radix-4 division: the high-performance digit recurrence baseline
//! (Ercegovac–Lang, paper ref [3]). Two quotient bits per cycle with a
//! redundant digit set {-2,-1,0,1,2}.
//!
//! The digit selection here is *behavioral*: `d_j = round(w_j / d)`
//! clamped to the digit set, which is what a P-D selection table
//! implements with truncated operands. The recurrence, digit set, cycle
//! count and final conversion are the real algorithm; only the selection
//! PLA is abstracted (DESIGN.md §4 notes the substitution).

use crate::arith::fixed::Fixed;

use super::BaselineResult;

/// SRT radix-4 division on mantissas `n, d in [1, 2)`.
/// Returns `q ~ n/d` at `frac` fraction bits, `ceil(frac/2)+1` digit
/// cycles plus one terminal-conversion cycle.
pub fn srt4_divide(n: &Fixed, d: &Fixed) -> BaselineResult {
    assert_eq!(n.frac(), d.frac());
    let frac = n.frac();
    let dd: i128 = d.bits() as i128;
    let mut w: i128 = n.bits() as i128; // partial remainder
    let digits = (frac as usize).div_ceil(2) + 1;
    let mut q_acc: i128 = 0; // base-4 accumulated quotient
    for _ in 0..digits {
        // behavioral selection: nearest digit, clamped to {-2..2}
        let digit = nearest_div(w, dd).clamp(-2, 2);
        w = 4 * (w - digit * dd);
        q_acc = 4 * q_acc + digit;
        debug_assert!(w.abs() <= 3 * dd, "remainder escaped bound");
    }
    // first digit carries weight 4^0, so q = q_acc * 4^-(digits-1);
    // rescale to frac fraction bits
    let shift = 2 * (digits as i32 - 1) - frac as i32;
    let q_bits: i128 = if shift > 0 {
        // round-to-nearest on the dropped bits
        (q_acc + (1i128 << (shift - 1))) >> shift
    } else {
        q_acc << (-shift)
    };
    let max = (1i128 << (frac + 2)) - 1;
    BaselineResult {
        quotient: Fixed::from_bits(q_bits.clamp(0, max) as u64, frac),
        cycles: digits as u64 + 1, // + on-the-fly conversion/CPA
        mult_passes: 0,
    }
}

/// Round-to-nearest integer division for signed `a / b`, `b > 0`.
fn nearest_div(a: i128, b: i128) -> i128 {
    debug_assert!(b > 0);
    if a >= 0 {
        (a + b / 2) / b
    } else {
        -((-a + b / 2) / b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::ulp::rel_err;
    use crate::check::{self, ensure};
    use crate::util::rng::Xoshiro256;

    const FRAC: u32 = 30;

    #[test]
    fn basic_quotients() {
        for (nf, df) in [(1.5, 1.5), (1.0, 1.999), (1.999, 1.0), (1.25, 1.75)] {
            let n = Fixed::from_f64(nf, FRAC);
            let d = Fixed::from_f64(df, FRAC);
            let r = srt4_divide(&n, &d);
            let err = rel_err(r.quotient.to_f64(), nf / df);
            assert!(err < 1e-8, "{nf}/{df}: err={err}");
        }
    }

    #[test]
    fn random_sweep_accuracy() {
        let mut rng = Xoshiro256::new(51);
        for _ in 0..1000 {
            let nf = rng.range_f64(1.0, 2.0);
            let df = rng.range_f64(1.0, 2.0);
            let r = srt4_divide(&Fixed::from_f64(nf, FRAC), &Fixed::from_f64(df, FRAC));
            let err = rel_err(r.quotient.to_f64(), nf / df);
            assert!(err < 8.0 * 2f64.powi(-(FRAC as i32)), "{nf}/{df}: {err}");
        }
    }

    #[test]
    fn digit_cycles_are_half_of_bit_serial() {
        let n = Fixed::from_f64(1.3, FRAC);
        let d = Fixed::from_f64(1.7, FRAC);
        let srt = srt4_divide(&n, &d);
        let restoring = super::super::restoring_divide(&n, &d);
        assert!(srt.cycles <= restoring.cycles / 2 + 2,
            "srt {} vs restoring {}", srt.cycles, restoring.cycles);
    }

    #[test]
    fn remainder_stays_bounded_property() {
        // the debug_assert inside the loop enforces the invariant; this
        // property run exercises it across operands
        check::property("srt4 accuracy", |g| {
            let n = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let d = Fixed::from_f64(g.f64_in(1.0, 2.0), FRAC);
            let r = srt4_divide(&n, &d);
            let err = rel_err(r.quotient.to_f64(), n.to_f64() / d.to_f64());
            ensure(err < 8.0 * 2f64.powi(-(FRAC as i32)),
                format!("n={} d={} err={err}", n.to_f64(), d.to_f64()))
        });
    }

    #[test]
    fn wide_datapath() {
        let n = Fixed::from_f64(1.23456789, 50);
        let d = Fixed::from_f64(1.98765432, 50);
        let r = srt4_divide(&n, &d);
        assert!(rel_err(r.quotient.to_f64(), n.to_f64() / d.to_f64()) < 1e-14);
        assert_eq!(r.cycles, 25 + 1 + 1);
    }

    #[test]
    fn nearest_div_signs() {
        assert_eq!(nearest_div(7, 2), 4);
        assert_eq!(nearest_div(-7, 2), -4);
        assert_eq!(nearest_div(6, 4), 2);
        assert_eq!(nearest_div(0, 5), 0);
    }
}
