//! IEEE-754 binary64 pack/unpack: the double-precision FPU boundary.
//!
//! EIMMW-2000 (the paper's foundation) targets double precision; this
//! module provides the f64 wrapper around the same mantissa datapath,
//! which needs `frac >= 56` (52 mantissa bits + guard bits — within the
//! `Fixed` limit of 62).

use super::fixed::Fixed;
use super::fp::FpClass;

/// Classify an f64 for dispatch before the datapath.
pub fn classify64(x: f64) -> FpClass {
    if x.is_nan() {
        FpClass::Nan
    } else if x.is_infinite() {
        FpClass::Inf
    } else if x == 0.0 {
        FpClass::Zero
    } else {
        FpClass::Finite
    }
}

/// A decomposed finite nonzero binary64.
#[derive(Clone, Copy, Debug)]
pub struct Unpacked64 {
    /// Sign bit.
    pub sign: bool,
    /// Unbiased exponent of the leading bit.
    pub exp: i32,
    /// Mantissa in `[1, 2)` at the requested fraction width.
    pub mant: Fixed,
}

/// Unpack a finite nonzero f64 (subnormals normalized), `frac >= 52`.
pub fn unpack64(x: f64, frac: u32) -> Unpacked64 {
    assert!(classify64(x) == FpClass::Finite, "unpack64({x}) on non-finite");
    assert!(frac >= 52, "f64 needs frac >= 52");
    let bits = x.to_bits();
    let sign = (bits >> 63) == 1;
    let biased_exp = ((bits >> 52) & 0x7FF) as i32;
    let raw_mant = bits & 0xF_FFFF_FFFF_FFFF;
    let (exp, mant52) = if biased_exp == 0 {
        // subnormal: value = raw_mant * 2^-1074
        let lz = raw_mant.leading_zeros() - 12; // zeros in the 52-bit field
        let shifted = raw_mant << (lz + 1);
        (-1022 - (lz as i32) - 1, shifted & 0xF_FFFF_FFFF_FFFF)
    } else {
        (biased_exp - 1023, raw_mant)
    };
    let mant = Fixed::from_bits(((1u64 << 52) | mant52) << (frac - 52), frac);
    Unpacked64 { sign, exp, mant }
}

/// Repack with round-to-nearest-even into f64. The mantissa may lie in
/// `[0.5, 4)`; exponent is renormalized; over/underflow saturate per
/// IEEE. Works directly on the fixed-point bits (no f64 detour — a
/// `frac > 52` mantissa would lose bits through a float intermediate).
pub fn pack64(sign: bool, exp: i32, mant: &Fixed) -> f64 {
    let frac = mant.frac();
    let mut bits = mant.bits();
    if bits == 0 {
        return if sign { -0.0 } else { 0.0 };
    }
    // normalize: find the leading one relative to the binary point
    let msb = 63 - bits.leading_zeros() as i32; // bit index of leading 1
    let lead = msb - frac as i32; // 0 => in [1,2)
    let e = exp + lead;
    // target: 52 fraction bits after the leading 1
    let shift = msb - 52;
    let mant53: u64 = if shift > 0 {
        // round-to-nearest-even on the dropped bits
        let dropped = shift as u32;
        let keep = bits >> dropped;
        let half = 1u64 << (dropped - 1);
        let rem = bits & ((1u64 << dropped) - 1);
        let round_up = rem > half || (rem == half && keep & 1 == 1);
        keep + round_up as u64
    } else {
        bits << (-shift) as u32
    };
    // rounding may carry out: 2.0 -> renormalize
    let (mant53, e) = if mant53 >= (1u64 << 53) { (mant53 >> 1, e + 1) } else { (mant53, e) };
    if e > 1023 {
        return if sign { f64::NEG_INFINITY } else { f64::INFINITY };
    }
    if e < -1022 {
        // subnormal or zero: shift the significand down
        let down = (-1022 - e) as u32;
        if down > 53 {
            return if sign { -0.0 } else { 0.0 };
        }
        let sub = mant53 >> down; // truncation; sub-ulp for the study
        bits = sub;
        let out = f64::from_bits(((sign as u64) << 63) | bits);
        return out;
    }
    let out_bits =
        ((sign as u64) << 63) | (((e + 1023) as u64) << 52) | (mant53 & 0xF_FFFF_FFFF_FFFF);
    f64::from_bits(out_bits)
}

/// Divide two f64s through a mantissa-division closure (IEEE specials
/// handled around the `[1,2) x [1,2)` core).
pub fn divide_via64<F>(n: f64, d: f64, frac: u32, core: F) -> f64
where
    F: FnOnce(Fixed, Fixed) -> Fixed,
{
    match (classify64(n), classify64(d)) {
        (FpClass::Nan, _) | (_, FpClass::Nan) => f64::NAN,
        (FpClass::Inf, FpClass::Inf) => f64::NAN,
        (FpClass::Zero, FpClass::Zero) => f64::NAN,
        (FpClass::Inf, _) => {
            if (n < 0.0) ^ (d < 0.0) { f64::NEG_INFINITY } else { f64::INFINITY }
        }
        (_, FpClass::Inf) => if (n < 0.0) ^ d.is_sign_negative() { -0.0 } else { 0.0 },
        (FpClass::Zero, _) => if n.is_sign_negative() ^ (d < 0.0) { -0.0 } else { 0.0 },
        (_, FpClass::Zero) => {
            if (n < 0.0) ^ d.is_sign_negative() { f64::NEG_INFINITY } else { f64::INFINITY }
        }
        (FpClass::Finite, FpClass::Finite) => {
            let un = unpack64(n, frac);
            let ud = unpack64(d, frac);
            let q = core(un.mant, ud.mant);
            pack64(un.sign ^ ud.sign, un.exp - ud.exp, &q)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::ulp::ulp_diff_f64;
    use crate::check::{self, ensure};

    #[test]
    fn unpack_normal() {
        let u = unpack64(6.5, 56);
        assert!(!u.sign);
        assert_eq!(u.exp, 2);
        assert!((u.mant.to_f64() - 1.625).abs() < 1e-15);
    }

    #[test]
    fn unpack_subnormal() {
        let x = f64::from_bits(1); // 2^-1074
        let u = unpack64(x, 56);
        assert_eq!(u.exp, -1074);
        assert_eq!(u.mant.bits(), 1u64 << 56);
    }

    #[test]
    fn pack_unpack_roundtrip_property() {
        check::property("pack64(unpack64(x)) == x", |g| {
            let bits = g.bits() & 0x7FFF_FFFF_FFFF_FFFF;
            let x = f64::from_bits(bits);
            if classify64(x) != FpClass::Finite {
                return Ok(());
            }
            let u = unpack64(x, 56);
            let back = pack64(u.sign, u.exp, &u.mant);
            ensure(back == x, format!("x={x:e} back={back:e}"))
        });
    }

    #[test]
    fn pack_rounds_to_nearest_even() {
        // mantissa with a 1 exactly past bit 52 and even keep: round down
        let m = Fixed::from_bits(((1u64 << 52) << 4) | 0b1000, 56);
        let out = pack64(false, 0, &m);
        assert_eq!(out, 1.0);
        // odd keep: round up
        let m = Fixed::from_bits((((1u64 << 52) | 1) << 4) | 0b1000, 56);
        let out = pack64(false, 0, &m);
        assert_eq!(out.to_bits() & 0xF_FFFF_FFFF_FFFF, 2);
    }

    #[test]
    fn overflow_underflow_saturate() {
        let m = Fixed::from_f64(1.5, 56);
        assert_eq!(pack64(false, 2000, &m), f64::INFINITY);
        assert_eq!(pack64(true, 2000, &m), f64::NEG_INFINITY);
        assert_eq!(pack64(false, -1200, &m), 0.0);
    }

    #[test]
    fn divide_via64_exact_core() {
        check::property("divide_via64(exact) ~= n/d", |g| {
            let n = g.f64_in(1e-3, 1e3);
            let d = g.f64_in(1e-3, 1e3);
            let q = divide_via64(n, d, 56, |nm, dm| {
                // 56-bit mantissa quotient via u128 long division (exact)
                let wide = (nm.bits() as u128) << 56;
                let qb = (wide / dm.bits() as u128) as u64;
                Fixed::from_bits(qb, 56)
            });
            ensure(
                ulp_diff_f64(q, n / d) <= 1,
                format!("n={n} d={d} q={q} want={}", n / d),
            )
        });
    }

    #[test]
    fn specials() {
        let core = |n: Fixed, _d: Fixed| n;
        assert!(divide_via64(f64::NAN, 1.0, 56, core).is_nan());
        assert_eq!(divide_via64(1.0, 0.0, 56, core), f64::INFINITY);
        assert_eq!(divide_via64(0.0, 2.0, 56, core), 0.0);
    }
}
