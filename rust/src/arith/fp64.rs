//! IEEE-754 binary64 pack/unpack: the double-precision FPU boundary.
//!
//! EIMMW-2000 (the paper's foundation) targets double precision; this
//! module is the f64-typed face of the generic boundary in
//! [`crate::formats`], wrapping the same mantissa datapath, which needs
//! `frac >= 56` (52 mantissa bits + guard bits — within the `Fixed`
//! limit of 62).

use super::fixed::Fixed;
use super::fp::FpClass;
use crate::formats::{self, F64 as Fmt64};

/// A decomposed finite nonzero binary64 (same shape as the generic
/// [`formats::Unpacked`]).
pub type Unpacked64 = formats::Unpacked;

/// Classify an f64 for dispatch before the datapath.
pub fn classify64(x: f64) -> FpClass {
    formats::classify::<Fmt64>(x.to_bits())
}

/// Unpack a finite nonzero f64 (subnormals normalized), `frac >= 52`.
pub fn unpack64(x: f64, frac: u32) -> Unpacked64 {
    assert!(frac >= 52, "f64 needs frac >= 52");
    formats::unpack::<Fmt64>(x.to_bits(), frac)
}

/// Repack with round-to-nearest-even into f64. The mantissa may lie in
/// `[0.5, 4)`; exponent is renormalized; over/underflow saturate per
/// IEEE. Works directly on the fixed-point bits (no f64 detour — a
/// `frac > 52` mantissa would lose bits through a float intermediate).
pub fn pack64(sign: bool, exp: i32, mant: &Fixed) -> f64 {
    f64::from_bits(formats::pack::<Fmt64>(sign, exp, mant))
}

/// Divide two f64s through a mantissa-division closure (IEEE specials
/// handled around the `[1,2) x [1,2)` core).
pub fn divide_via64<F>(n: f64, d: f64, frac: u32, core: F) -> f64
where
    F: FnOnce(Fixed, Fixed) -> Fixed,
{
    f64::from_bits(formats::divide_via_bits::<Fmt64, F>(n.to_bits(), d.to_bits(), frac, core))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::ulp::ulp_diff_f64;
    use crate::check::{self, ensure};

    #[test]
    fn unpack_normal() {
        let u = unpack64(6.5, 56);
        assert!(!u.sign);
        assert_eq!(u.exp, 2);
        assert!((u.mant.to_f64() - 1.625).abs() < 1e-15);
    }

    #[test]
    fn unpack_subnormal() {
        let x = f64::from_bits(1); // 2^-1074
        let u = unpack64(x, 56);
        assert_eq!(u.exp, -1074);
        assert_eq!(u.mant.bits(), 1u64 << 56);
    }

    #[test]
    fn pack_unpack_roundtrip_property() {
        check::property("pack64(unpack64(x)) == x", |g| {
            let bits = g.bits() & 0x7FFF_FFFF_FFFF_FFFF;
            let x = f64::from_bits(bits);
            if classify64(x) != FpClass::Finite {
                return Ok(());
            }
            let u = unpack64(x, 56);
            let back = pack64(u.sign, u.exp, &u.mant);
            ensure(back == x, format!("x={x:e} back={back:e}"))
        });
    }

    #[test]
    fn pack_rounds_to_nearest_even() {
        // mantissa with a 1 exactly past bit 52 and even keep: round down
        let m = Fixed::from_bits(((1u64 << 52) << 4) | 0b1000, 56);
        let out = pack64(false, 0, &m);
        assert_eq!(out, 1.0);
        // odd keep: round up
        let m = Fixed::from_bits((((1u64 << 52) | 1) << 4) | 0b1000, 56);
        let out = pack64(false, 0, &m);
        assert_eq!(out.to_bits() & 0xF_FFFF_FFFF_FFFF, 2);
    }

    #[test]
    fn overflow_underflow_saturate() {
        let m = Fixed::from_f64(1.5, 56);
        assert_eq!(pack64(false, 2000, &m), f64::INFINITY);
        assert_eq!(pack64(true, 2000, &m), f64::NEG_INFINITY);
        assert_eq!(pack64(false, -1200, &m), 0.0);
    }

    #[test]
    fn divide_via64_exact_core() {
        check::property("divide_via64(exact) ~= n/d", |g| {
            let n = g.f64_in(1e-3, 1e3);
            let d = g.f64_in(1e-3, 1e3);
            let q = divide_via64(n, d, 56, |nm, dm| {
                // 56-bit mantissa quotient via u128 long division (exact)
                let wide = (nm.bits() as u128) << 56;
                let qb = (wide / dm.bits() as u128) as u64;
                Fixed::from_bits(qb, 56)
            });
            ensure(
                ulp_diff_f64(q, n / d) <= 1,
                format!("n={n} d={d} q={q} want={}", n / d),
            )
        });
    }

    #[test]
    fn specials() {
        let core = |n: Fixed, _d: Fixed| n;
        assert!(divide_via64(f64::NAN, 1.0, 56, core).is_nan());
        assert_eq!(divide_via64(1.0, 0.0, 56, core), f64::INFINITY);
        assert_eq!(divide_via64(0.0, 2.0, 56, core), 0.0);
    }
}
