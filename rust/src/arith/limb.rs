//! 32-bit-limb multiply layer: the vectorizable formulation of the
//! datapath multiply, and the [`PlaneWord`] abstraction over width-true
//! plane words.
//!
//! # Why limbs
//!
//! The paper's lever is shrinking the multiplier to what the precision
//! actually needs. The software analogue: a `u64 x u64 -> u128` product
//! (the seed's formulation of every mantissa multiply) compiles to a
//! 64-bit `mul` producing a 128-bit result — an operation SIMD units do
//! not have, so the lane loops never auto-vectorize. Slicing each
//! operand into 32-bit limbs turns one wide product into four widening
//! `u32 x u32 -> u64` products plus an explicit carry chain — exactly
//! the primitive AVX2 (`vpmuludq`) and NEON (`umull`) expose 4-8 lanes
//! wide. And for the half-precision planes the whole word fits one
//! limb: a Q2.20 datapath word is 22 bits, so the product fits a single
//! `u64` and the multiply is *one* widening product per lane.
//!
//! Everything here is bit-identical to the `u128` reference by
//! construction (property-tested below); [`Fixed::mul`] and the batch
//! kernels' complement-multiply step are both built on it.
//!
//! [`Fixed::mul`]: crate::arith::fixed::Fixed::mul

use super::fixed::Rounding;

/// Bits per limb.
pub const LIMB_BITS: u32 = 32;
/// Low-limb mask.
pub const LIMB_MASK: u64 = 0xFFFF_FFFF;

/// Exact 128-bit product of two `u64` words as `(lo, hi)` halves,
/// computed from four `u32 x u32 -> u64` limb products with an explicit
/// carry chain — no `u128` anywhere. This is the schoolbook 2x2 limb
/// array; the middle-column sum fits a `u64` (at most `3 * (2^32 - 1)`
/// after the `p00` carry), so no intermediate overflows.
#[inline(always)]
pub fn widening_mul(a: u64, b: u64) -> (u64, u64) {
    let (a0, a1) = (a & LIMB_MASK, a >> LIMB_BITS);
    let (b0, b1) = (b & LIMB_MASK, b >> LIMB_BITS);
    let p00 = a0 * b0;
    let p01 = a0 * b1;
    let p10 = a1 * b0;
    let p11 = a1 * b1;
    let mid = (p00 >> LIMB_BITS) + (p01 & LIMB_MASK) + (p10 & LIMB_MASK);
    let lo = (p00 & LIMB_MASK) | (mid << LIMB_BITS);
    let hi = p11 + (p01 >> LIMB_BITS) + (p10 >> LIMB_BITS) + (mid >> LIMB_BITS);
    (lo, hi)
}

/// Narrow a 128-bit `(lo, hi)` product by `shift` bits under a rounding
/// mode and saturate to `sat`: the limb-sliced image of
/// `narrow_u128(wide, shift, mode).min(sat)` in [`crate::arith::fixed`].
/// `shift <= 62` (the `Fixed` fraction range).
#[inline(always)]
pub fn narrow_sat(mut lo: u64, mut hi: u64, shift: u32, mode: Rounding, sat: u64) -> u64 {
    debug_assert!(shift <= 62);
    if mode == Rounding::Nearest && shift > 0 {
        // add the half-ulp constant with an explicit carry into hi;
        // hi < 2^64 - 1 always (it is a product's top half), so the
        // carry add cannot wrap
        let (sum, carry) = lo.overflowing_add(1u64 << (shift - 1));
        lo = sum;
        hi += carry as u64;
    }
    if shift == 0 {
        return if hi != 0 { sat } else { lo.min(sat) };
    }
    if (hi >> shift) != 0 {
        return sat; // the narrowed value exceeds 64 bits: saturate
    }
    ((lo >> shift) | (hi << (64 - shift))).min(sat)
}

/// Full limb-sliced Q2 multiply on 64-bit words: exact product of two
/// `Q2.frac` words, narrowed back to `frac` fraction bits under
/// `NEAREST`, saturated at `sat`. Bit-identical to the `u128` reference
/// for every input pair.
#[inline(always)]
pub fn mul_q2_u64<const NEAREST: bool>(a: u64, b: u64, frac: u32, sat: u64) -> u64 {
    let (lo, hi) = widening_mul(a, b);
    let mode = if NEAREST { Rounding::Nearest } else { Rounding::Truncate };
    narrow_sat(lo, hi, frac, mode, sat)
}

/// Single-limb Q2 multiply on 32-bit words (the half-precision fast
/// path): both operands are at most `frac + 2 <= 32` bits, so the exact
/// product — and its Nearest half-ulp add — fits one `u64`. One
/// widening multiply per lane; this is the loop shape `vpmuludq` /
/// `umull` vectorize 4-8 wide.
#[inline(always)]
pub fn mul_q2_u32<const NEAREST: bool>(a: u32, b: u32, frac: u32, sat: u32) -> u32 {
    debug_assert!(frac <= 30, "u32 plane words need frac + 2 <= 32");
    let wide = (a as u64) * (b as u64);
    let narrowed = if NEAREST {
        if frac == 0 {
            wide
        } else {
            // wide <= (2^32 - 1)^2 leaves room for the half-ulp add
            (wide + (1u64 << (frac - 1))) >> frac
        }
    } else {
        wide >> frac
    };
    narrowed.min(sat as u64) as u32
}

/// A width-true SoA plane word: the storage type of one lane in the
/// batch kernels and the coordinator's operand planes. `u32` carries the
/// half-precision planes (16-bit containers, 22-bit Q2.20 datapath
/// words), `u64` the single/double planes. Every op the lane loops need
/// is part of the trait (or a supertrait bound), so the kernels
/// monomorphize to straight-line integer code per width.
pub trait PlaneWord:
    Copy
    + Default
    + Send
    + Sync
    + Eq
    + Ord
    + std::fmt::Debug
    + std::ops::Sub<Output = Self>
    + std::ops::Add<Output = Self>
    + std::ops::BitAnd<Output = Self>
    + std::ops::Shl<u32, Output = Self>
    + std::ops::Shr<u32, Output = Self>
    + 'static
{
    /// Word width in bits.
    const BITS: u32;
    /// The zero word.
    const ZERO: Self;
    /// The one word (an integer 1, not a fixed-point 1.0).
    const ONE: Self;

    /// Truncate a universal `u64` word down (callers guarantee fit;
    /// debug-checked).
    fn from_u64(w: u64) -> Self;

    /// Widen to the universal `u64` word.
    fn to_u64(self) -> u64;

    /// Wrapping subtract (the one's-complement circuit).
    fn wrapping_sub(self, rhs: Self) -> Self;

    /// The datapath multiply at this width: exact `Q2.frac` product
    /// narrowed to `frac` under `NEAREST`, saturated at `sat`.
    fn mul_q2<const NEAREST: bool>(a: Self, b: Self, frac: u32, sat: Self) -> Self;
}

impl PlaneWord for u32 {
    const BITS: u32 = 32;
    const ZERO: Self = 0;
    const ONE: Self = 1;

    #[inline(always)]
    fn from_u64(w: u64) -> Self {
        debug_assert!(w <= u32::MAX as u64, "{w:#x} does not fit a u32 plane word");
        w as u32
    }

    #[inline(always)]
    fn to_u64(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn wrapping_sub(self, rhs: Self) -> Self {
        u32::wrapping_sub(self, rhs)
    }

    #[inline(always)]
    fn mul_q2<const NEAREST: bool>(a: Self, b: Self, frac: u32, sat: Self) -> Self {
        mul_q2_u32::<NEAREST>(a, b, frac, sat)
    }
}

impl PlaneWord for u64 {
    const BITS: u32 = 64;
    const ZERO: Self = 0;
    const ONE: Self = 1;

    #[inline(always)]
    fn from_u64(w: u64) -> Self {
        w
    }

    #[inline(always)]
    fn to_u64(self) -> u64 {
        self
    }

    #[inline(always)]
    fn wrapping_sub(self, rhs: Self) -> Self {
        u64::wrapping_sub(self, rhs)
    }

    #[inline(always)]
    fn mul_q2<const NEAREST: bool>(a: Self, b: Self, frac: u32, sat: Self) -> Self {
        mul_q2_u64::<NEAREST>(a, b, frac, sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn widening_mul_matches_u128_property() {
        check::property("limb widening_mul == u128", |g| {
            let a = g.bits();
            let b = g.bits();
            let (lo, hi) = widening_mul(a, b);
            let want = (a as u128) * (b as u128);
            ensure(
                lo == want as u64 && hi == (want >> 64) as u64,
                format!("{a:#x} * {b:#x}: ({lo:#x}, {hi:#x}) want {want:#x}"),
            )
        });
    }

    #[test]
    fn widening_mul_edge_patterns() {
        for &a in &[0u64, 1, u64::MAX, 1u64 << 63, 0x5555_5555_5555_5555, LIMB_MASK] {
            for &b in &[0u64, 1, u64::MAX, 1u64 << 32, 0xAAAA_AAAA_AAAA_AAAA] {
                let (lo, hi) = widening_mul(a, b);
                let want = (a as u128) * (b as u128);
                assert_eq!(lo, want as u64, "{a:#x}*{b:#x} lo");
                assert_eq!(hi, (want >> 64) as u64, "{a:#x}*{b:#x} hi");
            }
        }
    }

    #[test]
    fn narrow_sat_matches_u128_reference_property() {
        use crate::arith::fixed::narrow_u128;
        check::property("limb narrow_sat == narrow_u128 + min", |g| {
            let a = g.bits();
            let b = g.bits();
            let shift = g.usize_in(0, 63) as u32; // 0..=62
            let mode = *g.pick(&[Rounding::Truncate, Rounding::Nearest]);
            let sat = g.bits();
            let (lo, hi) = widening_mul(a, b);
            let got = narrow_sat(lo, hi, shift, mode, sat);
            let want = narrow_u128((a as u128) * (b as u128), shift, mode).min(sat as u128) as u64;
            ensure(
                got == want,
                format!("{a:#x}*{b:#x} >> {shift} ({mode:?}): {got:#x} want {want:#x}"),
            )
        });
    }

    #[test]
    fn mul_q2_u64_matches_u128_reference_property() {
        use crate::arith::fixed::{narrow_u128, q2_max};
        check::property("mul_q2_u64 == u128 Q2 multiply", |g| {
            let frac = g.usize_in(0, 63) as u32; // 0..=62
            let sat = q2_max(frac);
            let a = g.bits() & sat;
            let b = g.bits() & sat;
            let wide = (a as u128) * (b as u128);
            let want_n = narrow_u128(wide, frac, Rounding::Nearest).min(sat as u128) as u64;
            let want_t = narrow_u128(wide, frac, Rounding::Truncate).min(sat as u128) as u64;
            ensure(
                mul_q2_u64::<true>(a, b, frac, sat) == want_n
                    && mul_q2_u64::<false>(a, b, frac, sat) == want_t,
                format!("frac={frac} a={a:#x} b={b:#x}"),
            )
        });
    }

    #[test]
    fn mul_q2_u32_matches_u64_path_property() {
        use crate::arith::fixed::q2_max;
        check::property("u32 fast path == u64 limb path", |g| {
            let frac = g.usize_in(0, 31) as u32; // 0..=30: the u32 range
            let sat = q2_max(frac);
            let a = g.bits() & sat;
            let b = g.bits() & sat;
            let got_n = mul_q2_u32::<true>(a as u32, b as u32, frac, sat as u32);
            let got_t = mul_q2_u32::<false>(a as u32, b as u32, frac, sat as u32);
            ensure(
                got_n as u64 == mul_q2_u64::<true>(a, b, frac, sat)
                    && got_t as u64 == mul_q2_u64::<false>(a, b, frac, sat),
                format!("frac={frac} a={a:#x} b={b:#x}"),
            )
        });
    }

    #[test]
    fn narrow_sat_saturates_oversized_products() {
        // (just under 4.0)^2 at frac 62: the 128-bit product exceeds the
        // word after narrowing and must clamp, not wrap
        let sat = u64::MAX;
        let (lo, hi) = widening_mul(u64::MAX, u64::MAX);
        assert_eq!(narrow_sat(lo, hi, 62, Rounding::Nearest, sat), sat);
        assert_eq!(narrow_sat(lo, hi, 62, Rounding::Truncate, sat), sat);
        // shift 0 with a nonzero hi half also saturates
        assert_eq!(narrow_sat(0, 1, 0, Rounding::Truncate, sat), sat);
    }

    #[test]
    fn plane_word_roundtrip_and_consts() {
        assert_eq!(<u32 as PlaneWord>::BITS, 32);
        assert_eq!(<u64 as PlaneWord>::BITS, 64);
        assert_eq!(u32::from_u64(0xABCD).to_u64(), 0xABCD);
        assert_eq!(u64::from_u64(u64::MAX).to_u64(), u64::MAX);
        assert_eq!(<u32 as PlaneWord>::ZERO + <u32 as PlaneWord>::ONE, 1);
        // trait mul dispatches to the width's implementation
        let s32 = crate::arith::fixed::q2_max(20) as u32;
        let one20 = 1u32 << 20;
        assert_eq!(<u32 as PlaneWord>::mul_q2::<true>(one20, one20, 20, s32), one20);
        let s64 = crate::arith::fixed::q2_max(58);
        let one58 = 1u64 << 58;
        assert_eq!(<u64 as PlaneWord>::mul_q2::<true>(one58, one58, 58, s64), one58);
    }
}
