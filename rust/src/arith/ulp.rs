//! ulp-distance measurement for the accuracy experiments (claim ACC,
//! variants V1/V2): how far a computed f32/f64 lands from the correctly
//! rounded result.

/// Distance in ulps between two finite f32 values of the same sign
/// (order-of-magnitude robust: integer distance on the bit lattice).
pub fn ulp_diff_f32(a: f32, b: f32) -> u64 {
    assert!(a.is_finite() && b.is_finite(), "ulp of non-finite");
    let to_lattice = |x: f32| -> i64 {
        let bits = x.to_bits() as i32;
        // map sign-magnitude to a monotone integer line: negative floats
        // fold below zero (+0.0 and -0.0 both land on 0)
        if bits < 0 { i32::MIN as i64 - bits as i64 } else { bits as i64 }
    };
    (to_lattice(a) - to_lattice(b)).unsigned_abs()
}

/// Distance in ulps between two finite f64 values.
pub fn ulp_diff_f64(a: f64, b: f64) -> u64 {
    assert!(a.is_finite() && b.is_finite(), "ulp of non-finite");
    let to_lattice = |x: f64| -> i128 {
        let bits = x.to_bits() as i64;
        if bits < 0 { i64::MIN as i128 - bits as i128 } else { bits as i128 }
    };
    (to_lattice(a) - to_lattice(b)).unsigned_abs() as u64
}

/// Size of one ulp at the magnitude of `x` (f32).
pub fn ulp_size_f32(x: f32) -> f32 {
    let next = f32::from_bits(x.to_bits() + 1);
    next - x
}

/// Relative error |a - b| / |b| in f64.
pub fn rel_err(a: f64, b: f64) -> f64 {
    if b == 0.0 { a.abs() } else { (a - b).abs() / b.abs() }
}

/// Maximum ulp error over paired slices.
pub fn max_ulp_f32(got: &[f32], want: &[f32]) -> u64 {
    assert_eq!(got.len(), want.len());
    got.iter().zip(want).map(|(&g, &w)| ulp_diff_f32(g, w)).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_distance() {
        assert_eq!(ulp_diff_f32(1.5, 1.5), 0);
        assert_eq!(ulp_diff_f64(-2.25, -2.25), 0);
    }

    #[test]
    fn adjacent_floats_are_one_ulp() {
        let x = 1.0f32;
        let next = f32::from_bits(x.to_bits() + 1);
        assert_eq!(ulp_diff_f32(x, next), 1);
        let y = 1e10f64;
        let next = f64::from_bits(y.to_bits() + 1);
        assert_eq!(ulp_diff_f64(y, next), 1);
    }

    #[test]
    fn across_binade() {
        // 2.0 is one ulp above the largest float below it
        let below = f32::from_bits(2.0f32.to_bits() - 1);
        assert_eq!(ulp_diff_f32(2.0, below), 1);
    }

    #[test]
    fn across_zero() {
        let pos = f32::from_bits(1); // smallest positive subnormal
        let neg = -pos;
        // distance: pos -> 0 -> -0 -> neg = 2 lattice steps
        assert_eq!(ulp_diff_f32(pos, neg), 2);
        assert_eq!(ulp_diff_f32(0.0, pos), 1);
    }

    #[test]
    fn symmetric() {
        assert_eq!(ulp_diff_f32(1.0, 1.5), ulp_diff_f32(1.5, 1.0));
    }

    #[test]
    fn rel_err_basics() {
        assert_eq!(rel_err(1.01, 1.0), 0.010000000000000009);
        assert_eq!(rel_err(5.0, 0.0), 5.0);
    }

    #[test]
    fn max_ulp_over_slices() {
        let want = [1.0f32, 2.0, 3.0];
        let got = [1.0f32, f32::from_bits(2.0f32.to_bits() + 3), 3.0];
        assert_eq!(max_ulp_f32(&got, &want), 3);
    }

    #[test]
    fn ulp_size_grows_with_magnitude() {
        assert!(ulp_size_f32(1e20) > ulp_size_f32(1.0));
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn non_finite_panics() {
        ulp_diff_f32(f32::NAN, 1.0);
    }
}
