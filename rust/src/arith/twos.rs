//! The paper's two's-complement block as an explicit hardware model.
//!
//! In the Goldschmidt datapath the block computes `K_{i+1} = 2 - r_i`.
//! For a `Q2.f` word this is the two's complement of the low `f+1` bits
//! (the value sits in `(0, 2]`), implementable as an inverter row plus an
//! increment. The carry-free variant skips the `+1` (one's complement),
//! landing one ulp low — EIMMW show the iteration absorbs this.
//!
//! This module models the block at bit level (for validation and for the
//! area model); the algorithm layer calls the equivalent
//! [`crate::arith::Fixed::two_minus`] /
//! [`Fixed::two_minus_ones_complement`](crate::arith::Fixed::two_minus_ones_complement).

use super::fixed::Fixed;
use super::mult::UnitCost;

/// Which complement circuit the datapath instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ComplementKind {
    /// Inverters + incrementer: exact `2 - r`.
    #[default]
    Exact,
    /// Inverters only: `2 - r - ulp` (carry-free, cheaper, 1 ulp bias).
    OnesComplement,
}

impl ComplementKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "exact" | "twos" => Ok(Self::Exact),
            "ones" | "ones-complement" => Ok(Self::OnesComplement),
            other => Err(format!("unknown complement kind {other:?}")),
        }
    }
}

/// Bit-level model of the complement block.
#[derive(Clone, Copy, Debug)]
pub struct ComplementBlock {
    /// Word fraction width it is wired for.
    pub frac: u32,
    /// Circuit variant.
    pub kind: ComplementKind,
}

impl ComplementBlock {
    /// New block for `Q2.frac` words.
    pub fn new(frac: u32, kind: ComplementKind) -> Self {
        Self { frac, kind }
    }

    /// Apply the block to a datapath word (must be in `(0, 2]`).
    pub fn apply(&self, r: &Fixed) -> Fixed {
        assert_eq!(r.frac(), self.frac, "block wired for Q2.{}", self.frac);
        match self.kind {
            ComplementKind::Exact => r.two_minus(),
            ComplementKind::OnesComplement => r.two_minus_ones_complement(),
        }
    }

    /// Bit-level evaluation on the raw word, for cross-checking `apply`:
    /// two's (or one's) complement within the `frac + 1`-bit field, which
    /// computes `2 - x` for `x in (0, 2)` — the block's operating domain
    /// (`r` sits near 1 in every Goldschmidt step).
    pub fn apply_bits(&self, bits: u64) -> u64 {
        let width = self.frac + 1; // field covering values in (0, 2)
        let mask = (1u64 << width) - 1;
        assert!(bits > 0 && bits < (1u64 << width), "input outside (0, 2)");
        let inverted = !bits & mask;
        match self.kind {
            ComplementKind::OnesComplement => inverted,
            ComplementKind::Exact => inverted + 1, // bits >= 1: no wrap
        }
    }

    /// Gate cost: one inverter per bit (+ incrementer chain if exact).
    pub fn cost(&self) -> UnitCost {
        let n = (self.frac + 2) as f64;
        match self.kind {
            // n inverters (0.5 GE) + n half-adders (3 GE) for the +1
            ComplementKind::Exact => UnitCost { gates: 0.5 * n + 3.0 * n, depth: 2.0 * n },
            ComplementKind::OnesComplement => UnitCost { gates: 0.5 * n, depth: 1.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn exact_block_matches_fixed_op() {
        check::property("block.apply == two_minus", |g| {
            let frac = g.usize_in(4, 60) as u32;
            let bits = 1 + g.u64_below(1u64 << (frac + 1));
            let r = Fixed::from_bits(bits, frac);
            let block = ComplementBlock::new(frac, ComplementKind::Exact);
            ensure(
                block.apply(&r).bits() == r.two_minus().bits(),
                format!("frac={frac} bits={bits}"),
            )
        });
    }

    #[test]
    fn bit_level_matches_value_level() {
        check::property("apply_bits == apply", |g| {
            let frac = g.usize_in(4, 60) as u32;
            let bits = 1 + g.u64_below((1u64 << (frac + 1)) - 1);
            let r = Fixed::from_bits(bits, frac);
            for kind in [ComplementKind::Exact, ComplementKind::OnesComplement] {
                let block = ComplementBlock::new(frac, kind);
                let via_bits = block.apply_bits(bits);
                let via_value = block.apply(&r).bits();
                if via_bits != via_value {
                    return Err(format!(
                        "kind={kind:?} frac={frac} bits={bits:#x}: {via_bits:#x} != {via_value:#x}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ones_complement_is_cheaper_and_shallower() {
        let exact = ComplementBlock::new(30, ComplementKind::Exact).cost();
        let ones = ComplementBlock::new(30, ComplementKind::OnesComplement).cost();
        assert!(ones.gates < exact.gates);
        assert!(ones.depth < exact.depth);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(ComplementKind::parse("exact").unwrap(), ComplementKind::Exact);
        assert_eq!(
            ComplementKind::parse("ones").unwrap(),
            ComplementKind::OnesComplement
        );
        assert!(ComplementKind::parse("bogus").is_err());
    }

    #[test]
    fn known_values() {
        let b = ComplementBlock::new(10, ComplementKind::Exact);
        // r = 1.0 -> K = 1.0
        assert_eq!(b.apply(&Fixed::one(10)).to_f64(), 1.0);
        // r = 0.5 -> K = 1.5
        assert_eq!(b.apply(&Fixed::from_f64(0.5, 10)).to_f64(), 1.5);
        // r = 2.0 -> K = 0.0
        assert_eq!(b.apply(&Fixed::two(10)).to_f64(), 0.0);
    }
}
