//! IEEE-754 binary32 pack/unpack: the FPU boundary around the paper's
//! mantissa datapath.
//!
//! The divider array only ever sees normalized mantissas in `[1, 2)`
//! (or `[1, 4)` for the square-root path); this module is the f32-typed
//! face of the generic boundary in [`crate::formats`] — classification,
//! decomposition, and round-to-nearest-even reassembly are implemented
//! once there and monomorphized here for binary32.

use super::fixed::Fixed;
use crate::formats::{self, F32 as Fmt32};

pub use crate::formats::{FpClass, Unpacked};

/// Classify an f32 for dispatch before the datapath.
pub fn classify(x: f32) -> FpClass {
    formats::classify::<Fmt32>(x.to_bits() as u64)
}

/// Unpack a finite nonzero f32 into sign/exponent/mantissa-in-[1,2) at
/// `frac` fraction bits. Subnormals are normalized (their leading zeros
/// move into the exponent), exactly as a hardware pre-normalizer does.
pub fn unpack(x: f32, frac: u32) -> Unpacked {
    formats::unpack::<Fmt32>(x.to_bits() as u64, frac)
}

/// Repack sign/exponent/mantissa into an f32 with round-to-nearest-even.
/// The mantissa may lie in `[0.5, 4)`; the exponent is renormalized.
/// Overflow returns ±inf, underflow rounds into the subnormal range.
pub fn pack(sign: bool, exp: i32, mant: &Fixed) -> f32 {
    f32::from_bits(formats::pack::<Fmt32>(sign, exp, mant) as u32)
}

/// Convenience: the mantissa field width used by the service layer.
pub const SERVICE_FRAC: u32 = 30;

/// Divide two finite f32s through a mantissa-division closure.
/// Handles sign, exponent arithmetic, zeros, infs and nans around the
/// `[1,2) x [1,2) -> (1/2, 2)` core the datapath provides.
pub fn divide_via<F>(n: f32, d: f32, frac: u32, core: F) -> f32
where
    F: FnOnce(Fixed, Fixed) -> Fixed,
{
    f32::from_bits(
        formats::divide_via_bits::<Fmt32, F>(n.to_bits() as u64, d.to_bits() as u64, frac, core)
            as u32,
    )
}

/// Reference mantissa divider used in tests: correctly-rounded via f64.
pub fn exact_mantissa_divide(n: Fixed, d: Fixed) -> Fixed {
    let q = n.to_f64() / d.to_f64();
    Fixed::from_f64(q, n.frac())
}

/// Round a wide-mantissa result to the 23-bit output format, RNE, by
/// going through f32 packing at exponent 0.
pub fn round_mantissa_to_f32(m: &Fixed) -> f32 {
    pack(false, 0, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn classify_all() {
        assert_eq!(classify(1.5), FpClass::Finite);
        assert_eq!(classify(-2.0e-40), FpClass::Finite); // subnormal
        assert_eq!(classify(0.0), FpClass::Zero);
        assert_eq!(classify(-0.0), FpClass::Zero);
        assert_eq!(classify(f32::INFINITY), FpClass::Inf);
        assert_eq!(classify(f32::NAN), FpClass::Nan);
    }

    #[test]
    fn unpack_normal() {
        let u = unpack(6.5, 30); // 1.625 * 2^2
        assert!(!u.sign);
        assert_eq!(u.exp, 2);
        assert!((u.mant.to_f64() - 1.625).abs() < 1e-9);
    }

    #[test]
    fn unpack_negative() {
        let u = unpack(-0.75, 30); // -1.5 * 2^-1
        assert!(u.sign);
        assert_eq!(u.exp, -1);
        assert!((u.mant.to_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn unpack_subnormal_normalizes() {
        let x = f32::from_bits(0x0000_0001); // smallest subnormal 2^-149
        let u = unpack(x, 30);
        assert_eq!(u.exp, -149);
        assert!((u.mant.to_f64() - 1.0).abs() < 1e-9);
        let y = f32::from_bits(0x0000_0003); // 3 * 2^-149 = 1.5 * 2^-148
        let v = unpack(y, 30);
        assert_eq!(v.exp, -148);
        assert!((v.mant.to_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn pack_unpack_roundtrip_property() {
        check::property("pack(unpack(x)) == x", |g| {
            // random finite normal f32 via random bits, skipping specials
            let bits = (g.bits() as u32) & 0x7FFF_FFFF;
            let x = f32::from_bits(bits);
            if classify(x) != FpClass::Finite {
                return Ok(());
            }
            let u = unpack(x, 40);
            let back = pack(u.sign, u.exp, &u.mant);
            ensure(back == x, format!("x={x:e} back={back:e}"))
        });
    }

    #[test]
    fn divide_via_specials() {
        let core = exact_mantissa_divide;
        assert!(divide_via(f32::NAN, 1.0, 30, core).is_nan());
        assert!(divide_via(1.0, f32::NAN, 30, core).is_nan());
        assert!(divide_via(f32::INFINITY, f32::INFINITY, 30, core).is_nan());
        assert!(divide_via(0.0, 0.0, 30, core).is_nan());
        assert_eq!(divide_via(f32::INFINITY, -2.0, 30, core), f32::NEG_INFINITY);
        assert_eq!(divide_via(3.0, f32::INFINITY, 30, core), 0.0);
        assert_eq!(divide_via(0.0, 5.0, 30, core), 0.0);
        assert_eq!(divide_via(-1.0, 0.0, 30, core), f32::NEG_INFINITY);
        assert_eq!(divide_via(1.0, -0.0, 30, core), f32::NEG_INFINITY);
    }

    #[test]
    fn divide_via_exact_core_matches_hardware_division() {
        check::property("divide_via(exact) ~= n/d", |g| {
            let n = g.f32_in(0.001, 1000.0);
            let d = g.f32_in(0.001, 1000.0);
            let q = divide_via(n, d, 40, exact_mantissa_divide);
            let want = n / d;
            let ulp = (q.to_bits() as i64 - want.to_bits() as i64).abs();
            ensure(ulp <= 1, format!("n={n} d={d} q={q} want={want}"))
        });
    }

    #[test]
    fn pack_handles_mantissa_out_of_unit_range() {
        // mantissa 0.75 with exp 3 == 6.0
        let m = Fixed::from_f64(0.75, 30);
        assert_eq!(pack(false, 3, &m), 6.0);
        // mantissa 3.0 with exp 0 == 3.0
        let m = Fixed::from_f64(3.0, 30);
        assert_eq!(pack(true, 0, &m), -3.0);
    }

    #[test]
    fn pack_subnormal_outputs_round_nearest_even() {
        // 1.5 * 2^-149: halfway between subnormals 1 and 2 -> ties to 2
        let m = Fixed::from_f64(1.5, 30);
        assert_eq!(pack(false, -149, &m).to_bits(), 2);
        // 1.25 * 2^-149 rounds down to the nearest subnormal
        let m = Fixed::from_f64(1.25, 30);
        assert_eq!(pack(false, -149, &m).to_bits(), 1);
    }
}
