//! Bit-accurate arithmetic substrate: the number formats and hardware
//! primitive models everything above (tables, algorithms, simulator)
//! is built on.
//!
//! * [`fixed`] — unsigned fixed-point `Q2.f` values (the datapath word).
//! * [`mult`] — bit-level multiplier models (array, Booth/Wallace) used
//!   both to validate [`fixed`] multiplication and to source the area /
//!   latency numbers in [`crate::area`].
//! * [`twos`] — the paper's two's-complement block (`K = 2 - r`),
//!   exact and one's-complement-approximate forms.
//! * [`fp`] / [`fp64`] — IEEE-754 binary32/64 pack/unpack for the FPU
//!   boundary (EIMMW-2000's own target is double precision).
//! * [`ulp`] — ulp-distance measurement for accuracy experiments.

pub mod fixed;
pub mod fp;
pub mod fp64;
pub mod mult;
pub mod twos;
pub mod ulp;

pub use fixed::{Fixed, Rounding};
