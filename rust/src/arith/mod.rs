//! Bit-accurate arithmetic substrate: the number formats and hardware
//! primitive models everything above (tables, algorithms, simulator)
//! is built on.
//!
//! * [`fixed`] — unsigned fixed-point `Q2.f` values (the datapath word).
//! * [`limb`] — the 32-bit-limb multiply layer (widening
//!   `u32 x u32 -> u64` products with explicit carry chains) every
//!   datapath multiply is built on, plus the [`limb::PlaneWord`]
//!   abstraction over width-true plane words (`u32` half-precision
//!   planes, `u64` single/double planes).
//! * [`mult`] — bit-level multiplier models (array, Booth/Wallace,
//!   limb-sliced) used both to validate [`fixed`] multiplication and to
//!   source the area / latency numbers in [`crate::area`].
//! * [`twos`] — the paper's two's-complement block (`K = 2 - r`),
//!   exact and one's-complement-approximate forms.
//! * [`fp`] / [`fp64`] — IEEE-754 binary32/64 pack/unpack for the FPU
//!   boundary (EIMMW-2000's own target is double precision).
//! * [`ulp`] — ulp-distance measurement for accuracy experiments.

pub mod fixed;
pub mod fp;
pub mod fp64;
pub mod limb;
pub mod mult;
pub mod twos;
pub mod ulp;

pub use fixed::{Fixed, Rounding};
pub use limb::PlaneWord;
