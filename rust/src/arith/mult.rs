//! Bit-level multiplier models.
//!
//! Two roles:
//!
//! 1. **Validation** — each model computes the product by explicit
//!    partial-product accumulation, bit by bit, and is checked against
//!    native integer multiplication. This is the evidence that the
//!    simulator's 4-cycle multiplier unit computes what real hardware
//!    would.
//! 2. **Cost source** — each model reports gate counts and logic depth;
//!    [`crate::area`] turns those into the paper's area comparison
//!    (claim A1) and the latency model justifies the 4-cycle pipeline
//!    stages used by [`crate::sim`].
//!
//! Gate-count conventions (standard unit-gate accounting): a NAND/NOR/
//! AND/OR counts 1 gate-equivalent (GE), an XOR 2, a full adder 5
//! (2 XOR + majority), a half adder 3, a 2:1 mux 3, a flip-flop 4.

/// Cost report for one hardware unit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnitCost {
    /// Gate equivalents (area proxy).
    pub gates: f64,
    /// Logic depth in unit-gate delays (latency proxy).
    pub depth: f64,
}

/// A bit-level combinational multiplier model: computes `a * b` for
/// `width`-bit unsigned inputs, returning the `2*width`-bit product.
pub trait MultiplierModel {
    /// Operand width in bits.
    fn width(&self) -> u32;
    /// Compute the product by explicit hardware-style accumulation.
    fn multiply(&self, a: u64, b: u64) -> u128;
    /// Area/depth cost of the combinational array.
    fn cost(&self) -> UnitCost;
    /// Model name for reports.
    fn name(&self) -> &'static str;
}

/// Classic carry-save array multiplier: `width` rows of AND-gated partial
/// products reduced by ripple rows of full adders.
#[derive(Clone, Copy, Debug)]
pub struct ArrayMultiplier {
    width: u32,
}

impl ArrayMultiplier {
    /// New model for `width`-bit operands (<= 63).
    pub fn new(width: u32) -> Self {
        assert!((1..=63).contains(&width));
        Self { width }
    }
}

impl MultiplierModel for ArrayMultiplier {
    fn width(&self) -> u32 {
        self.width
    }

    fn multiply(&self, a: u64, b: u64) -> u128 {
        assert!(a < (1u64 << self.width) && b < (1u64 << self.width));
        // row-by-row add of AND partial products — the array structure
        let mut acc: u128 = 0;
        for i in 0..self.width {
            if (b >> i) & 1 == 1 {
                acc = add_shifted(acc, a, i);
            }
        }
        acc
    }

    fn cost(&self) -> UnitCost {
        let n = self.width as f64;
        // n^2 AND gates + (n-1) rows of n full adders (5 GE each)
        let gates = n * n + (n - 1.0) * n * 5.0;
        // carry ripples through ~2n full-adder stages of depth ~2
        let depth = 2.0 * 2.0 * n;
        UnitCost { gates, depth }
    }

    fn name(&self) -> &'static str {
        "array"
    }
}

/// Booth-radix-4 recoded multiplier with a Wallace reduction tree: the
/// realistic high-speed choice (and the one EIMMW's 4-cycle pipelined
/// multiplier corresponds to).
#[derive(Clone, Copy, Debug)]
pub struct BoothWallaceMultiplier {
    width: u32,
}

impl BoothWallaceMultiplier {
    /// New model for `width`-bit operands (<= 62).
    pub fn new(width: u32) -> Self {
        assert!((2..=62).contains(&width));
        Self { width }
    }

    /// Booth radix-4 digit recoding of `b`: digits in {-2,-1,0,1,2}.
    fn recode(&self, b: u64) -> Vec<i8> {
        let mut digits = Vec::with_capacity((self.width as usize / 2) + 1);
        let mut prev = 0u64; // b_{-1} = 0
        let mut i = 0;
        while i < self.width + 1 {
            let b0 = (b >> i) & 1;
            let b1 = if i + 1 <= self.width { (b >> (i + 1)) & 1 } else { 0 };
            let trip = (b1 << 2) | (b0 << 1) | prev;
            let digit: i8 = match trip {
                0b000 | 0b111 => 0,
                0b001 | 0b010 => 1,
                0b011 => 2,
                0b100 => -2,
                0b101 | 0b110 => -1,
                _ => unreachable!(),
            };
            digits.push(digit);
            prev = b1;
            i += 2;
        }
        digits
    }
}

impl MultiplierModel for BoothWallaceMultiplier {
    fn width(&self) -> u32 {
        self.width
    }

    fn multiply(&self, a: u64, b: u64) -> u128 {
        assert!(a < (1u64 << self.width) && b < (1u64 << self.width));
        // signed accumulation of booth-recoded partial products
        let mut acc: i128 = 0;
        for (k, &d) in self.recode(b).iter().enumerate() {
            let pp: i128 = match d {
                0 => 0,
                1 => a as i128,
                2 => (a as i128) << 1,
                -1 => -(a as i128),
                -2 => -((a as i128) << 1),
                _ => unreachable!(),
            };
            acc += pp << (2 * k);
        }
        debug_assert!(acc >= 0);
        acc as u128
    }

    fn cost(&self) -> UnitCost {
        let n = self.width as f64;
        // n/2+1 booth-selected partial products: each selector row ~ n
        // muxes (3 GE) + recoder (~10 GE per digit)
        let rows = n / 2.0 + 1.0;
        let pp_gates = rows * (3.0 * n + 10.0);
        // Wallace tree: (rows - 2) * n full adders to reach 2 rows,
        // then a final fast adder ~ 2n * 5 GE
        let tree_gates = (rows - 2.0).max(0.0) * n * 5.0 + 2.0 * n * 5.0;
        let gates = pp_gates + tree_gates;
        // tree depth: log_{3/2}(rows) CSA levels * 2 + final CLA ~ 2 log2(2n)
        let depth = 2.0 * (rows.ln() / 1.5f64.ln()) + 2.0 * (2.0 * n).log2();
        UnitCost { gates, depth }
    }

    fn name(&self) -> &'static str {
        "booth-wallace"
    }
}

/// Rectangular (asymmetric) multiplier: a full `width_a`-bit operand by
/// a short `width_b`-bit one. This is EIMMW-2000's actual hardware shape:
/// after the first Goldschmidt step every factor is `K = 1 +- e` with `e`
/// only a few bits wide, so the multiplier array can be `n x m` with
/// `m << n` — an optimization *orthogonal* to the paper's unit-count
/// reduction (both compose; `benches/area_table.rs` shows the stack).
#[derive(Clone, Copy, Debug)]
pub struct RectangularMultiplier {
    width_a: u32,
    width_b: u32,
}

impl RectangularMultiplier {
    /// New model for `width_a x width_b`-bit operands.
    pub fn new(width_a: u32, width_b: u32) -> Self {
        assert!((1..=63).contains(&width_a));
        assert!((1..=63).contains(&width_b));
        Self { width_a, width_b }
    }

    /// Compute the exact product by row accumulation (the array).
    pub fn multiply(&self, a: u64, b: u64) -> u128 {
        assert!(a < (1u64 << self.width_a) && b < (1u64 << self.width_b));
        let mut acc: u128 = 0;
        for i in 0..self.width_b {
            if (b >> i) & 1 == 1 {
                acc = add_shifted(acc, a, i);
            }
        }
        acc
    }

    /// Area/depth: `a*b` AND gates + `(b-1)` rows of `a` full adders —
    /// linear in the short dimension.
    pub fn cost(&self) -> UnitCost {
        let a = self.width_a as f64;
        let b = self.width_b as f64;
        let gates = a * b + (b - 1.0).max(0.0) * a * 5.0;
        let depth = 2.0 * (b + a.log2());
        UnitCost { gates, depth }
    }
}

/// Limb-sliced multiplier: an `n x n` product built from 32-bit
/// multiplier tiles plus a carry-chain adder tree — the hardware image
/// of the software formulation in [`crate::arith::limb`] (and of SIMD
/// widening-multiply units, which are exactly such tiles). For
/// `width <= 32` a single tile computes the whole product (the
/// half-precision planes); wider words use the 2x2 tile array with the
/// same explicit carry chain the lane loops run.
#[derive(Clone, Copy, Debug)]
pub struct LimbSlicedMultiplier {
    width: u32,
}

impl LimbSlicedMultiplier {
    /// New model for `width`-bit operands (<= 64).
    pub fn new(width: u32) -> Self {
        assert!((1..=64).contains(&width));
        Self { width }
    }

    /// Tiles along one operand dimension (1 for a single-limb word).
    pub fn limbs(&self) -> u32 {
        self.width.div_ceil(crate::arith::limb::LIMB_BITS)
    }
}

impl MultiplierModel for LimbSlicedMultiplier {
    fn width(&self) -> u32 {
        self.width
    }

    fn multiply(&self, a: u64, b: u64) -> u128 {
        if self.width < 64 {
            assert!(a < (1u64 << self.width) && b < (1u64 << self.width));
        }
        // the exact limb formulation the datapath multiplies run
        let (lo, hi) = crate::arith::limb::widening_mul(a, b);
        ((hi as u128) << 64) | lo as u128
    }

    fn cost(&self) -> UnitCost {
        // limbs^2 32-bit booth-wallace tiles + the carry-chain adders
        // merging the partial columns (three 64-bit additions per extra
        // tile row, ~5 GE per full-adder bit)
        let tile = BoothWallaceMultiplier::new(crate::arith::limb::LIMB_BITS).cost();
        let k = self.limbs() as f64;
        let merge_gates = if k > 1.0 { (k * k - 1.0) * 64.0 * 5.0 } else { 0.0 };
        UnitCost {
            gates: k * k * tile.gates + merge_gates,
            // tiles run in parallel; the merge chain adds log-depth CLAs
            depth: tile.depth + if k > 1.0 { 2.0 * 64f64.log2() } else { 0.0 },
        }
    }

    fn name(&self) -> &'static str {
        "limb-sliced"
    }
}

fn add_shifted(acc: u128, a: u64, shift: u32) -> u128 {
    acc + ((a as u128) << shift)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn array_small_products() {
        let m = ArrayMultiplier::new(8);
        assert_eq!(m.multiply(0, 0), 0);
        assert_eq!(m.multiply(255, 255), 255 * 255);
        assert_eq!(m.multiply(13, 17), 221);
    }

    #[test]
    fn array_matches_native_property() {
        check::property("array mult == native", |g| {
            let w = g.usize_in(2, 60) as u32;
            let a = g.u64_below(1u64 << w);
            let b = g.u64_below(1u64 << w);
            let m = ArrayMultiplier::new(w);
            ensure(
                m.multiply(a, b) == (a as u128) * (b as u128),
                format!("w={w} a={a} b={b}"),
            )
        });
    }

    #[test]
    fn booth_matches_native_property() {
        check::property("booth-wallace mult == native", |g| {
            let w = g.usize_in(2, 60) as u32;
            let a = g.u64_below(1u64 << w);
            let b = g.u64_below(1u64 << w);
            let m = BoothWallaceMultiplier::new(w);
            ensure(
                m.multiply(a, b) == (a as u128) * (b as u128),
                format!("w={w} a={a} b={b}"),
            )
        });
    }

    #[test]
    fn booth_edge_patterns() {
        let m = BoothWallaceMultiplier::new(32);
        for &a in &[0u64, 1, 0xFFFF_FFFF, 0x8000_0000, 0x5555_5555, 0xAAAA_AAAA] {
            for &b in &[0u64, 1, 0xFFFF_FFFF, 0x8000_0000, 0x5555_5555] {
                assert_eq!(m.multiply(a, b), (a as u128) * (b as u128), "{a:#x}*{b:#x}");
            }
        }
    }

    #[test]
    fn booth_recoding_digit_range() {
        let m = BoothWallaceMultiplier::new(16);
        for b in [0u64, 1, 0xFFFF, 0x8001, 0x5555] {
            for d in m.recode(b) {
                assert!((-2..=2).contains(&d));
            }
        }
    }

    #[test]
    fn costs_scale_with_width() {
        let small = BoothWallaceMultiplier::new(12).cost();
        let big = BoothWallaceMultiplier::new(24).cost();
        assert!(big.gates > 2.0 * small.gates, "quadratic-ish growth");
        assert!(big.depth > small.depth);
        // booth-wallace is faster (shallower) than the ripple array
        let arr = ArrayMultiplier::new(24).cost();
        let bw = BoothWallaceMultiplier::new(24).cost();
        assert!(bw.depth < arr.depth);
    }

    #[test]
    fn rectangular_matches_native_property() {
        check::property("rectangular mult == native", |g| {
            let wa = g.usize_in(2, 60) as u32;
            let wb = g.usize_in(1, 20) as u32;
            let a = g.u64_below(1u64 << wa);
            let b = g.u64_below(1u64 << wb);
            let m = RectangularMultiplier::new(wa, wb);
            ensure(
                m.multiply(a, b) == (a as u128) * (b as u128),
                format!("wa={wa} wb={wb} a={a} b={b}"),
            )
        });
    }

    #[test]
    fn rectangular_is_much_smaller_when_short() {
        // 32x8 rectangular vs 32x32 square: ~4x fewer gates
        let rect = RectangularMultiplier::new(32, 8).cost();
        let square = ArrayMultiplier::new(32).cost();
        assert!(rect.gates < square.gates / 3.0);
    }

    #[test]
    fn names() {
        assert_eq!(ArrayMultiplier::new(8).name(), "array");
        assert_eq!(BoothWallaceMultiplier::new(8).name(), "booth-wallace");
        assert_eq!(LimbSlicedMultiplier::new(22).name(), "limb-sliced");
    }

    #[test]
    fn limb_sliced_matches_native_property() {
        check::property("limb-sliced mult == native", |g| {
            let w = g.usize_in(1, 65) as u32; // 1..=64: the full-word case included
            let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
            let a = g.bits() & mask;
            let b = g.bits() & mask;
            let m = LimbSlicedMultiplier::new(w);
            ensure(
                m.multiply(a, b) == (a as u128) * (b as u128),
                format!("w={w} a={a:#x} b={b:#x}"),
            )
        });
    }

    #[test]
    fn limb_sliced_tile_counts_and_costs() {
        // a Q2.20 word (22 bits) is a single tile; a Q2.58 word (60
        // bits) needs the 2x2 array — 4x the tiles plus merge adders
        let half = LimbSlicedMultiplier::new(22);
        let double = LimbSlicedMultiplier::new(60);
        assert_eq!(half.limbs(), 1);
        assert_eq!(double.limbs(), 2);
        assert!(double.cost().gates > 3.9 * half.cost().gates);
        assert!(double.cost().depth > half.cost().depth);
    }
}
