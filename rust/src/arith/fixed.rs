//! Unsigned fixed-point `Q2.f`: the Goldschmidt datapath word.
//!
//! All values flowing through the paper's datapath live in `[0, 4)`:
//! mantissas in `[1, 2)`, products `q_i, r_i` in `(1/2, 2)`, and the
//! complement constants `K_i = 2 - r_i` near 1. A `Fixed` stores the
//! value as `bits / 2^frac` with 2 integer bits, so `frac + 2 <= 64`
//! (fraction widths up to 62 bits, covering every guard-bit setting the
//! experiments sweep).
//!
//! Multiplication produces a `2*frac`-bit exact product in `u128` and
//! rounds back to the result width under a selectable [`Rounding`] mode —
//! exactly what a hardware multiplier + output register does.

/// Rounding mode applied when a wide product is narrowed back to the
/// datapath width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Drop low bits (hardware-cheapest; biased toward zero).
    Truncate,
    /// Round half up (adds the 0.5-ulp constant before dropping bits).
    Nearest,
}

/// An unsigned fixed-point value with 2 integer bits and `frac` fraction
/// bits: `value = bits / 2^frac`, `0 <= value < 4`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fixed {
    bits: u64,
    frac: u32,
}

/// Largest bit pattern of a `Q2.frac` word: `2^(frac+2) - 1`, computed
/// without the `1 << 64` overflow that the naive form hits at
/// `frac == Fixed::MAX_FRAC` (a full 64-bit word).
#[inline]
pub(crate) fn q2_max(frac: u32) -> u64 {
    debug_assert!(frac <= Fixed::MAX_FRAC);
    u64::MAX >> (Fixed::MAX_FRAC - frac)
}

/// Narrow a wide product by `shift` bits under a rounding mode,
/// returning the full-width result (callers saturate to their word
/// before casting down, so an out-of-range product clamps instead of
/// silently wrapping through a `u64` cast). The `Nearest` half-ulp
/// constant is `2^(shift-1)`, which is well-defined only for
/// `shift >= 1`; at `shift == 0` nothing is dropped, so the value
/// passes through unchanged (the old `1 << (shift - 1)` form was
/// shift-underflow UB at zero).
#[inline]
pub(crate) fn narrow_u128(wide: u128, shift: u32, mode: Rounding) -> u128 {
    match mode {
        Rounding::Truncate => wide >> shift,
        Rounding::Nearest => {
            if shift == 0 {
                wide
            } else {
                // wide <= (2^64-1)^2 leaves headroom for the half-ulp add
                (wide + (1u128 << (shift - 1))) >> shift
            }
        }
    }
}

impl Fixed {
    /// Maximum supported fraction width.
    pub const MAX_FRAC: u32 = 62;

    /// From raw bits (must fit in 2 integer + `frac` fraction bits).
    pub fn from_bits(bits: u64, frac: u32) -> Self {
        assert!(frac <= Self::MAX_FRAC, "frac {frac} > {}", Self::MAX_FRAC);
        assert!(bits <= q2_max(frac), "bits {bits:#x} out of Q2.{frac} range");
        Self { bits, frac }
    }

    /// Round-to-nearest conversion from f64 (panics outside `[0, 4)`).
    pub fn from_f64(x: f64, frac: u32) -> Self {
        assert!(frac <= Self::MAX_FRAC);
        assert!((0.0..4.0).contains(&x), "{x} out of [0,4)");
        let scaled = (x * (1u64 << frac) as f64).round() as u64;
        // x*2^frac may round up to exactly 4.0*2^frac; clamp into range
        Self { bits: scaled.min(q2_max(frac)), frac }
    }

    /// The constant 1.0 at the given fraction width.
    pub fn one(frac: u32) -> Self {
        Self::from_bits(1u64 << frac, frac)
    }

    /// The constant 2.0 at the given fraction width.
    pub fn two(frac: u32) -> Self {
        Self::from_bits(1u64 << (frac + 1), frac)
    }

    /// Raw bits.
    pub fn bits(&self) -> u64 {
        self.bits
    }

    /// Fraction width.
    pub fn frac(&self) -> u32 {
        self.frac
    }

    /// Exact conversion to f64 (frac <= 62 keeps this exact: bits < 2^64
    /// and f64 has enough range; values < 4 need at most 64 significand
    /// bits — not exact in general! — so we document: exact for
    /// frac <= 51, otherwise correctly rounded).
    pub fn to_f64(&self) -> f64 {
        self.bits as f64 / (1u64 << self.frac) as f64
    }

    /// Change fraction width, rounding if narrowing.
    pub fn with_frac(&self, frac: u32, mode: Rounding) -> Self {
        assert!(frac <= Self::MAX_FRAC);
        if frac >= self.frac {
            Self { bits: self.bits << (frac - self.frac), frac }
        } else {
            // shift >= 1 here; the u128 widening keeps the Nearest
            // half-ulp add overflow-free even for full 64-bit words
            let shift = self.frac - frac;
            let bits = narrow_u128(self.bits as u128, shift, mode);
            Self { bits: bits.min(q2_max(frac) as u128) as u64, frac }
        }
    }

    /// Exact wide multiply, then narrow to `self.frac` under `mode`.
    /// Both operands must share a fraction width (as datapath wires do).
    ///
    /// Formulated on the 32-bit-limb layer ([`crate::arith::limb`]):
    /// four widening `u32 x u32 -> u64` products with explicit carries
    /// instead of one `u64 x u64 -> u128` — bit-identical to the `u128`
    /// reference (property-tested both here and in `limb`), but built
    /// from the primitive SIMD units actually have.
    pub fn mul(&self, rhs: &Fixed, mode: Rounding) -> Self {
        assert_eq!(self.frac, rhs.frac, "mixed fraction widths");
        let sat = q2_max(self.frac);
        let bits = match mode {
            Rounding::Nearest => {
                crate::arith::limb::mul_q2_u64::<true>(self.bits, rhs.bits, self.frac, sat)
            }
            Rounding::Truncate => {
                crate::arith::limb::mul_q2_u64::<false>(self.bits, rhs.bits, self.frac, sat)
            }
        };
        Self { bits, frac: self.frac }
    }

    /// Exact `2 - self` (the paper's two's-complement block output).
    /// Requires `self <= 2`.
    pub fn two_minus(&self) -> Self {
        let two = 1u64 << (self.frac + 1);
        assert!(self.bits <= two, "two_minus of value > 2");
        Self { bits: two - self.bits, frac: self.frac }
    }

    /// One's-complement approximation of `2 - self`: bitwise NOT of the
    /// fraction+integer field modulo 4, i.e. `2 - self - ulp` for
    /// `self in (0, 2]`. This is the carry-free hardware shortcut EIMMW
    /// notes; it under-shoots by exactly one ulp.
    pub fn two_minus_ones_complement(&self) -> Self {
        let mask = q2_max(self.frac);
        let two = 1u64 << (self.frac + 1);
        assert!(self.bits <= two && self.bits > 0);
        // (2 - x - ulp) mod 4 == NOT(x) truncated to the word, for x<=2
        let bits = (two.wrapping_sub(self.bits).wrapping_sub(1)) & mask;
        Self { bits, frac: self.frac }
    }

    /// Saturating add (datapath adders saturate rather than wrap).
    pub fn add(&self, rhs: &Fixed) -> Self {
        assert_eq!(self.frac, rhs.frac);
        Self { bits: self.bits.saturating_add(rhs.bits).min(q2_max(self.frac)), frac: self.frac }
    }

    /// Subtract (panics on underflow — the datapath never goes negative).
    pub fn sub(&self, rhs: &Fixed) -> Self {
        assert_eq!(self.frac, rhs.frac);
        assert!(self.bits >= rhs.bits, "fixed-point underflow");
        Self { bits: self.bits - rhs.bits, frac: self.frac }
    }

    /// Absolute difference in ulps at this width.
    pub fn ulp_diff(&self, rhs: &Fixed) -> u64 {
        assert_eq!(self.frac, rhs.frac);
        self.bits.abs_diff(rhs.bits)
    }
}

impl std::fmt::Display for Fixed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.*}", (self.frac as usize / 3) + 1, self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn roundtrip_f64() {
        for &x in &[0.0, 0.5, 1.0, 1.5, 1.999999, 3.75] {
            let f = Fixed::from_f64(x, 30);
            assert!((f.to_f64() - x).abs() < 1e-9, "{x}");
        }
    }

    #[test]
    fn constants() {
        assert_eq!(Fixed::one(10).to_f64(), 1.0);
        assert_eq!(Fixed::two(10).to_f64(), 2.0);
        assert_eq!(Fixed::one(10).bits(), 1 << 10);
    }

    #[test]
    #[should_panic(expected = "out of [0,4)")]
    fn from_f64_range_checked() {
        Fixed::from_f64(4.0, 10);
    }

    #[test]
    #[should_panic(expected = "out of Q2")]
    fn from_bits_range_checked() {
        Fixed::from_bits(1 << 13, 10); // 8.0 in Q2.10
    }

    #[test]
    fn mul_exact_small() {
        let a = Fixed::from_f64(1.5, 20);
        let b = Fixed::from_f64(1.25, 20);
        let p = a.mul(&b, Rounding::Nearest);
        assert!((p.to_f64() - 1.875).abs() < 1e-6);
    }

    #[test]
    fn mul_matches_integer_reference() {
        check::property("fixed mul == u128 reference", |g| {
            let frac = g.usize_in(8, 52) as u32;
            let a_bits = g.u64_below(1u64 << (frac + 1)); // values < 2
            let b_bits = g.u64_below(1u64 << (frac + 1));
            let a = Fixed::from_bits(a_bits, frac);
            let b = Fixed::from_bits(b_bits, frac);
            let got = a.mul(&b, Rounding::Truncate).bits();
            let want = ((a_bits as u128 * b_bits as u128) >> frac) as u64;
            ensure(got == want, format!("frac={frac} a={a_bits} b={b_bits}"))
        });
    }

    #[test]
    fn nearest_vs_truncate_differ_by_at_most_one() {
        check::property("rounding modes within 1 ulp", |g| {
            let frac = g.usize_in(4, 50) as u32;
            let a = Fixed::from_bits(g.u64_below(1u64 << (frac + 1)), frac);
            let b = Fixed::from_bits(g.u64_below(1u64 << (frac + 1)), frac);
            let t = a.mul(&b, Rounding::Truncate).bits();
            let n = a.mul(&b, Rounding::Nearest).bits();
            ensure(n == t || n == t + 1, format!("t={t} n={n}"))
        });
    }

    #[test]
    fn two_minus_exact() {
        let r = Fixed::from_f64(0.999, 30);
        let k = r.two_minus();
        assert!((k.to_f64() - 1.001).abs() < 1e-8);
        // identity: r + (2 - r) == 2
        assert_eq!(r.add(&k).bits(), Fixed::two(30).bits());
    }

    #[test]
    fn twos_complement_identity_property() {
        check::property("r + (2-r) == 2", |g| {
            let frac = g.usize_in(4, 60) as u32;
            let bits = g.u64_below((1u64 << (frac + 1)) + 1);
            let r = Fixed::from_bits(bits, frac);
            let k = r.two_minus();
            ensure(
                r.add(&k).bits() == Fixed::two(frac).bits(),
                format!("frac={frac} bits={bits}"),
            )
        });
    }

    #[test]
    fn ones_complement_is_one_ulp_low() {
        check::property("ones-complement = exact - 1 ulp", |g| {
            let frac = g.usize_in(4, 60) as u32;
            let bits = 1 + g.u64_below(1u64 << (frac + 1));
            let r = Fixed::from_bits(bits, frac);
            let exact = r.two_minus().bits();
            let approx = r.two_minus_ones_complement().bits();
            ensure(
                approx == exact.wrapping_sub(1),
                format!("frac={frac} bits={bits} exact={exact} approx={approx}"),
            )
        });
    }

    #[test]
    fn with_frac_widen_narrow() {
        let a = Fixed::from_f64(1.2345678, 40);
        let w = a.with_frac(50, Rounding::Nearest);
        assert_eq!(w.frac(), 50);
        assert!((w.to_f64() - a.to_f64()).abs() < 1e-12);
        let n = a.with_frac(10, Rounding::Nearest);
        assert!((n.to_f64() - 1.2345678).abs() < 1e-3);
    }

    #[test]
    fn sub_and_ulp_diff() {
        let a = Fixed::from_bits(1000, 10);
        let b = Fixed::from_bits(990, 10);
        assert_eq!(a.sub(&b).bits(), 10);
        assert_eq!(a.ulp_diff(&b), 10);
        assert_eq!(b.ulp_diff(&a), 10);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        Fixed::from_bits(1, 10).sub(&Fixed::from_bits(2, 10));
    }

    #[test]
    fn add_saturates() {
        let max = Fixed::from_bits((1 << 12) - 1, 10);
        let one = Fixed::one(10);
        assert_eq!(max.add(&one).bits(), (1 << 12) - 1);
    }

    // ---- rounding-shift regression tests at boundary widths ----------
    //
    // frac == 1 narrows to frac == 0 (shift hits the Nearest half-ulp
    // minimum), frac == 51 is the widest exact-f64 width, frac == 62 is
    // MAX_FRAC where the word occupies all 64 bits and the naive
    // `1 << (frac + 2)` bound / `bits + half` add both overflow.

    #[test]
    fn mul_nearest_well_defined_at_zero_shift() {
        // frac == 0: the product keeps all bits; Nearest must not
        // compute `1 << (0 - 1)`
        let a = Fixed::from_bits(3, 0); // 3.0 in Q2.0
        let b = Fixed::from_bits(1, 0); // 1.0
        assert_eq!(a.mul(&b, Rounding::Nearest).bits(), 3);
        assert_eq!(a.mul(&b, Rounding::Truncate).bits(), 3);
        // 3.0 * 3.0 = 9.0 saturates to the Q2.0 max (3)
        assert_eq!(a.mul(&a, Rounding::Nearest).bits(), 3);
    }

    #[test]
    fn boundary_width_frac1() {
        let a = Fixed::from_bits(3, 1); // 1.5 in Q2.1
        let p = a.mul(&a, Rounding::Nearest); // 2.25 -> rounds at 1 bit
        assert_eq!(p.bits(), 5, "1.5^2 = 2.25 -> 2.5 (round half up)");
        assert_eq!(a.mul(&a, Rounding::Truncate).bits(), 4); // -> 2.0
        // narrowing 1 -> 0 exercises shift == 1 in with_frac
        assert_eq!(a.with_frac(0, Rounding::Nearest).bits(), 2);
        assert_eq!(a.with_frac(0, Rounding::Truncate).bits(), 1);
    }

    #[test]
    fn boundary_width_frac51() {
        let frac = 51u32;
        let a = Fixed::from_bits((1u64 << frac) | 1, frac); // 1 + ulp
        let b = Fixed::from_bits(3u64 << (frac - 1), frac); // 1.5
        let want = ((a.bits() as u128 * b.bits() as u128) >> frac) as u64;
        assert_eq!(a.mul(&b, Rounding::Truncate).bits(), want);
        let n = a.mul(&b, Rounding::Nearest).bits();
        assert!(n == want || n == want + 1);
    }

    #[test]
    fn boundary_width_frac62_no_overflow() {
        let frac = Fixed::MAX_FRAC;
        // the largest representable word: bits == u64::MAX (just under 4.0)
        let max = Fixed::from_bits(u64::MAX, frac);
        assert_eq!(max.frac(), frac);
        // saturating ops at the top of the range must not wrap or panic
        assert_eq!(max.add(&Fixed::one(frac)).bits(), u64::MAX);
        let sq = max.mul(&max, Rounding::Nearest);
        assert_eq!(sq.bits(), u64::MAX, "(~4)^2 saturates");
        // narrowing the full 64-bit word rounds without overflowing the
        // half-ulp add (the old `bits + (1 << (shift-1))` form wrapped)
        let narrowed = max.with_frac(30, Rounding::Nearest);
        assert_eq!(narrowed.bits(), (1u64 << 32) - 1, "saturates at Q2.30 max");
        // 2.0 survives a 62 -> 51 -> 62 round-trip exactly
        let two = Fixed::two(frac);
        let back = two.with_frac(51, Rounding::Nearest).with_frac(frac, Rounding::Nearest);
        assert_eq!(back.bits(), two.bits());
    }

    #[test]
    fn with_frac_nearest_matches_u128_reference() {
        check::property("with_frac nearest == u128 round-half-up", |g| {
            let from = g.usize_in(1, 63) as u32;
            let to = g.usize_in(0, from as usize) as u32;
            let bits = g.u64_below(q2_max(from));
            let a = Fixed::from_bits(bits, from);
            let shift = from - to;
            let want =
                (((bits as u128 + (1u128 << (shift - 1))) >> shift) as u64).min(q2_max(to));
            ensure(
                a.with_frac(to, Rounding::Nearest).bits() == want,
                format!("from={from} to={to} bits={bits:#x}"),
            )
        });
    }
}
