//! Always-on service metrics: counters and latency histograms, shared
//! between workers and readable while the service runs. Sliced per
//! (op, format) — the same key the router queues and batch planes use —
//! with per-op aggregates for the headline numbers.
//!
//! The v2 request plane distinguishes outcomes, so the metrics do too:
//! `requests` counts completed lanes, `errors` counts lanes failed
//! after batching — backend execution failures (delivered to clients
//! as [`ServiceError::ExecFailed`](super::request::ServiceError)) and
//! the rare total-worker-loss path (delivered as `Shutdown`) — and
//! `shed` counts lanes dropped by deadline expiry before execution.

use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};
use std::sync::Mutex;

use crate::util::stats::{LogHistogram, RateWindow};

use super::request::{op_format_slot, FormatKind, OpKind, OP_FORMAT_SLOTS};

const SLOTS: usize = OP_FORMAT_SLOTS;

/// Per-(op, format) slice of the metrics.
#[derive(Clone, Debug, Default)]
struct SliceMetrics {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    live_slots: u64,
    latency: LogHistogram,
    batch_exec_ns: LogHistogram,
    errors: u64,
    shed: u64,
    admission_rejected: u64,
    /// Would-reject submissions seen by admission control (drives the
    /// 1-in-N probe that keeps a rejecting slot able to recover).
    admission_probes: u64,
    /// Per-batch `(exec_ns, live lanes)` service-rate window: the
    /// queue-delay model reads `sum(exec_ns) / sum(lanes)` over the
    /// last `RECENT_WINDOW` batches, so the rate **decays** as the
    /// service recovers — a cumulative histogram would let one
    /// overload burst poison admission control forever.
    rate: RateWindow<RECENT_WINDOW>,
}

/// Batches a slice must have completed before its service-rate window
/// is trusted as a queue-delay model (admission control stays out of
/// the way on a cold service).
const ADMISSION_MIN_BATCHES: usize = 4;

/// Recent-batch window size backing the service-rate estimate.
const RECENT_WINDOW: usize = 32;

/// Every `N`-th would-reject submission is admitted anyway as a probe.
const ADMISSION_PROBE_PERIOD: u64 = 16;

/// Shared metrics sink (interior mutability; cheap enough for the
/// per-batch hot path — one lock per *batch*, not per request; the
/// queue-depth gauges are plain atomics, touched once per submission
/// and once per batch formation).
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<[SliceMetrics; SLOTS]>,
    /// Per-slot queued-lane gauge: incremented at submit (when a work
    /// item enters the bounded queue), decremented when its lanes are
    /// drained into a batch (or shed). This mirrors the router's lane
    /// counts — plus the submit-channel backlog the router has not
    /// seen yet, which is exactly what makes burst tracking prompt.
    depth: [AtomicI64; SLOTS],
    /// Per-slot serving-pool worker count (default 1), set once at
    /// service start from the routed pool sizes. The queue-delay model
    /// divides by it: `w` workers drain a slot's queue `w` times
    /// faster than the per-batch service rate alone suggests, and
    /// without the divisor a multi-worker pool sheds far too early.
    workers: [AtomicU32; SLOTS],
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

fn idx(op: OpKind, format: FormatKind) -> usize {
    op_format_slot(op, format)
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(std::array::from_fn(|_| SliceMetrics::default())),
            depth: std::array::from_fn(|_| AtomicI64::new(0)),
            workers: std::array::from_fn(|_| AtomicU32::new(1)),
        }
    }

    /// Set the worker-pool size serving one (op, format) slot (the
    /// preferred backend's pool; clamped to at least 1). Called once at
    /// service start — the queue-delay model divides by it.
    pub fn set_slot_workers(&self, op: OpKind, format: FormatKind, workers: usize) {
        self.workers[idx(op, format)].store(workers.max(1) as u32, Ordering::Relaxed);
    }

    /// Record one executed batch. `latencies_ns` carries one entry per
    /// work item: `(end-to-end latency, lanes at that latency)` — a
    /// vectored submission's lanes share an enqueue timestamp, so they
    /// weight the histogram without per-lane recording.
    pub fn record_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        latencies_ns: &[(u64, usize)],
        exec_ns: u64,
        padded: usize,
    ) {
        let lanes: u64 = latencies_ns.iter().map(|&(_, n)| n as u64).sum();
        let mut m = self.inner.lock().expect("metrics poisoned");
        let s = &mut m[idx(op, format)];
        s.requests += lanes;
        s.batches += 1;
        s.live_slots += lanes;
        s.padded_slots += padded as u64;
        s.batch_exec_ns.record(exec_ns);
        for &(l, n) in latencies_ns {
            s.latency.record_n(l, n as u64);
        }
        // the admission model tracks the slot's service rate: how many
        // nanoseconds of executor time one lane costs, windowed
        s.rate.push(exec_ns, lanes);
    }

    /// Record a failed batch (all its lanes error out).
    pub fn record_error(&self, op: OpKind, format: FormatKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op, format)].errors += count;
    }

    /// Record lanes shed by deadline expiry (never executed).
    pub fn record_shed(&self, op: OpKind, format: FormatKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op, format)].shed += count;
    }

    /// Record lanes rejected by deadline admission control (never
    /// queued — distinct from `shed`, which counts work admitted and
    /// then expired in the queue).
    pub fn record_admission_reject(&self, op: OpKind, format: FormatKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op, format)].admission_rejected += count;
    }

    /// Record lanes entering the queue (submit time). Paired with
    /// [`Self::record_dequeued`] at batch formation, this keeps the
    /// per-slot queued-lane gauge the admission model multiplies by
    /// the service rate.
    pub fn record_enqueued(&self, op: OpKind, format: FormatKind, lanes: u64) {
        self.depth[idx(op, format)].fetch_add(lanes as i64, Ordering::Relaxed);
    }

    /// Record lanes leaving the queue (drained into a batch or shed).
    /// Every dequeue must be covered by a prior enqueue — lanes are
    /// enqueued *before* they can reach the router, so an underflowing
    /// gauge means double-counted dequeues, not a benign interleaving.
    pub fn record_dequeued(&self, op: OpKind, format: FormatKind, lanes: u64) {
        let prev = self.depth[idx(op, format)].fetch_sub(lanes as i64, Ordering::Relaxed);
        debug_assert!(
            prev >= lanes as i64,
            "queued-lane gauge underflow: dequeued {lanes} lanes at depth {prev}"
        );
    }

    /// Currently queued lanes for one (op, format) slot (submit queue +
    /// router backlog; clamped at zero in release builds as a
    /// belt-and-braces guard — see [`Self::record_dequeued`]).
    pub fn queued_lanes(&self, op: OpKind, format: FormatKind) -> u64 {
        self.depth[idx(op, format)].load(Ordering::Relaxed).max(0) as u64
    }

    /// Queue-delay estimate for one (op, format) slot, in nanoseconds:
    /// a **queue-depth × service-rate model** — the lanes currently
    /// queued ahead (the gauge fed by submit/batch-formation, mirroring
    /// the router's lane counts) times the windowed executor cost per
    /// lane over the slot's last `RECENT_WINDOW` batches, divided by
    /// the serving pool's worker count (`w` workers drain the queue in
    /// parallel, so a lane waits `depth × rate / w`, not
    /// `depth × rate`). Bursts move the estimate the instant they are
    /// *queued*, not a latency-window later; and an idle slot estimates
    /// ~zero delay no matter how slow its history was, so recovery is
    /// immediate. `None` until a minimum number of batches
    /// (`ADMISSION_MIN_BATCHES`, currently 4) have fed the rate window,
    /// so admission control never rejects on a cold slot. Reads one
    /// slice under the lock — cheap enough for the deadline-submit path
    /// (deadline-free submits never call it).
    pub fn queue_delay_estimate_ns(&self, op: OpKind, format: FormatKind) -> Option<u64> {
        let depth = self.queued_lanes(op, format);
        let workers = self.workers[idx(op, format)].load(Ordering::Relaxed).max(1);
        let m = self.inner.lock().expect("metrics poisoned");
        let s = &m[idx(op, format)];
        if s.rate.len() < ADMISSION_MIN_BATCHES {
            return None;
        }
        Some((depth as f64 * s.rate.ns_per_lane()? / workers as f64) as u64)
    }

    /// Admission probe gate, called for each submission the estimate
    /// says to reject: every `ADMISSION_PROBE_PERIOD`-th would-reject
    /// is admitted anyway (returns `true`). The probes keep fresh
    /// service-rate samples flowing through a rejecting slot, so a
    /// stale rate window gets re-measured and full admission resumes —
    /// without the probe, a slot whose traffic is all deadline-gated
    /// could reject forever on stale signal.
    pub fn admission_probe(&self, op: OpKind, format: FormatKind) -> bool {
        let mut m = self.inner.lock().expect("metrics poisoned");
        let s = &mut m[idx(op, format)];
        s.admission_probes += 1;
        s.admission_probes % ADMISSION_PROBE_PERIOD == 0
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().expect("metrics poisoned");
        build_snapshot(&m)
    }

    /// Merged snapshot over several metrics instances — one per
    /// coordinator shard. Counters sum and latency/exec histograms
    /// merge exactly (log-bucket histograms are additive), so the
    /// merged percentiles are what a single global histogram would have
    /// recorded. The admission rate windows and queue-depth gauges stay
    /// per-shard: admission control runs on the shard that owns the
    /// submission, so merging them would model a queue no request ever
    /// waits in.
    pub fn merged_snapshot<'a, I>(parts: I) -> MetricsSnapshot
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut merged: [SliceMetrics; SLOTS] = std::array::from_fn(|_| SliceMetrics::default());
        for m in parts {
            let g = m.inner.lock().expect("metrics poisoned");
            for (dst, src) in merged.iter_mut().zip(g.iter()) {
                merge_slice(dst, src);
            }
        }
        build_snapshot(&merged)
    }
}

/// Accumulate one shard's (op, format) slice into a merge target.
/// Everything additive merges; the rate window is deliberately left
/// alone (see [`Metrics::merged_snapshot`]).
fn merge_slice(dst: &mut SliceMetrics, src: &SliceMetrics) {
    dst.requests += src.requests;
    dst.batches += src.batches;
    dst.padded_slots += src.padded_slots;
    dst.live_slots += src.live_slots;
    dst.errors += src.errors;
    dst.shed += src.shed;
    dst.admission_rejected += src.admission_rejected;
    dst.admission_probes += src.admission_probes;
    dst.latency.merge(&src.latency);
    dst.batch_exec_ns.merge(&src.batch_exec_ns);
}

/// Build the reporting snapshot from a slice array (a single instance's
/// slices under its lock, or a cross-shard merge).
fn build_snapshot(m: &[SliceMetrics; SLOTS]) -> MetricsSnapshot {
    let snap_of = |s: &SliceMetrics| OpSnapshotBody {
        requests: s.requests,
        batches: s.batches,
        errors: s.errors,
        shed: s.shed,
        admission_rejected: s.admission_rejected,
        mean_latency_ns: s.latency.mean(),
        p50_latency_ns: s.latency.quantile(0.5),
        p99_latency_ns: s.latency.quantile(0.99),
        mean_exec_ns: s.batch_exec_ns.mean(),
        occupancy: if s.padded_slots == 0 {
            1.0
        } else {
            s.live_slots as f64 / s.padded_slots as f64
        },
    };
    let mut op_formats = Vec::with_capacity(SLOTS);
    let mut ops = Vec::with_capacity(OpKind::ALL.len());
    for &op in &OpKind::ALL {
        // aggregate the op's format slices (histograms merge exactly)
        let mut agg = SliceMetrics::default();
        for &format in &FormatKind::ALL {
            let s = &m[idx(op, format)];
            merge_slice(&mut agg, s);
            op_formats.push(OpFormatSnapshot { op, format, body: snap_of(s) });
        }
        ops.push(OpSnapshot { op, body: snap_of(&agg) });
    }
    MetricsSnapshot { ops, op_formats }
}

/// The measured quantities shared by per-op and per-(op, format)
/// snapshots.
#[derive(Clone, Copy, Debug)]
pub struct OpSnapshotBody {
    /// Lanes completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Lanes failed after batching (backend execution failure, or
    /// worker loss at dispatch).
    pub errors: u64,
    /// Lanes shed by deadline expiry (never executed).
    pub shed: u64,
    /// Lanes rejected by deadline admission control at submit time
    /// (never queued).
    pub admission_rejected: u64,
    /// Mean end-to-end latency (ns).
    pub mean_latency_ns: f64,
    /// Median end-to-end latency (ns, bucket upper edge).
    pub p50_latency_ns: u64,
    /// p99 end-to-end latency (ns, bucket upper edge).
    pub p99_latency_ns: u64,
    /// Mean executor time per batch (ns).
    pub mean_exec_ns: f64,
    /// Live/padded slot occupancy (1.0 = no padding waste).
    pub occupancy: f64,
}

/// One op's aggregate snapshot (all formats merged).
#[derive(Clone, Copy, Debug)]
pub struct OpSnapshot {
    /// Which op.
    pub op: OpKind,
    /// The measurements.
    pub body: OpSnapshotBody,
}

impl std::ops::Deref for OpSnapshot {
    type Target = OpSnapshotBody;
    fn deref(&self) -> &OpSnapshotBody {
        &self.body
    }
}

/// One (op, format) slice's snapshot.
#[derive(Clone, Copy, Debug)]
pub struct OpFormatSnapshot {
    /// Which op.
    pub op: OpKind,
    /// Which format.
    pub format: FormatKind,
    /// The measurements.
    pub body: OpSnapshotBody,
}

impl std::ops::Deref for OpFormatSnapshot {
    type Target = OpSnapshotBody;
    fn deref(&self) -> &OpSnapshotBody {
        &self.body
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Per-op aggregates in [`OpKind::ALL`] order.
    pub ops: Vec<OpSnapshot>,
    /// Per-(op, format) slices, ops-major in [`OpKind::ALL`] x
    /// [`FormatKind::ALL`] order.
    pub op_formats: Vec<OpFormatSnapshot>,
}

impl MetricsSnapshot {
    /// Aggregate snapshot for one op (all formats).
    pub fn op(&self, op: OpKind) -> &OpSnapshot {
        self.ops.iter().find(|s| s.op == op).expect("all ops present")
    }

    /// Snapshot for one (op, format) slice.
    pub fn op_format(&self, op: OpKind, format: FormatKind) -> &OpFormatSnapshot {
        self.op_formats
            .iter()
            .find(|s| s.op == op && s.format == format)
            .expect("all slices present")
    }

    /// Total completed lanes.
    pub fn total_requests(&self) -> u64 {
        self.ops.iter().map(|s| s.requests).sum()
    }

    /// Total errors.
    pub fn total_errors(&self) -> u64 {
        self.ops.iter().map(|s| s.errors).sum()
    }

    /// Total deadline-shed lanes.
    pub fn total_shed(&self) -> u64 {
        self.ops.iter().map(|s| s.shed).sum()
    }

    /// Total admission-rejected lanes.
    pub fn total_admission_rejected(&self) -> u64 {
        self.ops.iter().map(|s| s.admission_rejected).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(OpKind::Divide, F32, &[(1000, 1), (2000, 1), (3000, 1)], 500, 4);
        m.record_batch(OpKind::Divide, F32, &[(1500, 1)], 400, 64);
        m.record_batch(OpKind::Sqrt, F32, &[(800, 1)], 300, 1);
        let s = m.snapshot();
        assert_eq!(s.op(OpKind::Divide).requests, 4);
        assert_eq!(s.op(OpKind::Divide).batches, 2);
        assert_eq!(s.op(OpKind::Sqrt).requests, 1);
        assert_eq!(s.total_requests(), 5);
        assert!(s.op(OpKind::Divide).mean_latency_ns > 0.0);
        // occupancy: 4 live / 68 padded
        let occ = s.op(OpKind::Divide).occupancy;
        assert!((occ - 4.0 / 68.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn vectored_entries_weight_lanes() {
        let m = Metrics::new();
        // one group of 100 lanes + one single, same batch
        m.record_batch(OpKind::Divide, F32, &[(5000, 100), (900, 1)], 400, 128);
        let s = m.snapshot();
        let d = s.op(OpKind::Divide);
        assert_eq!(d.requests, 101);
        assert_eq!(d.batches, 1);
        // the mean leans heavily toward the group's latency
        assert!(d.mean_latency_ns > 4000.0, "{}", d.mean_latency_ns);
        assert!((d.occupancy - 101.0 / 128.0).abs() < 1e-9);
    }

    #[test]
    fn per_format_slices_are_isolated() {
        let m = Metrics::new();
        m.record_batch(OpKind::Divide, FormatKind::F32, &[(1000, 1), (1000, 1)], 500, 4);
        m.record_batch(OpKind::Divide, FormatKind::F64, &[(9000, 1)], 700, 8);
        m.record_error(OpKind::Divide, FormatKind::F16, 3);
        let s = m.snapshot();
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F32).requests, 2);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F64).requests, 1);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F16).errors, 3);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::BF16).requests, 0);
        // the op aggregate sums the slices
        assert_eq!(s.op(OpKind::Divide).requests, 3);
        assert_eq!(s.op(OpKind::Divide).batches, 2);
        assert_eq!(s.op(OpKind::Divide).errors, 3);
        let occ = s.op(OpKind::Divide).occupancy;
        assert!((occ - 3.0 / 12.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(OpKind::Rsqrt, F32, 7);
        assert_eq!(m.snapshot().total_errors(), 7);
        assert_eq!(m.snapshot().op(OpKind::Rsqrt).errors, 7);
    }

    #[test]
    fn shed_counted_separately_from_errors() {
        let m = Metrics::new();
        m.record_shed(OpKind::Divide, FormatKind::F16, 5);
        m.record_error(OpKind::Divide, FormatKind::F16, 2);
        let s = m.snapshot();
        assert_eq!(s.total_shed(), 5);
        assert_eq!(s.total_errors(), 2);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F16).shed, 5);
        assert_eq!(s.op(OpKind::Divide).shed, 5);
        assert_eq!(s.total_requests(), 0);
    }

    #[test]
    fn queue_depth_gauge_tracks_enqueue_dequeue_per_slot() {
        let m = Metrics::new();
        assert_eq!(m.queued_lanes(OpKind::Divide, F32), 0);
        m.record_enqueued(OpKind::Divide, F32, 100);
        m.record_enqueued(OpKind::Divide, F32, 28);
        assert_eq!(m.queued_lanes(OpKind::Divide, F32), 128);
        // slots are independent
        assert_eq!(m.queued_lanes(OpKind::Divide, FormatKind::F16), 0);
        assert_eq!(m.queued_lanes(OpKind::Sqrt, F32), 0);
        // partial drains are fine; full drains return to zero
        m.record_dequeued(OpKind::Divide, F32, 100);
        assert_eq!(m.queued_lanes(OpKind::Divide, F32), 28);
        m.record_dequeued(OpKind::Divide, F32, 28);
        assert_eq!(m.queued_lanes(OpKind::Divide, F32), 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "queued-lane gauge underflow")]
    fn double_dequeue_is_a_debug_panic() {
        let m = Metrics::new();
        m.record_enqueued(OpKind::Divide, F32, 5);
        m.record_dequeued(OpKind::Divide, F32, 5);
        // the same lanes dequeued again: a bookkeeping bug, not a
        // benign interleaving — debug builds must catch it
        m.record_dequeued(OpKind::Divide, F32, 5);
    }

    #[test]
    fn queue_depth_gauge_property_random_legal_interleavings() {
        use crate::util::rng::Xoshiro256;
        // any legal sequence (never dequeue more than is queued) keeps
        // the gauge exactly equal to the model and never negative
        let mut rng = Xoshiro256::new(0x5eed_cafe);
        let m = Metrics::new();
        let mut model = 0u64;
        for _ in 0..10_000 {
            if model == 0 || rng.chance(0.55) {
                let lanes = rng.next_below(500) + 1;
                m.record_enqueued(OpKind::Sqrt, F32, lanes);
                model += lanes;
            } else {
                let lanes = rng.next_below(model) + 1;
                m.record_dequeued(OpKind::Sqrt, F32, lanes);
                model -= lanes;
            }
            assert_eq!(m.queued_lanes(OpKind::Sqrt, F32), model);
        }
    }

    #[test]
    fn queue_delay_estimate_divides_by_pool_workers() {
        let m = Metrics::new();
        for _ in 0..ADMISSION_MIN_BATCHES {
            m.record_batch(OpKind::Divide, F32, &[(5_000, 64)], 64_000, 64);
        }
        m.record_enqueued(OpKind::Divide, F32, 200);
        // default pool size 1: 200 lanes x 1000 ns/lane
        assert_eq!(m.queue_delay_estimate_ns(OpKind::Divide, F32), Some(200_000));
        // four workers drain in parallel: a lane waits a quarter of that
        m.set_slot_workers(OpKind::Divide, F32, 4);
        assert_eq!(m.queue_delay_estimate_ns(OpKind::Divide, F32), Some(50_000));
        // slots are independent; zero clamps to one
        m.set_slot_workers(OpKind::Sqrt, F32, 8);
        m.set_slot_workers(OpKind::Divide, FormatKind::F16, 0);
        assert_eq!(m.queue_delay_estimate_ns(OpKind::Divide, F32), Some(50_000));
        m.record_dequeued(OpKind::Divide, F32, 200);
    }

    #[test]
    fn queue_delay_estimate_is_depth_times_service_rate() {
        let m = Metrics::new();
        // no batches: no estimate (cold slot, admission stays open)
        assert!(m.queue_delay_estimate_ns(OpKind::Divide, F32).is_none());
        for _ in 0..3 {
            m.record_batch(OpKind::Divide, F32, &[(5_000, 64)], 64_000, 64);
        }
        assert!(m.queue_delay_estimate_ns(OpKind::Divide, F32).is_none(), "below min batches");
        m.record_batch(OpKind::Divide, F32, &[(5_000, 64)], 64_000, 64);
        // rate signal: 64_000ns / 64 lanes = 1000 ns per lane; with an
        // empty queue the model predicts ~zero delay
        assert_eq!(m.queue_delay_estimate_ns(OpKind::Divide, F32), Some(0));
        // a queued burst moves the estimate immediately: depth x rate
        m.record_enqueued(OpKind::Divide, F32, 200);
        let est = m.queue_delay_estimate_ns(OpKind::Divide, F32).expect("warm slot");
        assert_eq!(est, 200_000, "200 lanes x 1000 ns/lane");
        // and draining the queue recovers the estimate instantly — no
        // latency window to wait out
        m.record_dequeued(OpKind::Divide, F32, 200);
        assert_eq!(m.queue_delay_estimate_ns(OpKind::Divide, F32), Some(0));
        // other slots stay cold
        assert!(m.queue_delay_estimate_ns(OpKind::Sqrt, F32).is_none());
        assert!(m.queue_delay_estimate_ns(OpKind::Divide, FormatKind::F16).is_none());
    }

    #[test]
    fn service_rate_window_decays_after_slow_burst() {
        // the rate window must decay: a burst of slow batches followed
        // by fast ones re-ranks the per-lane cost (a cumulative mean
        // would keep over-rejecting forever)
        let m = Metrics::new();
        m.record_enqueued(OpKind::Divide, F32, 10);
        for _ in 0..40 {
            m.record_batch(OpKind::Divide, F32, &[(50_000_000, 1)], 5_000_000, 1);
        }
        // 10 lanes x 5ms/lane = 50ms
        assert!(m.queue_delay_estimate_ns(OpKind::Divide, F32).unwrap() >= 50_000_000);
        for _ in 0..RECENT_WINDOW {
            m.record_batch(OpKind::Divide, F32, &[(2_000, 1)], 200, 1);
        }
        let est = m.queue_delay_estimate_ns(OpKind::Divide, F32).unwrap();
        assert!(est <= 2_000, "rate window did not decay: {est}");
    }

    #[test]
    fn admission_probe_admits_one_in_period() {
        let m = Metrics::new();
        let mut admitted = 0;
        for _ in 0..(2 * ADMISSION_PROBE_PERIOD) {
            if m.admission_probe(OpKind::Divide, F32) {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 2, "exactly one probe per period");
        // the first would-reject is never a probe (rejection is prompt)
        let m = Metrics::new();
        assert!(!m.admission_probe(OpKind::Divide, F32));
        // probes are per slot
        assert!(!m.admission_probe(OpKind::Sqrt, F32));
    }

    #[test]
    fn admission_rejects_counted_separately() {
        let m = Metrics::new();
        m.record_admission_reject(OpKind::Divide, F32, 7);
        m.record_shed(OpKind::Divide, F32, 2);
        let s = m.snapshot();
        assert_eq!(s.op_format(OpKind::Divide, F32).admission_rejected, 7);
        assert_eq!(s.op(OpKind::Divide).admission_rejected, 7);
        assert_eq!(s.total_admission_rejected(), 7);
        assert_eq!(s.total_shed(), 2);
        assert_eq!(s.total_errors(), 0);
    }

    #[test]
    fn empty_snapshot_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.total_requests(), 0);
        assert_eq!(s.total_shed(), 0);
        assert_eq!(s.op(OpKind::Divide).occupancy, 1.0);
        assert_eq!(s.op_formats.len(), 12);
    }

    #[test]
    fn merged_snapshot_sums_shards_and_merges_histograms() {
        // two shards' slices: counters sum, and the merged percentiles
        // come from the union of both latency populations — not from
        // shard 0 alone (the bug the ServiceMetrics wrapper fixes)
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_batch(OpKind::Divide, F32, &[(1_000, 1)], 500, 4);
        a.record_error(OpKind::Divide, F32, 2);
        b.record_batch(OpKind::Divide, F32, &[(1_000_000, 3)], 900, 4);
        b.record_shed(OpKind::Sqrt, F32, 5);
        let s = Metrics::merged_snapshot([&a, &b]);
        let d = s.op_format(OpKind::Divide, F32);
        assert_eq!(d.requests, 4);
        assert_eq!(d.batches, 2);
        assert_eq!(d.errors, 2);
        assert_eq!(s.op_format(OpKind::Sqrt, F32).shed, 5);
        assert_eq!(s.total_requests(), 4);
        // 3 of 4 lanes are ~1ms: the merged p99 sees shard b's tail
        assert!(d.p99_latency_ns >= 1_000_000, "{}", d.p99_latency_ns);
        // occupancy merges too: 4 live / 8 padded
        assert!((d.occupancy - 0.5).abs() < 1e-9, "{}", d.occupancy);
        // merging one instance reproduces its own snapshot's counters
        let solo = Metrics::merged_snapshot([&a]);
        assert_eq!(solo.total_requests(), a.snapshot().total_requests());
        // an empty merge is the empty snapshot
        let empty = Metrics::merged_snapshot(std::iter::empty::<&Metrics>());
        assert_eq!(empty.total_requests(), 0);
        assert_eq!(empty.op_formats.len(), 12);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    m.record_batch(OpKind::Divide, F32, &[(100, 1)], 50, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().op(OpKind::Divide).requests, 400);
    }
}
