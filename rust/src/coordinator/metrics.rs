//! Always-on service metrics: counters and latency histograms, shared
//! between workers and readable while the service runs.

use std::sync::Mutex;

use crate::util::stats::LogHistogram;

use super::request::OpKind;

/// Per-op slice of the metrics.
#[derive(Clone, Debug, Default)]
struct OpMetrics {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    live_slots: u64,
    latency: LogHistogram,
    batch_exec_ns: LogHistogram,
    errors: u64,
}

/// Shared metrics sink (interior mutability; cheap enough for the
/// per-batch hot path — one lock per *batch*, not per request).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<[OpMetrics; 3]>,
}

fn idx(op: OpKind) -> usize {
    match op {
        OpKind::Divide => 0,
        OpKind::Sqrt => 1,
        OpKind::Rsqrt => 2,
    }
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: per-request latencies plus batch-level
    /// execution time and padding accounting.
    pub fn record_batch(
        &self,
        op: OpKind,
        latencies_ns: &[u64],
        exec_ns: u64,
        padded: usize,
    ) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        let s = &mut m[idx(op)];
        s.requests += latencies_ns.len() as u64;
        s.batches += 1;
        s.live_slots += latencies_ns.len() as u64;
        s.padded_slots += padded as u64;
        s.batch_exec_ns.record(exec_ns);
        for &l in latencies_ns {
            s.latency.record(l);
        }
    }

    /// Record a failed batch (all its requests error out).
    pub fn record_error(&self, op: OpKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op)].errors += count;
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().expect("metrics poisoned");
        MetricsSnapshot {
            ops: OpKind::ALL
                .iter()
                .map(|&op| {
                    let s = &m[idx(op)];
                    OpSnapshot {
                        op,
                        requests: s.requests,
                        batches: s.batches,
                        errors: s.errors,
                        mean_latency_ns: s.latency.mean(),
                        p50_latency_ns: s.latency.quantile(0.5),
                        p99_latency_ns: s.latency.quantile(0.99),
                        mean_exec_ns: s.batch_exec_ns.mean(),
                        occupancy: if s.padded_slots == 0 {
                            1.0
                        } else {
                            s.live_slots as f64 / s.padded_slots as f64
                        },
                    }
                })
                .collect(),
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Per-op snapshots in [`OpKind::ALL`] order.
    pub ops: Vec<OpSnapshot>,
}

/// One op's snapshot.
#[derive(Clone, Copy, Debug)]
pub struct OpSnapshot {
    /// Which op.
    pub op: OpKind,
    /// Requests completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests failed.
    pub errors: u64,
    /// Mean end-to-end latency (ns).
    pub mean_latency_ns: f64,
    /// Median end-to-end latency (ns, bucket upper edge).
    pub p50_latency_ns: u64,
    /// p99 end-to-end latency (ns, bucket upper edge).
    pub p99_latency_ns: u64,
    /// Mean executor time per batch (ns).
    pub mean_exec_ns: f64,
    /// Live/padded slot occupancy (1.0 = no padding waste).
    pub occupancy: f64,
}

impl MetricsSnapshot {
    /// Snapshot for one op.
    pub fn op(&self, op: OpKind) -> &OpSnapshot {
        self.ops.iter().find(|s| s.op == op).expect("all ops present")
    }

    /// Total completed requests.
    pub fn total_requests(&self) -> u64 {
        self.ops.iter().map(|s| s.requests).sum()
    }

    /// Total errors.
    pub fn total_errors(&self) -> u64 {
        self.ops.iter().map(|s| s.errors).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(OpKind::Divide, &[1000, 2000, 3000], 500, 4);
        m.record_batch(OpKind::Divide, &[1500], 400, 64);
        m.record_batch(OpKind::Sqrt, &[800], 300, 1);
        let s = m.snapshot();
        assert_eq!(s.op(OpKind::Divide).requests, 4);
        assert_eq!(s.op(OpKind::Divide).batches, 2);
        assert_eq!(s.op(OpKind::Sqrt).requests, 1);
        assert_eq!(s.total_requests(), 5);
        assert!(s.op(OpKind::Divide).mean_latency_ns > 0.0);
        // occupancy: 4 live / 68 padded
        let occ = s.op(OpKind::Divide).occupancy;
        assert!((occ - 4.0 / 68.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(OpKind::Rsqrt, 7);
        assert_eq!(m.snapshot().total_errors(), 7);
        assert_eq!(m.snapshot().op(OpKind::Rsqrt).errors, 7);
    }

    #[test]
    fn empty_snapshot_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.total_requests(), 0);
        assert_eq!(s.op(OpKind::Divide).occupancy, 1.0);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    m.record_batch(OpKind::Divide, &[100], 50, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().op(OpKind::Divide).requests, 400);
    }
}
