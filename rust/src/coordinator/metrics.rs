//! Always-on service metrics: counters and latency histograms, shared
//! between workers and readable while the service runs. Sliced per
//! (op, format) — the same key the router queues and batch planes use —
//! with per-op aggregates for the headline numbers.
//!
//! The v2 request plane distinguishes outcomes, so the metrics do too:
//! `requests` counts completed lanes, `errors` counts lanes failed
//! after batching — backend execution failures (delivered to clients
//! as [`ServiceError::ExecFailed`](super::request::ServiceError)) and
//! the rare total-worker-loss path (delivered as `Shutdown`) — and
//! `shed` counts lanes dropped by deadline expiry before execution.

use std::sync::Mutex;

use crate::util::stats::LogHistogram;

use super::request::{op_format_slot, FormatKind, OpKind, OP_FORMAT_SLOTS};

const SLOTS: usize = OP_FORMAT_SLOTS;

/// Per-(op, format) slice of the metrics.
#[derive(Clone, Debug, Default)]
struct SliceMetrics {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    live_slots: u64,
    latency: LogHistogram,
    batch_exec_ns: LogHistogram,
    errors: u64,
    shed: u64,
}

/// Shared metrics sink (interior mutability; cheap enough for the
/// per-batch hot path — one lock per *batch*, not per request).
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<[SliceMetrics; SLOTS]>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

fn idx(op: OpKind, format: FormatKind) -> usize {
    op_format_slot(op, format)
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self { inner: Mutex::new(std::array::from_fn(|_| SliceMetrics::default())) }
    }

    /// Record one executed batch. `latencies_ns` carries one entry per
    /// work item: `(end-to-end latency, lanes at that latency)` — a
    /// vectored submission's lanes share an enqueue timestamp, so they
    /// weight the histogram without per-lane recording.
    pub fn record_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        latencies_ns: &[(u64, usize)],
        exec_ns: u64,
        padded: usize,
    ) {
        let lanes: u64 = latencies_ns.iter().map(|&(_, n)| n as u64).sum();
        let mut m = self.inner.lock().expect("metrics poisoned");
        let s = &mut m[idx(op, format)];
        s.requests += lanes;
        s.batches += 1;
        s.live_slots += lanes;
        s.padded_slots += padded as u64;
        s.batch_exec_ns.record(exec_ns);
        for &(l, n) in latencies_ns {
            s.latency.record_n(l, n as u64);
        }
    }

    /// Record a failed batch (all its lanes error out).
    pub fn record_error(&self, op: OpKind, format: FormatKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op, format)].errors += count;
    }

    /// Record lanes shed by deadline expiry (never executed).
    pub fn record_shed(&self, op: OpKind, format: FormatKind, count: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m[idx(op, format)].shed += count;
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().expect("metrics poisoned");
        let snap_of = |s: &SliceMetrics| OpSnapshotBody {
            requests: s.requests,
            batches: s.batches,
            errors: s.errors,
            shed: s.shed,
            mean_latency_ns: s.latency.mean(),
            p50_latency_ns: s.latency.quantile(0.5),
            p99_latency_ns: s.latency.quantile(0.99),
            mean_exec_ns: s.batch_exec_ns.mean(),
            occupancy: if s.padded_slots == 0 {
                1.0
            } else {
                s.live_slots as f64 / s.padded_slots as f64
            },
        };
        let mut op_formats = Vec::with_capacity(SLOTS);
        let mut ops = Vec::with_capacity(OpKind::ALL.len());
        for &op in &OpKind::ALL {
            // aggregate the op's format slices (histograms merge exactly)
            let mut agg = SliceMetrics::default();
            for &format in &FormatKind::ALL {
                let s = &m[idx(op, format)];
                agg.requests += s.requests;
                agg.batches += s.batches;
                agg.padded_slots += s.padded_slots;
                agg.live_slots += s.live_slots;
                agg.errors += s.errors;
                agg.shed += s.shed;
                agg.latency.merge(&s.latency);
                agg.batch_exec_ns.merge(&s.batch_exec_ns);
                op_formats.push(OpFormatSnapshot { op, format, body: snap_of(s) });
            }
            ops.push(OpSnapshot { op, body: snap_of(&agg) });
        }
        MetricsSnapshot { ops, op_formats }
    }
}

/// The measured quantities shared by per-op and per-(op, format)
/// snapshots.
#[derive(Clone, Copy, Debug)]
pub struct OpSnapshotBody {
    /// Lanes completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Lanes failed after batching (backend execution failure, or
    /// worker loss at dispatch).
    pub errors: u64,
    /// Lanes shed by deadline expiry (never executed).
    pub shed: u64,
    /// Mean end-to-end latency (ns).
    pub mean_latency_ns: f64,
    /// Median end-to-end latency (ns, bucket upper edge).
    pub p50_latency_ns: u64,
    /// p99 end-to-end latency (ns, bucket upper edge).
    pub p99_latency_ns: u64,
    /// Mean executor time per batch (ns).
    pub mean_exec_ns: f64,
    /// Live/padded slot occupancy (1.0 = no padding waste).
    pub occupancy: f64,
}

/// One op's aggregate snapshot (all formats merged).
#[derive(Clone, Copy, Debug)]
pub struct OpSnapshot {
    /// Which op.
    pub op: OpKind,
    /// The measurements.
    pub body: OpSnapshotBody,
}

impl std::ops::Deref for OpSnapshot {
    type Target = OpSnapshotBody;
    fn deref(&self) -> &OpSnapshotBody {
        &self.body
    }
}

/// One (op, format) slice's snapshot.
#[derive(Clone, Copy, Debug)]
pub struct OpFormatSnapshot {
    /// Which op.
    pub op: OpKind,
    /// Which format.
    pub format: FormatKind,
    /// The measurements.
    pub body: OpSnapshotBody,
}

impl std::ops::Deref for OpFormatSnapshot {
    type Target = OpSnapshotBody;
    fn deref(&self) -> &OpSnapshotBody {
        &self.body
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Per-op aggregates in [`OpKind::ALL`] order.
    pub ops: Vec<OpSnapshot>,
    /// Per-(op, format) slices, ops-major in [`OpKind::ALL`] x
    /// [`FormatKind::ALL`] order.
    pub op_formats: Vec<OpFormatSnapshot>,
}

impl MetricsSnapshot {
    /// Aggregate snapshot for one op (all formats).
    pub fn op(&self, op: OpKind) -> &OpSnapshot {
        self.ops.iter().find(|s| s.op == op).expect("all ops present")
    }

    /// Snapshot for one (op, format) slice.
    pub fn op_format(&self, op: OpKind, format: FormatKind) -> &OpFormatSnapshot {
        self.op_formats
            .iter()
            .find(|s| s.op == op && s.format == format)
            .expect("all slices present")
    }

    /// Total completed lanes.
    pub fn total_requests(&self) -> u64 {
        self.ops.iter().map(|s| s.requests).sum()
    }

    /// Total errors.
    pub fn total_errors(&self) -> u64 {
        self.ops.iter().map(|s| s.errors).sum()
    }

    /// Total deadline-shed lanes.
    pub fn total_shed(&self) -> u64 {
        self.ops.iter().map(|s| s.shed).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(OpKind::Divide, F32, &[(1000, 1), (2000, 1), (3000, 1)], 500, 4);
        m.record_batch(OpKind::Divide, F32, &[(1500, 1)], 400, 64);
        m.record_batch(OpKind::Sqrt, F32, &[(800, 1)], 300, 1);
        let s = m.snapshot();
        assert_eq!(s.op(OpKind::Divide).requests, 4);
        assert_eq!(s.op(OpKind::Divide).batches, 2);
        assert_eq!(s.op(OpKind::Sqrt).requests, 1);
        assert_eq!(s.total_requests(), 5);
        assert!(s.op(OpKind::Divide).mean_latency_ns > 0.0);
        // occupancy: 4 live / 68 padded
        let occ = s.op(OpKind::Divide).occupancy;
        assert!((occ - 4.0 / 68.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn vectored_entries_weight_lanes() {
        let m = Metrics::new();
        // one group of 100 lanes + one single, same batch
        m.record_batch(OpKind::Divide, F32, &[(5000, 100), (900, 1)], 400, 128);
        let s = m.snapshot();
        let d = s.op(OpKind::Divide);
        assert_eq!(d.requests, 101);
        assert_eq!(d.batches, 1);
        // the mean leans heavily toward the group's latency
        assert!(d.mean_latency_ns > 4000.0, "{}", d.mean_latency_ns);
        assert!((d.occupancy - 101.0 / 128.0).abs() < 1e-9);
    }

    #[test]
    fn per_format_slices_are_isolated() {
        let m = Metrics::new();
        m.record_batch(OpKind::Divide, FormatKind::F32, &[(1000, 1), (1000, 1)], 500, 4);
        m.record_batch(OpKind::Divide, FormatKind::F64, &[(9000, 1)], 700, 8);
        m.record_error(OpKind::Divide, FormatKind::F16, 3);
        let s = m.snapshot();
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F32).requests, 2);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F64).requests, 1);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F16).errors, 3);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::BF16).requests, 0);
        // the op aggregate sums the slices
        assert_eq!(s.op(OpKind::Divide).requests, 3);
        assert_eq!(s.op(OpKind::Divide).batches, 2);
        assert_eq!(s.op(OpKind::Divide).errors, 3);
        let occ = s.op(OpKind::Divide).occupancy;
        assert!((occ - 3.0 / 12.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(OpKind::Rsqrt, F32, 7);
        assert_eq!(m.snapshot().total_errors(), 7);
        assert_eq!(m.snapshot().op(OpKind::Rsqrt).errors, 7);
    }

    #[test]
    fn shed_counted_separately_from_errors() {
        let m = Metrics::new();
        m.record_shed(OpKind::Divide, FormatKind::F16, 5);
        m.record_error(OpKind::Divide, FormatKind::F16, 2);
        let s = m.snapshot();
        assert_eq!(s.total_shed(), 5);
        assert_eq!(s.total_errors(), 2);
        assert_eq!(s.op_format(OpKind::Divide, FormatKind::F16).shed, 5);
        assert_eq!(s.op(OpKind::Divide).shed, 5);
        assert_eq!(s.total_requests(), 0);
    }

    #[test]
    fn empty_snapshot_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.total_requests(), 0);
        assert_eq!(s.total_shed(), 0);
        assert_eq!(s.op(OpKind::Divide).occupancy, 1.0);
        assert_eq!(s.op_formats.len(), 12);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    m.record_batch(OpKind::Divide, F32, &[(100, 1)], 50, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().op(OpKind::Divide).requests, 400);
    }
}
