//! Request-plane types for the FPU service: op kinds, typed service
//! errors, responses, and the [`WorkItem`] unit the router and batcher
//! move around.
//!
//! v2 of the request plane replaced the per-request reply channel with
//! shared completion slots (see [`super::ticket`]): a [`WorkItem`] is
//! either one request or a contiguous slice of a vectored submission,
//! and carries a handle to the slot its results are written into. Every
//! failure mode is a typed [`ServiceError`] delivered through that slot
//! — nothing is signalled by dropping a sender any more.

use std::sync::Arc;
use std::time::Instant;

pub use crate::formats::{FormatKind, Value};

use crate::formats::PlaneBuf;

use super::ticket::{BatchTicket, Ticket, TicketCore};

/// The operations the divider unit serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// `a / b`.
    Divide,
    /// `sqrt(a)`.
    Sqrt,
    /// `1 / sqrt(a)`.
    Rsqrt,
}

impl OpKind {
    /// All op kinds, in routing order.
    pub const ALL: [OpKind; 3] = [OpKind::Divide, OpKind::Sqrt, OpKind::Rsqrt];

    /// Dense index (for per-op tables: queues, metrics).
    pub fn index(&self) -> usize {
        match self {
            OpKind::Divide => 0,
            OpKind::Sqrt => 1,
            OpKind::Rsqrt => 2,
        }
    }

    /// Number of operands.
    pub fn arity(&self) -> u32 {
        match self {
            OpKind::Divide => 2,
            _ => 1,
        }
    }

    /// Stable label for metrics/tables.
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Divide => "divide",
            OpKind::Sqrt => "sqrt",
            OpKind::Rsqrt => "rsqrt",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "divide" | "div" => Ok(OpKind::Divide),
            "sqrt" => Ok(OpKind::Sqrt),
            "rsqrt" => Ok(OpKind::Rsqrt),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

/// Number of (op, format) routing slots.
pub(crate) const OP_FORMAT_SLOTS: usize = OpKind::ALL.len() * FormatKind::ALL.len();

/// Dense (op, format) slot index — the one layout shared by the
/// router's queues, the metrics slices, the batcher's policies and the
/// backend capability table.
pub(crate) fn op_format_slot(op: OpKind, format: FormatKind) -> usize {
    op.index() * FormatKind::ALL.len() + format.index()
}

/// Every way a request can fail, carried to the client through its
/// ticket. The v1 plane collapsed all of these into a dropped reply
/// sender (`RecvError`); v2 makes each outcome distinguishable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The submission was invalid or unservable (format mismatch, bad
    /// arity, an (op, format) pair outside the backend's capabilities).
    /// Raised at submit time — rejected work never enters the queue.
    Rejected {
        /// Human-readable cause.
        reason: String,
    },
    /// The bounded submit queue is full (only from the `try_submit`
    /// family; blocking submits apply backpressure instead).
    Overloaded,
    /// The backend failed the batch this request rode in; carries the
    /// executor's own error message.
    ExecFailed {
        /// The backend's rendered error chain.
        backend: String,
    },
    /// The request's deadline expired before execution; the dispatcher
    /// shed it without running it.
    Deadline,
    /// The service shut down (or lost every worker) before the request
    /// could complete.
    Shutdown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Rejected { reason } => write!(f, "request rejected: {reason}"),
            ServiceError::Overloaded => f.write_str("service overloaded: submit queue full"),
            ServiceError::ExecFailed { backend } => {
                write!(f, "backend execution failed: {backend}")
            }
            ServiceError::Deadline => f.write_str("deadline expired before execution"),
            ServiceError::Shutdown => f.write_str("service is shut down"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// The service's answer to one request.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echoes the request id.
    pub id: u64,
    /// Result value in the request's format (NaN propagated per IEEE
    /// semantics).
    pub value: Value,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u64,
    /// Size of the batch this request rode in (for diagnostics).
    pub batch_size: usize,
}

/// Operand storage: one inline pair, or a shared slice of a vectored
/// submission's planes (groups split at ladder boundaries by cloning
/// the `Arc` and narrowing the window — no copying).
#[derive(Debug)]
enum Payload {
    One { a: u64, b: u64 },
    Group { planes: Arc<GroupPlanes>, start: usize, len: usize },
}

/// The operand planes of one vectored submission (`b` empty for unary
/// ops), stored **width-true** at the submission format's plane width —
/// a queued half-precision group holds `u32` lanes, half the memory of
/// the old universal `u64` planes, all the way from submit to batch
/// formation.
#[derive(Debug)]
struct GroupPlanes {
    a: PlaneBuf,
    b: PlaneBuf,
}

/// A unit of work travelling through the coordinator: one request, or a
/// contiguous window of a vectored submission. Results flow back
/// through the completion slot shared with the submitting client's
/// ticket; a `WorkItem` dropped without being completed fails its lanes
/// with [`ServiceError::Shutdown`], so no client can be left waiting.
#[derive(Debug)]
pub struct WorkItem {
    /// Request / group id (assigned by the service handle).
    pub id: u64,
    /// Operation.
    pub op: OpKind,
    /// Enqueue timestamp (latency accounting and age-based flush).
    pub enqueued_at: Instant,
    /// Optional completion deadline; expired items are shed, not run.
    pub deadline: Option<Instant>,
    /// Whole-lifecycle trace sampling flag: set at submit time by the
    /// service handle (1-in-N by request id), carried through routing
    /// and batch formation so every stage of a sampled request is
    /// captured — or none of it. Error-class trace events ignore this
    /// flag entirely (they are always captured).
    pub sampled: bool,
    format: FormatKind,
    payload: Payload,
    completion: Arc<TicketCore>,
    /// First lane of this item within its ticket's result plane.
    base: usize,
    done: bool,
}

impl WorkItem {
    /// One request plus the [`Ticket`] resolving it. The routing format
    /// is the first operand's tag, so it can never desync from the
    /// payload; the caller has already checked `a` and `b` agree.
    pub fn single(
        id: u64,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Option<Instant>,
    ) -> (WorkItem, Ticket) {
        let format = a.format();
        let core = TicketCore::new(1);
        let item = WorkItem {
            id,
            op,
            enqueued_at: Instant::now(),
            deadline,
            sampled: false,
            format,
            payload: Payload::One { a: a.bits(), b: b.bits() },
            completion: core.clone(),
            base: 0,
            done: false,
        };
        (item, Ticket::new(core, id, format))
    }

    /// A vectored submission plus the [`BatchTicket`] resolving it.
    /// `a` must be non-empty; `b` is the divisor plane for divide (same
    /// length as `a`) and must be empty for unary ops. Arity is
    /// enforced here — the service handle reports it as a typed
    /// [`ServiceError::Rejected`] before construction, but direct
    /// callers fail at their own boundary instead of inside the
    /// dispatcher.
    pub fn group(
        id: u64,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Option<Instant>,
    ) -> (WorkItem, BatchTicket) {
        assert!(!a.is_empty(), "a group needs at least one lane");
        match op {
            OpKind::Divide => assert!(
                b.len() == a.len(),
                "divide group needs matching operand planes ({} vs {})",
                a.len(),
                b.len()
            ),
            OpKind::Sqrt | OpKind::Rsqrt => {
                assert!(b.is_empty(), "{} group takes one operand plane", op.label())
            }
        }
        let lanes = a.len();
        let core = TicketCore::new(lanes);
        let width = format.plane_width();
        let item = WorkItem {
            id,
            op,
            enqueued_at: Instant::now(),
            deadline,
            sampled: false,
            format,
            payload: Payload::Group {
                planes: Arc::new(GroupPlanes {
                    a: PlaneBuf::from_u64_slice(width, a),
                    b: PlaneBuf::from_u64_slice(width, b),
                }),
                start: 0,
                len: lanes,
            },
            completion: core.clone(),
            base: 0,
            done: false,
        };
        (item, BatchTicket::new(core, id, format, lanes))
    }

    /// The IEEE format this item is served in (the routing key).
    pub fn format(&self) -> FormatKind {
        self.format
    }

    /// Number of operand lanes this item contributes to a batch.
    pub fn lanes(&self) -> usize {
        match &self.payload {
            Payload::One { .. } => 1,
            Payload::Group { len, .. } => *len,
        }
    }

    /// True once the deadline (if any) has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Split the first `k` lanes off into their own item (group items
    /// only; `0 < k < lanes`). Both halves share the operand planes and
    /// the completion slot; results land in the right ticket lanes via
    /// each half's base offset.
    pub(crate) fn split_off_front(&mut self, k: usize) -> WorkItem {
        let front_base = self.base;
        match &mut self.payload {
            Payload::Group { planes, start, len } => {
                assert!(k > 0 && k < *len, "split {k} outside (0, {len})");
                let front = WorkItem {
                    id: self.id,
                    op: self.op,
                    enqueued_at: self.enqueued_at,
                    deadline: self.deadline,
                    sampled: self.sampled,
                    format: self.format,
                    payload: Payload::Group {
                        planes: planes.clone(),
                        start: *start,
                        len: k,
                    },
                    completion: self.completion.clone(),
                    base: front_base,
                    done: false,
                };
                *start += k;
                *len -= k;
                self.base += k;
                front
            }
            Payload::One { .. } => unreachable!("cannot split a single request"),
        }
    }

    /// Append this item's operand lanes to a batch's width-true planes.
    /// `b_out` is `None` for unary-op batches (no divisor plane is
    /// built at all); a group submitted without a `b` plane but batched
    /// for divide fills its divisor lanes with the neutral `one_bits`
    /// so the planes stay rectangular. Group windows whose stored width
    /// matches the batch plane (the common case — both derive from the
    /// format) copy as straight `memcpy`s.
    pub(crate) fn push_operands(
        &self,
        a_out: &mut PlaneBuf,
        b_out: Option<&mut PlaneBuf>,
        one_bits: u64,
    ) {
        match &self.payload {
            Payload::One { a, b } => {
                a_out.push(*a);
                if let Some(b_out) = b_out {
                    b_out.push(*b);
                }
            }
            Payload::Group { planes, start, len } => {
                a_out.extend_window(&planes.a, *start, *len);
                if let Some(b_out) = b_out {
                    if planes.b.is_empty() {
                        b_out.resize(b_out.len() + *len, one_bits);
                    } else {
                        b_out.extend_window(&planes.b, *start, *len);
                    }
                }
            }
        }
    }

    /// Deliver this item's results (one value per lane, in lane order).
    pub(crate) fn complete(mut self, values: &[u64], latency_ns: u64, batch_size: usize) {
        debug_assert_eq!(values.len(), self.lanes());
        self.completion.complete_range(self.base, values, latency_ns, batch_size);
        self.done = true;
    }

    /// Fail this item's lanes with a typed error.
    pub(crate) fn fail(mut self, err: ServiceError) {
        self.completion.fail_range(self.lanes(), err);
        self.done = true;
    }
}

impl Drop for WorkItem {
    /// Failsafe: an item dropped without completion (a batch stranded in
    /// a dead worker's channel, a queue dropped mid-teardown) fails its
    /// lanes so no client blocks forever. This is the typed replacement
    /// for v1's "dropped reply sender" signal.
    fn drop(&mut self) {
        if !self.done {
            self.completion.fail_range(self.lanes(), ServiceError::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_and_labels() {
        assert_eq!(OpKind::Divide.arity(), 2);
        assert_eq!(OpKind::Sqrt.arity(), 1);
        assert_eq!(OpKind::Rsqrt.arity(), 1);
        assert_eq!(OpKind::Divide.label(), "divide");
    }

    #[test]
    fn parse_ops() {
        assert_eq!(OpKind::parse("div").unwrap(), OpKind::Divide);
        assert_eq!(OpKind::parse("sqrt").unwrap(), OpKind::Sqrt);
        assert_eq!(OpKind::parse("rsqrt").unwrap(), OpKind::Rsqrt);
        assert!(OpKind::parse("cbrt").is_err());
    }

    #[test]
    fn all_covers_every_kind() {
        assert_eq!(OpKind::ALL.len(), 3);
        let mut labels: Vec<_> = OpKind::ALL.iter().map(|o| o.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 3);
        let mut idxs: Vec<_> = OpKind::ALL.iter().map(|o| o.index()).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, vec![0, 1, 2]);
    }

    #[test]
    fn value_round_trips_through_response_plane() {
        for kind in FormatKind::ALL {
            let v = Value::from_f64(kind, 2.5);
            assert_eq!(v.format(), kind);
            assert_eq!(Value::from_bits(kind, v.bits()), v);
            assert_eq!(v.to_f64(), 2.5);
        }
    }

    #[test]
    fn service_error_displays_carry_detail() {
        let e = ServiceError::Rejected { reason: "bad arity".into() };
        assert!(e.to_string().contains("bad arity"));
        let e = ServiceError::ExecFailed { backend: "pjrt: OOM".into() };
        assert!(e.to_string().contains("pjrt: OOM"));
        assert!(ServiceError::Deadline.to_string().contains("deadline"));
        assert!(ServiceError::Overloaded.to_string().contains("overloaded"));
        assert!(ServiceError::Shutdown.to_string().contains("shut down"));
    }

    #[test]
    fn single_item_completes_its_ticket() {
        let (item, ticket) =
            WorkItem::single(3, OpKind::Divide, Value::F32(6.0), Value::F32(2.0), None);
        assert_eq!(item.lanes(), 1);
        assert_eq!(item.format(), FormatKind::F32);
        item.complete(&[3.0f32.to_bits() as u64], 100, 64);
        let resp = ticket.wait().expect("ok");
        assert_eq!(resp.value.f32(), 3.0);
        assert_eq!(resp.id, 3);
    }

    #[test]
    fn dropped_item_fails_ticket_with_shutdown() {
        let (item, ticket) =
            WorkItem::single(0, OpKind::Sqrt, Value::F32(4.0), Value::F32(1.0), None);
        drop(item);
        assert_eq!(ticket.wait().unwrap_err(), ServiceError::Shutdown);
    }

    #[test]
    fn group_split_preserves_lanes_and_order() {
        let a: Vec<u64> = (0..10).map(|i| i + 100).collect();
        let (mut item, ticket) =
            WorkItem::group(1, OpKind::Sqrt, FormatKind::F32, &a, &[], None);
        assert_eq!(item.lanes(), 10);
        let front = item.split_off_front(4);
        assert_eq!(front.lanes(), 4);
        assert_eq!(item.lanes(), 6);
        // operand windows stay aligned (width-true f32 planes)
        let width = FormatKind::F32.plane_width();
        let (mut pa, mut pb) = (PlaneBuf::new(width), PlaneBuf::new(width));
        front.push_operands(&mut pa, Some(&mut pb), 0);
        item.push_operands(&mut pa, Some(&mut pb), 0);
        assert_eq!((0..pa.len()).map(|i| pa.get(i)).collect::<Vec<_>>(), a);
        assert_eq!(pb, PlaneBuf::from_u64_slice(width, &[0u64; 10])); // b-less group: neutral lanes
        // and a unary batch builds no divisor plane at all
        let mut pa2 = PlaneBuf::new(width);
        item.push_operands(&mut pa2, None, 0);
        assert_eq!((0..pa2.len()).map(|i| pa2.get(i)).collect::<Vec<_>>(), a[4..]);
        // completing the halves out of order still fills the right slots
        let tail: Vec<u64> = (4..10u64).map(|i| i * 2).collect();
        item.complete(&tail, 50, 64);
        let head: Vec<u64> = (0..4u64).map(|i| i * 2).collect();
        front.complete(&head, 80, 64);
        let resp = ticket.wait().expect("ok");
        assert_eq!(resp.bits, (0..10u64).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(resp.latency_ns, 80);
    }

    #[test]
    fn half_precision_groups_store_width_true_planes() {
        // a queued f16 group holds u32 lanes end to end
        let a: Vec<u64> = vec![0x3C00; 8];
        let (item, _t) = WorkItem::group(1, OpKind::Sqrt, FormatKind::F16, &a, &[], None);
        let mut pa = PlaneBuf::for_format(FormatKind::F16);
        item.push_operands(&mut pa, None, 0);
        assert_eq!(pa.width(), crate::formats::PlaneWidth::W32);
        assert_eq!(pa.len(), 8);
        assert_eq!(pa.get(0), 0x3C00);
    }

    #[test]
    fn expiry_follows_deadline() {
        let now = Instant::now();
        let (item, _t) = WorkItem::single(
            0,
            OpKind::Divide,
            Value::F32(1.0),
            Value::F32(1.0),
            Some(now),
        );
        assert!(item.expired(now + std::time::Duration::from_micros(1)));
        let (item, _t) =
            WorkItem::single(0, OpKind::Divide, Value::F32(1.0), Value::F32(1.0), None);
        assert!(!item.expired(now + std::time::Duration::from_secs(1)));
    }
}
