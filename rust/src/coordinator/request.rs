//! Request/response types for the FPU service.

use std::sync::mpsc;
use std::time::Instant;

/// The operations the divider unit serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// `a / b`.
    Divide,
    /// `sqrt(a)`.
    Sqrt,
    /// `1 / sqrt(a)`.
    Rsqrt,
}

impl OpKind {
    /// All op kinds, in routing order.
    pub const ALL: [OpKind; 3] = [OpKind::Divide, OpKind::Sqrt, OpKind::Rsqrt];

    /// Number of operands.
    pub fn arity(&self) -> u32 {
        match self {
            OpKind::Divide => 2,
            _ => 1,
        }
    }

    /// Stable label for metrics/tables.
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Divide => "divide",
            OpKind::Sqrt => "sqrt",
            OpKind::Rsqrt => "rsqrt",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "divide" | "div" => Ok(OpKind::Divide),
            "sqrt" => Ok(OpKind::Sqrt),
            "rsqrt" => Ok(OpKind::Rsqrt),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

/// A unit of work travelling through the coordinator.
#[derive(Debug)]
pub struct Request {
    /// Unique id (assigned by the service handle).
    pub id: u64,
    /// Operation.
    pub op: OpKind,
    /// First operand.
    pub a: f32,
    /// Second operand (ignored for unary ops).
    pub b: f32,
    /// Enqueue timestamp (for latency accounting and age-based flush).
    pub enqueued_at: Instant,
    /// Where the response goes.
    pub reply: mpsc::Sender<Response>,
}

/// The service's answer to one request.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echoes the request id.
    pub id: u64,
    /// Result value (NaN propagated per IEEE semantics).
    pub value: f32,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u64,
    /// Size of the batch this request rode in (for diagnostics).
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_and_labels() {
        assert_eq!(OpKind::Divide.arity(), 2);
        assert_eq!(OpKind::Sqrt.arity(), 1);
        assert_eq!(OpKind::Rsqrt.arity(), 1);
        assert_eq!(OpKind::Divide.label(), "divide");
    }

    #[test]
    fn parse_ops() {
        assert_eq!(OpKind::parse("div").unwrap(), OpKind::Divide);
        assert_eq!(OpKind::parse("sqrt").unwrap(), OpKind::Sqrt);
        assert_eq!(OpKind::parse("rsqrt").unwrap(), OpKind::Rsqrt);
        assert!(OpKind::parse("cbrt").is_err());
    }

    #[test]
    fn all_covers_every_kind() {
        assert_eq!(OpKind::ALL.len(), 3);
        let mut labels: Vec<_> = OpKind::ALL.iter().map(|o| o.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 3);
    }
}
