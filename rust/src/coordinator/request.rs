//! Request/response types for the FPU service.

use std::sync::mpsc;
use std::time::Instant;

pub use crate::formats::{FormatKind, Value};

/// The operations the divider unit serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// `a / b`.
    Divide,
    /// `sqrt(a)`.
    Sqrt,
    /// `1 / sqrt(a)`.
    Rsqrt,
}

impl OpKind {
    /// All op kinds, in routing order.
    pub const ALL: [OpKind; 3] = [OpKind::Divide, OpKind::Sqrt, OpKind::Rsqrt];

    /// Dense index (for per-op tables: queues, metrics).
    pub fn index(&self) -> usize {
        match self {
            OpKind::Divide => 0,
            OpKind::Sqrt => 1,
            OpKind::Rsqrt => 2,
        }
    }

    /// Number of operands.
    pub fn arity(&self) -> u32 {
        match self {
            OpKind::Divide => 2,
            _ => 1,
        }
    }

    /// Stable label for metrics/tables.
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Divide => "divide",
            OpKind::Sqrt => "sqrt",
            OpKind::Rsqrt => "rsqrt",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "divide" | "div" => Ok(OpKind::Divide),
            "sqrt" => Ok(OpKind::Sqrt),
            "rsqrt" => Ok(OpKind::Rsqrt),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

/// A unit of work travelling through the coordinator. The operands are
/// format-tagged [`Value`]s; [`Request::format`] (derived from the
/// first operand, so it can never desync from the payload) is the
/// routing key the per-(op, format) queues and batch planes use.
#[derive(Debug)]
pub struct Request {
    /// Unique id (assigned by the service handle).
    pub id: u64,
    /// Operation.
    pub op: OpKind,
    /// First operand.
    pub a: Value,
    /// Second operand (`1.0` in the request format for unary ops;
    /// must share `a`'s format — the service handle enforces this at
    /// submit time).
    pub b: Value,
    /// Enqueue timestamp (for latency accounting and age-based flush).
    pub enqueued_at: Instant,
    /// Where the response goes.
    pub reply: mpsc::Sender<Response>,
}

impl Request {
    /// The IEEE format this request is served in (the first operand's
    /// tag — structural, not stored).
    pub fn format(&self) -> FormatKind {
        self.a.format()
    }
}

/// Number of (op, format) routing slots.
pub(crate) const OP_FORMAT_SLOTS: usize = OpKind::ALL.len() * FormatKind::ALL.len();

/// Dense (op, format) slot index — the one layout shared by the
/// router's queues, the metrics slices and the batcher's ladders.
pub(crate) fn op_format_slot(op: OpKind, format: FormatKind) -> usize {
    op.index() * FormatKind::ALL.len() + format.index()
}

/// The service's answer to one request.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echoes the request id.
    pub id: u64,
    /// Result value in the request's format (NaN propagated per IEEE
    /// semantics).
    pub value: Value,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u64,
    /// Size of the batch this request rode in (for diagnostics).
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_and_labels() {
        assert_eq!(OpKind::Divide.arity(), 2);
        assert_eq!(OpKind::Sqrt.arity(), 1);
        assert_eq!(OpKind::Rsqrt.arity(), 1);
        assert_eq!(OpKind::Divide.label(), "divide");
    }

    #[test]
    fn parse_ops() {
        assert_eq!(OpKind::parse("div").unwrap(), OpKind::Divide);
        assert_eq!(OpKind::parse("sqrt").unwrap(), OpKind::Sqrt);
        assert_eq!(OpKind::parse("rsqrt").unwrap(), OpKind::Rsqrt);
        assert!(OpKind::parse("cbrt").is_err());
    }

    #[test]
    fn all_covers_every_kind() {
        assert_eq!(OpKind::ALL.len(), 3);
        let mut labels: Vec<_> = OpKind::ALL.iter().map(|o| o.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 3);
        let mut idxs: Vec<_> = OpKind::ALL.iter().map(|o| o.index()).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, vec![0, 1, 2]);
    }

    #[test]
    fn value_round_trips_through_response_plane() {
        for kind in FormatKind::ALL {
            let v = Value::from_f64(kind, 2.5);
            assert_eq!(v.format(), kind);
            assert_eq!(Value::from_bits(kind, v.bits()), v);
            assert_eq!(v.to_f64(), 2.5);
        }
    }
}
