//! The threaded FPU service: lifecycle, backpressure, dispatch loop and
//! worker pool. This is the event loop the paper's "divider unit as a
//! shared resource" maps onto: many clients, one (or a few) expensive
//! execution engines, a batching layer in between.
//!
//! Threading model (std threads + channels; no async runtime exists in
//! the offline environment, and none is needed):
//!
//! * clients hold a [`ServiceHandle`] and `submit()` into a *bounded*
//!   channel — the backpressure boundary; a full queue pushes back on
//!   submitters instead of growing without bound;
//! * one **dispatcher** thread owns the [`Router`] + [`DynamicBatcher`]
//!   and turns the request stream into batches;
//! * `workers` **executor** threads each own one [`Executor`] (one
//!   "divider unit" each) and execute batches round-robin.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::executor::Executor;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{FormatKind, OpKind, Request, Response, Value};
use super::router::Router;

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Bounded submit-queue depth (the backpressure knob).
    pub queue_depth: usize,
    /// Number of executor workers (parallel "divider units").
    pub workers: usize,
    /// Dispatcher poll granularity when idle.
    pub poll: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            queue_depth: 16_384,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }
}

enum DispatchMsg {
    Req(Request),
    Shutdown,
}

/// Client-side handle: cheap to clone, safe across threads.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<DispatchMsg>,
    next_id: Arc<AtomicU64>,
}

impl ServiceHandle {
    fn make_request(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
    ) -> Result<(Request, mpsc::Receiver<Response>)> {
        if a.format() != b.format() {
            bail!("operand format mismatch: {} vs {}", a.format(), b.format());
        }
        let (reply, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            op,
            a,
            b,
            enqueued_at: Instant::now(),
            reply,
        };
        Ok((req, rx))
    }

    /// Submit one op on format-tagged operands; returns the receiver for
    /// its [`Response`]. Blocks while the submit queue is full
    /// (backpressure). Both operands must share a format (pass
    /// `Value::one(format)` as `b` for unary ops).
    pub fn submit_value(&self, op: OpKind, a: Value, b: Value) -> Result<mpsc::Receiver<Response>> {
        let (req, rx) = self.make_request(op, a, b)?;
        if self.tx.send(DispatchMsg::Req(req)).is_err() {
            bail!("service is shut down");
        }
        Ok(rx)
    }

    /// Submit one f32 op (the single-precision convenience path).
    pub fn submit(&self, op: OpKind, a: f32, b: f32) -> Result<mpsc::Receiver<Response>> {
        self.submit_value(op, Value::F32(a), Value::F32(b))
    }

    /// Non-blocking submit of format-tagged operands: `Ok(None)` when
    /// the queue is full.
    pub fn try_submit_value(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        let (req, rx) = self.make_request(op, a, b)?;
        match self.tx.try_send(DispatchMsg::Req(req)) {
            Ok(()) => Ok(Some(rx)),
            Err(TrySendError::Full(_)) => Ok(None),
            Err(TrySendError::Disconnected(_)) => bail!("service is shut down"),
        }
    }

    /// Non-blocking f32 submit: `Ok(None)` when the queue is full.
    pub fn try_submit(
        &self,
        op: OpKind,
        a: f32,
        b: f32,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        self.try_submit_value(op, Value::F32(a), Value::F32(b))
    }

    /// Convenience: blocking round-trip divide (f32).
    pub fn divide(&self, n: f32, d: f32) -> Result<f32> {
        Ok(self.submit(OpKind::Divide, n, d)?.recv()?.value.f32())
    }

    /// Convenience: blocking round-trip sqrt (f32).
    pub fn sqrt(&self, x: f32) -> Result<f32> {
        Ok(self.submit(OpKind::Sqrt, x, 1.0)?.recv()?.value.f32())
    }

    /// Convenience: blocking round-trip rsqrt (f32).
    pub fn rsqrt(&self, x: f32) -> Result<f32> {
        Ok(self.submit(OpKind::Rsqrt, x, 1.0)?.recv()?.value.f32())
    }

    /// Convenience: blocking round-trip divide in any format (operands
    /// encoded from f64 with round-to-nearest-even, result decoded
    /// exactly).
    pub fn divide_in(&self, format: FormatKind, n: f64, d: f64) -> Result<f64> {
        let rx = self.submit_value(
            OpKind::Divide,
            Value::from_f64(format, n),
            Value::from_f64(format, d),
        )?;
        Ok(rx.recv()?.value.to_f64())
    }

    /// Convenience: blocking round-trip sqrt in any format.
    pub fn sqrt_in(&self, format: FormatKind, x: f64) -> Result<f64> {
        let rx =
            self.submit_value(OpKind::Sqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(rx.recv()?.value.to_f64())
    }

    /// Convenience: blocking round-trip rsqrt in any format.
    pub fn rsqrt_in(&self, format: FormatKind, x: f64) -> Result<f64> {
        let rx =
            self.submit_value(OpKind::Rsqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(rx.recv()?.value.to_f64())
    }
}

/// The running service.
pub struct FpuService {
    handle: ServiceHandle,
    metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shutdown_tx: SyncSender<DispatchMsg>,
}

impl FpuService {
    /// Start the service. `make_executor` is called once on the caller
    /// thread (to validate the configuration and read the batch ladder)
    /// and once *inside each worker thread* — executors are not `Send`
    /// (the PJRT client wraps thread-local FFI state), so each worker
    /// owns an executor it built itself: one "divider unit" per worker.
    pub fn start<F>(config: ServiceConfig, make_executor: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        assert!(config.workers >= 1, "need at least one worker");
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<DispatchMsg>(config.queue_depth);

        // probe executor: validates the factory up front + batch ladders
        let probe = make_executor()?;
        let mut ladders: Vec<(OpKind, FormatKind, Vec<usize>)> = Vec::new();
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                ladders.push((op, format, probe.batch_ladder(op, format)));
            }
        }
        drop(probe);
        let batcher = DynamicBatcher::new(config.batcher, move |op, format| {
            ladders
                .iter()
                .find(|(o, f, _)| *o == op && *f == format)
                .map(|(_, _, l)| l.clone())
                .unwrap_or_default()
        });

        // worker channels: dispatcher round-robins batches across them
        let make_executor = Arc::new(make_executor);
        let mut batch_txs = Vec::new();
        let mut workers = Vec::new();
        for w in 0..config.workers {
            let (btx, brx) = mpsc::sync_channel::<Batch>(4);
            batch_txs.push(btx);
            let metrics = metrics.clone();
            let factory = make_executor.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fpu-worker-{w}"))
                    .spawn(move || match factory() {
                        Ok(executor) => worker_loop(brx, executor, metrics),
                        Err(e) => eprintln!("fpu-worker-{w}: executor init failed: {e:#}"),
                    })
                    .expect("spawn worker"),
            );
        }

        let dispatcher = std::thread::Builder::new()
            .name("fpu-dispatcher".into())
            .spawn(move || dispatcher_loop(rx, batcher, batch_txs, config.poll))
            .expect("spawn dispatcher");

        let handle = ServiceHandle { tx: tx.clone(), next_id: Arc::new(AtomicU64::new(0)) };
        Ok(Self {
            handle,
            metrics,
            dispatcher: Some(dispatcher),
            workers,
            shutdown_tx: tx,
        })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Graceful shutdown: drains queued work, joins all threads.
    pub fn shutdown(mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for FpuService {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    batcher: DynamicBatcher,
    batch_txs: Vec<SyncSender<Batch>>,
    poll: Duration,
) {
    let mut router = Router::new();
    let mut next_worker = 0usize;
    let dispatch = |batch: Batch, next_worker: &mut usize| {
        // round-robin; a full worker queue applies backpressure here
        let tx = &batch_txs[*next_worker % batch_txs.len()];
        *next_worker += 1;
        let _ = tx.send(batch); // worker gone => requests drop, senders see err
    };
    'outer: loop {
        // block for the first message (bounded by the poll tick) ...
        match rx.recv_timeout(poll) {
            Ok(DispatchMsg::Req(req)) => router.route(req),
            Ok(DispatchMsg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // ... then greedily drain the backlog so the batcher sees the
        // whole burst at once (otherwise a stale-age flush would emit
        // singleton batches while the queue still holds work)
        loop {
            match rx.try_recv() {
                Ok(DispatchMsg::Req(req)) => router.route(req),
                Ok(DispatchMsg::Shutdown) => break 'outer,
                Err(_) => break,
            }
        }
        for batch in batcher.ready_batches(&mut router, Instant::now()) {
            dispatch(batch, &mut next_worker);
        }
    }
    // drain everything left
    while let Ok(DispatchMsg::Req(req)) = rx.try_recv() {
        router.route(req);
    }
    for batch in batcher.flush_all(&mut router) {
        dispatch(batch, &mut next_worker);
    }
    // dropping batch_txs closes worker channels -> workers exit
}

fn worker_loop(rx: Receiver<Batch>, mut executor: Box<dyn Executor>, metrics: Arc<Metrics>) {
    while let Ok(batch) = rx.recv() {
        let t0 = Instant::now();
        let result = executor.execute(
            batch.op,
            batch.format,
            &batch.a,
            if batch.op == OpKind::Divide { Some(&batch.b) } else { None },
        );
        let exec_ns = t0.elapsed().as_nanos() as u64;
        match result {
            Ok(values) => {
                let done = Instant::now();
                let latencies: Vec<u64> = batch
                    .requests
                    .iter()
                    .map(|req| done.duration_since(req.enqueued_at).as_nanos() as u64)
                    .collect();
                // record metrics BEFORE replying: once a client observes
                // its response, the snapshot already includes it
                metrics.record_batch(batch.op, batch.format, &latencies, exec_ns, batch.padded);
                for (i, req) in batch.requests.iter().enumerate() {
                    let _ = req.reply.send(Response {
                        id: req.id,
                        value: Value::from_bits(batch.format, values[i]),
                        latency_ns: latencies[i],
                        batch_size: batch.padded,
                    });
                }
            }
            Err(_) => {
                // fail the whole batch: drop reply senders (receivers see
                // RecvError) and count the errors
                metrics.record_error(batch.op, batch.format, batch.requests.len() as u64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::executor::NativeExecutor;

    fn quick_config() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_micros(100) },
            queue_depth: 1024,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }

    fn native() -> Result<Box<dyn Executor>> {
        Ok(Box::new(NativeExecutor::with_defaults()))
    }

    #[test]
    fn round_trip_divide() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(10.0, 4.0).unwrap(), 2.5);
        assert_eq!(h.sqrt(81.0).unwrap(), 9.0);
        assert_eq!(h.rsqrt(4.0).unwrap(), 0.5);
        svc.shutdown();
    }

    #[test]
    fn round_trip_every_format() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
            // the response carries the request's format tag
            let rx = h
                .submit_value(
                    OpKind::Divide,
                    Value::from_f64(format, 6.0),
                    Value::from_f64(format, 2.0),
                )
                .unwrap();
            let resp = rx.recv().unwrap();
            assert_eq!(resp.value.format(), format);
            assert_eq!(resp.value.to_f64(), 3.0);
        }
        let snap = svc.metrics().snapshot();
        for format in FormatKind::ALL {
            assert!(snap.op_format(OpKind::Divide, format).requests >= 2, "{format}");
        }
        svc.shutdown();
    }

    #[test]
    fn mixed_format_operands_rejected() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let err = h.submit_value(OpKind::Divide, Value::F32(1.0), Value::F64(2.0));
        assert!(err.is_err());
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for i in 1..50u32 {
                    let n = (t * 100 + i) as f32;
                    let q = h.divide(n * 3.0, 3.0).unwrap();
                    assert_eq!(q, n);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op(OpKind::Divide).requests, 8 * 49);
        assert_eq!(snap.total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn batches_actually_form() {
        // long wait + many pipelined submissions => multi-request batches
        let mut cfg = quick_config();
        cfg.batcher.max_wait = Duration::from_millis(5);
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let rxs: Vec<_> =
            (0..200).map(|i| h.submit(OpKind::Divide, i as f32, 1.0).unwrap()).collect();
        let mut max_batch = 0usize;
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.value.f32(), i as f32);
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut cfg = quick_config();
        cfg.batcher.max_wait = Duration::from_secs(10); // only drain flushes
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let rxs: Vec<_> =
            (0..10).map(|i| h.submit(OpKind::Sqrt, (i * i) as f32, 1.0).unwrap()).collect();
        svc.shutdown(); // must flush the waiting batch
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().value.f32(), i as f32);
        }
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        svc.shutdown();
        assert!(h.divide(1.0, 1.0).is_err());
    }

    #[test]
    fn multiple_workers() {
        let mut cfg = quick_config();
        cfg.workers = 4;
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let rxs: Vec<_> =
            (1..=500).map(|i| h.submit(OpKind::Divide, (2 * i) as f32, 2.0).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().value.f32(), (i + 1) as f32);
        }
        svc.shutdown();
    }

    #[test]
    fn failing_executor_reports_errors() {
        struct Failing;
        impl Executor for Failing {
            fn batch_ladder(&self, _op: OpKind, _format: FormatKind) -> Vec<usize> {
                vec![64]
            }
            fn execute(
                &mut self,
                _: OpKind,
                _: FormatKind,
                _: &[u64],
                _: Option<&[u64]>,
            ) -> Result<Vec<u64>> {
                bail!("injected failure")
            }
            fn name(&self) -> &'static str {
                "failing"
            }
        }
        let svc =
            FpuService::start(quick_config(), || Ok(Box::new(Failing) as Box<dyn Executor>))
                .unwrap();
        let h = svc.handle();
        let rx = h.submit(OpKind::Divide, 1.0, 1.0).unwrap();
        // reply sender dropped on failure -> RecvError
        assert!(rx.recv().is_err());
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.total_errors(), 1);
        svc.shutdown();
    }
}
