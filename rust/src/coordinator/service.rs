//! The threaded FPU service: lifecycle, backpressure, dispatch loop and
//! worker pool. This is the event loop the paper's "divider unit as a
//! shared resource" maps onto: many clients, one (or a few) expensive
//! execution engines, a batching layer in between.
//!
//! Threading model (std threads + channels; no async runtime exists in
//! the offline environment, and none is needed):
//!
//! * clients hold a [`ServiceHandle`] and submit into a *bounded*
//!   channel — the backpressure boundary; a full queue pushes back on
//!   submitters (or returns [`ServiceError::Overloaded`] from the
//!   `try_submit` family) instead of growing without bound;
//! * one **dispatcher** thread owns the [`Router`] + [`DynamicBatcher`]
//!   and turns the work stream into batches, shedding expired-deadline
//!   items and skipping dead workers' channels;
//! * `workers` **executor** threads each own one [`Executor`] (one
//!   "divider unit" each) and execute batches round-robin into a
//!   reused output plane, completing each item's ticket in place.
//!
//! Startup is fail-fast: the executor factory is probed once on the
//! caller thread (capability negotiation), and every worker reports its
//! own factory result back before [`FpuService::start`] returns — a
//! worker that cannot build its executor fails `start` instead of
//! silently eating a share of the traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::formats::{PlaneRefMut, PlaneWidth};
use crate::runtime::caps::BackendCaps;
use crate::runtime::executor::Executor;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PlanePool};
use super::metrics::Metrics;
use super::request::{FormatKind, OpKind, ServiceError, Value, WorkItem};
use super::router::Router;
use super::ticket::{BatchTicket, Ticket};

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Batching policy (global knobs + per-(op, format) overrides).
    pub batcher: BatcherConfig,
    /// Bounded submit-queue depth (the backpressure knob).
    pub queue_depth: usize,
    /// Number of executor workers (parallel "divider units").
    pub workers: usize,
    /// Dispatcher poll granularity when idle.
    pub poll: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            queue_depth: 16_384,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }
}

enum DispatchMsg {
    Req(WorkItem),
    Shutdown,
}

/// Client-side handle: cheap to clone, safe across threads. Every
/// submission returns a [`Ticket`] / [`BatchTicket`] backed by a shared
/// completion slot — no per-request channel — and every failure is a
/// typed [`ServiceError`].
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<DispatchMsg>,
    next_id: Arc<AtomicU64>,
    caps: Arc<BackendCaps>,
    metrics: Arc<Metrics>,
}

impl ServiceHandle {
    /// The backend's negotiated capability table (what this service can
    /// serve, per (op, format), and at which batch sizes).
    pub fn capabilities(&self) -> &BackendCaps {
        &self.caps
    }

    /// Deadline admission control: a deadline-carrying submission whose
    /// budget is already smaller than the queue-delay estimate for its
    /// (op, format) slot is rejected **at submit time** with
    /// [`ServiceError::Deadline`] — the work never enters the queue
    /// only to be shed at batch formation. The estimate is windowed
    /// (median worst-rider latency over the slot's recent batches, see
    /// [`Metrics::queue_delay_estimate_ns`]), and every N-th
    /// would-reject is admitted anyway as a probe
    /// ([`Metrics::admission_probe`]), so a rejecting slot keeps
    /// sampling the service and recovers as soon as the backlog
    /// clears. With no signal yet (a cold service) everything is
    /// admitted and deadline enforcement falls to the batcher's shed
    /// path as before.
    fn admit_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        lanes: usize,
        deadline: Duration,
    ) -> Result<(), ServiceError> {
        if let Some(est_ns) = self.metrics.queue_delay_estimate_ns(op, format) {
            if Duration::from_nanos(est_ns) > deadline && !self.metrics.admission_probe(op, format)
            {
                self.metrics.record_admission_reject(op, format, lanes as u64);
                return Err(ServiceError::Deadline);
            }
        }
        Ok(())
    }

    fn check_supported(&self, op: OpKind, format: FormatKind) -> Result<(), ServiceError> {
        if self.caps.supports(op, format) {
            Ok(())
        } else {
            Err(ServiceError::Rejected {
                reason: format!(
                    "backend {} does not serve ({}, {format})",
                    self.caps.backend(),
                    op.label()
                ),
            })
        }
    }

    fn send(&self, item: WorkItem) -> Result<(), ServiceError> {
        // a failed send drops the item, which fails its ticket — but the
        // caller gets the error directly and never sees that ticket
        self.tx.send(DispatchMsg::Req(item)).map_err(|_| ServiceError::Shutdown)
    }

    /// Validation shared by the single-request submit family (cheap:
    /// two compares, no allocation — the admission reject path relies
    /// on that).
    fn check_single(&self, op: OpKind, a: Value, b: Value) -> Result<(), ServiceError> {
        if a.format() != b.format() {
            return Err(ServiceError::Rejected {
                reason: format!("operand format mismatch: {} vs {}", a.format(), b.format()),
            });
        }
        self.check_supported(op, a.format())
    }

    fn make_single(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Option<Duration>,
    ) -> Result<(WorkItem, Ticket), ServiceError> {
        self.check_single(op, a, b)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(WorkItem::single(id, op, a, b, deadline.map(|d| Instant::now() + d)))
    }

    /// Submit one op on format-tagged operands; returns the [`Ticket`]
    /// resolving it. Blocks while the submit queue is full
    /// (backpressure). Both operands must share a format (pass
    /// `Value::one(format)` as `b` for unary ops).
    pub fn submit_value(&self, op: OpKind, a: Value, b: Value) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        self.send(item)?;
        Ok(ticket)
    }

    /// [`Self::submit_value`] with a completion deadline. Admission
    /// control runs first: when the queue-delay estimate already
    /// exceeds `deadline`, the submission fails immediately with
    /// [`ServiceError::Deadline`]. Once admitted, a request still
    /// queued when the deadline arrives is shed by the dispatcher
    /// (counted in metrics) and the ticket resolves to
    /// [`ServiceError::Deadline`] instead of executing stale work.
    pub fn submit_value_deadline(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Duration,
    ) -> Result<Ticket, ServiceError> {
        // validate first (a malformed submission is Rejected with its
        // reason, never misreported as a Deadline admission miss), and
        // only construct once admitted — the overload reject path
        // allocates nothing
        self.check_single(op, a, b)?;
        self.admit_deadline(op, a.format(), 1, deadline)?;
        let (item, ticket) = self.make_single(op, a, b, Some(deadline))?;
        self.send(item)?;
        Ok(ticket)
    }

    /// Submit one f32 op (the single-precision convenience path).
    pub fn submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.submit_value(op, Value::F32(a), Value::F32(b))
    }

    /// Non-blocking submit of format-tagged operands:
    /// [`ServiceError::Overloaded`] when the queue is full.
    pub fn try_submit_value(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
    ) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        match self.tx.try_send(DispatchMsg::Req(item)) {
            Ok(()) => Ok(ticket),
            Err(TrySendError::Full(_)) => Err(ServiceError::Overloaded),
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::Shutdown),
        }
    }

    /// Non-blocking f32 submit: [`ServiceError::Overloaded`] when full.
    pub fn try_submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.try_submit_value(op, Value::F32(a), Value::F32(b))
    }

    fn check_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<(), ServiceError> {
        if a.is_empty() {
            return Err(ServiceError::Rejected { reason: "empty batch".into() });
        }
        match op {
            OpKind::Divide if b.len() != a.len() => {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "divide needs matching operand planes ({} vs {})",
                        a.len(),
                        b.len()
                    ),
                });
            }
            OpKind::Sqrt | OpKind::Rsqrt if !b.is_empty() => {
                return Err(ServiceError::Rejected {
                    reason: format!("{} takes one operand plane", op.label()),
                });
            }
            _ => {}
        }
        // raw words must fit the format's container: the queue stores
        // planes width-true, so an oversized word would otherwise be a
        // debug panic / silent release truncation instead of a typed
        // rejection of bad client input
        if format.total_bits() < 64 {
            let mask = !((1u64 << format.total_bits()) - 1);
            if let Some(bad) = a.iter().chain(b.iter()).find(|&&w| w & mask != 0) {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "operand word {bad:#x} does not fit a {}-bit {format} container",
                        format.total_bits()
                    ),
                });
            }
        }
        self.check_supported(op, format)
    }

    /// Callers have already run [`Self::check_batch`].
    fn submit_batch_inner(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Option<Duration>,
    ) -> Result<BatchTicket, ServiceError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (item, ticket) =
            WorkItem::group(id, op, format, a, b, deadline.map(|d| Instant::now() + d));
        self.send(item)?;
        Ok(ticket)
    }

    /// Vectored submission: a whole operand plane (raw `format` words)
    /// as **one** queue entry with **one** completion slot. The group
    /// enters the router pre-formed — batch locality is preserved, not
    /// re-discovered — and is split only at executable-ladder
    /// boundaries. `b` is the divisor plane for divide (same length as
    /// `a`) and must be empty for unary ops.
    pub fn submit_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<BatchTicket, ServiceError> {
        self.check_batch(op, format, a, b)?;
        self.submit_batch_inner(op, format, a, b, None)
    }

    /// [`Self::submit_batch`] with a completion deadline covering the
    /// whole group. Admission control applies as in
    /// [`Self::submit_value_deadline`]: a budget the queue-delay
    /// estimate already exceeds is rejected here, before any queueing.
    pub fn submit_batch_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Duration,
    ) -> Result<BatchTicket, ServiceError> {
        // validation precedes admission (see submit_value_deadline)
        self.check_batch(op, format, a, b)?;
        self.admit_deadline(op, format, a.len(), deadline)?;
        self.submit_batch_inner(op, format, a, b, Some(deadline))
    }

    /// Convenience: blocking round-trip divide (f32).
    pub fn divide(&self, n: f32, d: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Divide, n, d)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip sqrt (f32).
    pub fn sqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Sqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip rsqrt (f32).
    pub fn rsqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Rsqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip divide in any format (operands
    /// encoded from f64 with round-to-nearest-even, result decoded
    /// exactly).
    pub fn divide_in(&self, format: FormatKind, n: f64, d: f64) -> Result<f64, ServiceError> {
        let t = self.submit_value(
            OpKind::Divide,
            Value::from_f64(format, n),
            Value::from_f64(format, d),
        )?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip sqrt in any format.
    pub fn sqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Sqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip rsqrt in any format.
    pub fn rsqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Rsqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }
}

/// The running service.
pub struct FpuService {
    handle: ServiceHandle,
    metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shutdown_tx: SyncSender<DispatchMsg>,
}

impl FpuService {
    /// Start the service. `make_executor` is called once on the caller
    /// thread (capability negotiation: the probe's [`BackendCaps`] are
    /// kept for the life of the service) and once *inside each worker
    /// thread* — executors are not `Send` (the PJRT client wraps
    /// thread-local FFI state), so each worker owns an executor it built
    /// itself: one "divider unit" per worker. Any worker whose factory
    /// fails makes `start` return that error — no silently dead
    /// workers.
    pub fn start<F>(config: ServiceConfig, make_executor: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        assert!(config.workers >= 1, "need at least one worker");
        let metrics = Arc::new(Metrics::new());
        let pool = PlanePool::new();
        let (tx, rx) = mpsc::sync_channel::<DispatchMsg>(config.queue_depth);

        // probe executor: validates the factory and negotiates the
        // capability table (support + batch ladders, one call)
        let caps =
            Arc::new(make_executor().context("probing executor capabilities")?.capabilities());
        let batcher = DynamicBatcher::new(config.batcher, &caps);

        // worker channels: dispatcher round-robins batches across them
        let make_executor = Arc::new(make_executor);
        let (init_tx, init_rx) = mpsc::channel::<(usize, std::result::Result<(), String>)>();
        let mut batch_txs = Vec::new();
        let mut workers = Vec::new();
        for w in 0..config.workers {
            let (btx, brx) = mpsc::sync_channel::<Batch>(4);
            batch_txs.push(btx);
            let metrics = metrics.clone();
            let pool = pool.clone();
            let factory = make_executor.clone();
            let init_tx = init_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fpu-worker-{w}"))
                    .spawn(move || match factory() {
                        Ok(executor) => {
                            let _ = init_tx.send((w, Ok(())));
                            drop(init_tx);
                            worker_loop(brx, executor, metrics, pool);
                        }
                        Err(e) => {
                            let _ = init_tx.send((w, Err(format!("{e:#}"))));
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        drop(init_tx);

        // fail-fast: every worker reports its init before we go live
        for _ in 0..config.workers {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((w, Err(msg))) => {
                    drop(batch_txs); // close channels -> live workers exit
                    for h in workers {
                        let _ = h.join();
                    }
                    bail!("fpu-worker-{w}: executor init failed: {msg}");
                }
                Err(_) => {
                    drop(batch_txs);
                    for h in workers {
                        let _ = h.join();
                    }
                    bail!("a worker exited before reporting executor init");
                }
            }
        }

        let dispatcher = {
            let metrics = metrics.clone();
            let pool = pool.clone();
            std::thread::Builder::new()
                .name("fpu-dispatcher".into())
                .spawn(move || dispatcher_loop(rx, batcher, batch_txs, config.poll, metrics, pool))
                .expect("spawn dispatcher")
        };

        let handle = ServiceHandle {
            tx: tx.clone(),
            next_id: Arc::new(AtomicU64::new(0)),
            caps,
            metrics: metrics.clone(),
        };
        Ok(Self {
            handle,
            metrics,
            dispatcher: Some(dispatcher),
            workers,
            shutdown_tx: tx,
        })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The backend's negotiated capability table.
    pub fn capabilities(&self) -> &BackendCaps {
        self.handle.capabilities()
    }

    /// Graceful shutdown: drains queued work, joins all threads.
    pub fn shutdown(mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for FpuService {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Hand one batch to a live worker, skipping closed channels (a worker
/// whose thread died). With every worker gone the batch is failed with
/// a typed [`ServiceError::Shutdown`] instead of vanishing.
fn dispatch(
    mut batch: Batch,
    live: &mut Vec<SyncSender<Batch>>,
    next_worker: &mut usize,
    metrics: &Metrics,
    pool: &PlanePool,
) {
    while !live.is_empty() {
        let i = *next_worker % live.len();
        *next_worker += 1;
        // round-robin; a full worker queue applies backpressure here
        match live[i].send(batch) {
            Ok(()) => return,
            Err(mpsc::SendError(returned)) => {
                batch = returned;
                live.remove(i); // dead worker: never pick it again
            }
        }
    }
    metrics.record_error(batch.op, batch.format, batch.live() as u64);
    for item in batch.items.drain(..) {
        item.fail(ServiceError::Shutdown);
    }
    pool.give(std::mem::take(&mut batch.a));
    pool.give(std::mem::take(&mut batch.b));
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    batcher: DynamicBatcher,
    batch_txs: Vec<SyncSender<Batch>>,
    poll: Duration,
    metrics: Arc<Metrics>,
    pool: PlanePool,
) {
    let mut router = Router::new();
    let mut live = batch_txs;
    let mut next_worker = 0usize;
    'outer: loop {
        // block for the first message (bounded by the poll tick) ...
        match rx.recv_timeout(poll) {
            Ok(DispatchMsg::Req(req)) => router.route(req),
            Ok(DispatchMsg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // ... then greedily drain the backlog so the batcher sees the
        // whole burst at once (otherwise a stale-age flush would emit
        // singleton batches while the queue still holds work)
        loop {
            match rx.try_recv() {
                Ok(DispatchMsg::Req(req)) => router.route(req),
                Ok(DispatchMsg::Shutdown) => break 'outer,
                Err(_) => break,
            }
        }
        for batch in batcher.ready_batches(&mut router, Instant::now(), &pool, &metrics) {
            dispatch(batch, &mut live, &mut next_worker, &metrics, &pool);
        }
    }
    // drain everything left
    while let Ok(DispatchMsg::Req(req)) = rx.try_recv() {
        router.route(req);
    }
    for batch in batcher.flush_all(&mut router, Instant::now(), &pool, &metrics) {
        dispatch(batch, &mut live, &mut next_worker, &metrics, &pool);
    }
    // dropping batch senders closes worker channels -> workers exit
}

fn worker_loop(
    rx: Receiver<Batch>,
    mut executor: Box<dyn Executor>,
    metrics: Arc<Metrics>,
    pool: PlanePool,
) {
    // all buffers persist across batches: the steady-state hot path
    // performs no allocation in this loop (execute_into writes in place
    // at the batch's plane width, operand planes go back to the pool).
    // One output buffer per width; `widened` is the u64 view the ticket
    // boundary needs for u32 batches.
    let mut out32: Vec<u32> = Vec::new();
    let mut out64: Vec<u64> = Vec::new();
    let mut widened: Vec<u64> = Vec::new();
    let mut lat: Vec<(u64, usize)> = Vec::new();
    while let Ok(mut batch) = rx.recv() {
        let width = batch.a.width();
        let b_plane = if batch.op == OpKind::Divide { Some(batch.b.as_ref()) } else { None };
        let t0 = Instant::now();
        let result = match width {
            PlaneWidth::W32 => {
                out32.clear();
                out32.resize(batch.padded, 0);
                executor.execute_into(
                    batch.op,
                    batch.format,
                    batch.a.as_ref(),
                    b_plane,
                    PlaneRefMut::W32(&mut out32),
                )
            }
            PlaneWidth::W64 => {
                out64.clear();
                out64.resize(batch.padded, 0);
                executor.execute_into(
                    batch.op,
                    batch.format,
                    batch.a.as_ref(),
                    b_plane,
                    PlaneRefMut::W64(&mut out64),
                )
            }
        };
        let exec_ns = t0.elapsed().as_nanos() as u64;
        match result {
            Ok(()) => {
                let done = Instant::now();
                lat.clear();
                for item in &batch.items {
                    lat.push((
                        done.duration_since(item.enqueued_at).as_nanos() as u64,
                        item.lanes(),
                    ));
                }
                // record metrics BEFORE completing: once a client observes
                // its response, the snapshot already includes it
                metrics.record_batch(batch.op, batch.format, &lat, exec_ns, batch.padded);
                // tickets store u64 result words: widen u32 result
                // planes once per batch (the one narrowing boundary)
                let view: &[u64] = match width {
                    PlaneWidth::W32 => {
                        widened.clear();
                        widened.extend(out32.iter().map(|&w| w as u64));
                        &widened
                    }
                    PlaneWidth::W64 => &out64,
                };
                let mut off = 0usize;
                for (k, item) in batch.items.drain(..).enumerate() {
                    let lanes = item.lanes();
                    item.complete(&view[off..off + lanes], lat[k].0, batch.padded);
                    off += lanes;
                }
            }
            Err(e) => {
                // fail the whole batch with the backend's message: every
                // rider's ticket resolves to ExecFailed
                metrics.record_error(batch.op, batch.format, batch.live() as u64);
                let backend = format!("{e:#}");
                for item in batch.items.drain(..) {
                    item.fail(ServiceError::ExecFailed { backend: backend.clone() });
                }
            }
        }
        pool.give(std::mem::take(&mut batch.a));
        pool.give(std::mem::take(&mut batch.b));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::PlaneRef;
    use crate::runtime::executor::NativeExecutor;

    fn quick_config() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig::new(64, Duration::from_micros(100)),
            queue_depth: 1024,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }

    fn native() -> Result<Box<dyn Executor>> {
        Ok(Box::new(NativeExecutor::with_defaults()))
    }

    #[test]
    fn round_trip_divide() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(10.0, 4.0).unwrap(), 2.5);
        assert_eq!(h.sqrt(81.0).unwrap(), 9.0);
        assert_eq!(h.rsqrt(4.0).unwrap(), 0.5);
        svc.shutdown();
    }

    #[test]
    fn round_trip_every_format() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
            // the response carries the request's format tag
            let t = h
                .submit_value(
                    OpKind::Divide,
                    Value::from_f64(format, 6.0),
                    Value::from_f64(format, 2.0),
                )
                .unwrap();
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.format(), format);
            assert_eq!(resp.value.to_f64(), 3.0);
        }
        let snap = svc.metrics().snapshot();
        for format in FormatKind::ALL {
            assert!(snap.op_format(OpKind::Divide, format).requests >= 2, "{format}");
        }
        svc.shutdown();
    }

    #[test]
    fn mixed_format_operands_rejected() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_value(OpKind::Divide, Value::F32(1.0), Value::F64(2.0)) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("format mismatch"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        svc.shutdown();
    }

    #[test]
    fn capabilities_visible_on_handle() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let caps = svc.handle().capabilities().clone();
        assert_eq!(caps.backend(), "native-fixed-point");
        assert!(caps.supports(OpKind::Divide, FormatKind::BF16));
        assert_eq!(caps.ladder(OpKind::Divide, FormatKind::F32), &[64, 256, 1024]);
        assert_eq!(svc.capabilities().backend(), "native-fixed-point");
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for i in 1..50u32 {
                    let n = (t * 100 + i) as f32;
                    let q = h.divide(n * 3.0, 3.0).unwrap();
                    assert_eq!(q, n);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op(OpKind::Divide).requests, 8 * 49);
        assert_eq!(snap.total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn batches_actually_form() {
        // long wait + many pipelined submissions => multi-request batches
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_millis(5));
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..200).map(|i| h.submit(OpKind::Divide, i as f32, 1.0).unwrap()).collect();
        let mut max_batch = 0usize;
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.f32(), i as f32);
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_round_trip() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let n: Vec<u64> = (1..=100u32).map(|i| ((3 * i) as f32).to_bits() as u64).collect();
        let d: Vec<u64> = (1..=100u32).map(|_| 3.0f32.to_bits() as u64).collect();
        let ticket = h.submit_batch(OpKind::Divide, FormatKind::F32, &n, &d).unwrap();
        assert_eq!(ticket.lanes(), 100);
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.len(), 100);
        for (i, v) in resp.values().enumerate() {
            assert_eq!(v.f32(), (i + 1) as f32, "lane {i}");
        }
        // unary vectored path
        let x: Vec<u64> = [4.0f32, 9.0, 16.0].iter().map(|v| v.to_bits() as u64).collect();
        let resp = h.submit_batch(OpKind::Sqrt, FormatKind::F32, &x, &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 3);
        assert_eq!(resp.value(0).f32(), 2.0);
        assert_eq!(resp.value(2).f32(), 4.0);
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_validates_arity() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let a = [1.0f32.to_bits() as u64];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::F32, &a, &[]),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &a, &a),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &[], &[]),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_rejects_oversized_words() {
        // a raw word that does not fit the format's container is a
        // typed Rejected, not a narrowing panic or silent truncation
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x1_0000], &[]) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("does not fit"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        // the divisor plane is checked too
        let ok = [0x3C00u64, 0x4000];
        let bad = [0x3C00u64, u64::MAX];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::BF16, &ok, &bad),
            Err(ServiceError::Rejected { .. })
        ));
        // in-range f16 words and full-width f64 words pass
        let resp =
            h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x4400], &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 1);
        let w = (-2.0f64).to_bits(); // high bit set: fine for a 64-bit container
        assert!(h.submit_batch(OpKind::Sqrt, FormatKind::F64, &[w], &[]).is_ok());
        svc.shutdown();
    }

    #[test]
    fn deadline_admission_rejects_at_submit() {
        // the ROADMAP admission-control item: once the queue-delay
        // estimate (observed p50 latency) exceeds a submission's
        // budget, the submission fails with Deadline at submit time —
        // before any queueing
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        // a cold service has no estimate: even a tiny budget is admitted
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(6.0),
                Value::F32(2.0),
                Duration::from_secs(5),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 3.0);
        // seed the estimator: observed latency ~10ms on (divide, f32)
        for _ in 0..8 {
            svc.metrics().record_batch(
                OpKind::Divide,
                FormatKind::F32,
                &[(10_000_000, 1)],
                1_000,
                1,
            );
        }
        // a 50us budget is now hopeless: rejected at submit, typed
        match h.submit_value_deadline(
            OpKind::Divide,
            Value::F32(6.0),
            Value::F32(2.0),
            Duration::from_micros(50),
        ) {
            Err(ServiceError::Deadline) => {}
            other => panic!("expected Deadline at submit, got {:?}", other.map(|t| t.id())),
        }
        // the vectored path is gated the same way, counting every lane
        let a: Vec<u64> = vec![2.0f32.to_bits() as u64; 10];
        assert!(matches!(
            h.submit_batch_deadline(
                OpKind::Divide,
                FormatKind::F32,
                &a,
                &a,
                Duration::from_micros(50)
            ),
            Err(ServiceError::Deadline)
        ));
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op_format(OpKind::Divide, FormatKind::F32).admission_rejected, 11);
        assert_eq!(snap.total_shed(), 0, "admission rejects are not queue sheds");
        // a generous budget still passes admission and completes
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(8.0),
                Value::F32(2.0),
                Duration::from_secs(30),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 4.0);
        // other (op, format) slots are unaffected by this slot's history
        let t = h
            .submit_value_deadline(
                OpKind::Sqrt,
                Value::F32(9.0),
                Value::F32(1.0),
                Duration::from_micros(50),
            )
            .unwrap();
        let _ = t.wait(); // may complete or shed; must not reject at submit
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_secs(10)); // only drain flushes
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..10).map(|i| h.submit(OpKind::Sqrt, (i * i) as f32, 1.0).unwrap()).collect();
        svc.shutdown(); // must flush the waiting batch
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), i as f32);
        }
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        svc.shutdown();
        assert_eq!(h.divide(1.0, 1.0).unwrap_err(), ServiceError::Shutdown);
    }

    #[test]
    fn multiple_workers() {
        let mut cfg = quick_config();
        cfg.workers = 4;
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (1..=500).map(|i| h.submit(OpKind::Divide, (2 * i) as f32, 2.0).unwrap()).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), (i + 1) as f32);
        }
        svc.shutdown();
    }

    #[test]
    fn failing_executor_reports_typed_errors() {
        struct Failing;
        impl Executor for Failing {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::uniform("failing", &[64])
            }
            fn execute_into(
                &mut self,
                _: OpKind,
                _: FormatKind,
                _: PlaneRef<'_>,
                _: Option<PlaneRef<'_>>,
                _: PlaneRefMut<'_>,
            ) -> Result<()> {
                bail!("injected failure")
            }
        }
        let svc =
            FpuService::start(quick_config(), || Ok(Box::new(Failing) as Box<dyn Executor>))
                .unwrap();
        let h = svc.handle();
        let t = h.submit(OpKind::Divide, 1.0, 1.0).unwrap();
        // the backend's message reaches the client, typed
        match t.wait() {
            Err(ServiceError::ExecFailed { backend }) => {
                assert!(backend.contains("injected failure"), "{backend}");
            }
            other => panic!("expected ExecFailed, got {other:?}"),
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.total_errors(), 1);
        svc.shutdown();
    }

    #[test]
    fn unsupported_pair_rejected_at_submit() {
        // a backend that only serves f32 divide: everything else is
        // rejected before queueing, with the backend named
        struct DivOnly(NativeExecutor);
        impl Executor for DivOnly {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::new("div-only").with(OpKind::Divide, FormatKind::F32, &[64])
            }
            fn execute_into(
                &mut self,
                op: OpKind,
                format: FormatKind,
                a: PlaneRef<'_>,
                b: Option<PlaneRef<'_>>,
                out: PlaneRefMut<'_>,
            ) -> Result<()> {
                self.0.execute_into(op, format, a, b, out)
            }
        }
        let svc = FpuService::start(quick_config(), || {
            Ok(Box::new(DivOnly(NativeExecutor::with_defaults())) as Box<dyn Executor>)
        })
        .unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(6.0, 2.0).unwrap(), 3.0);
        match h.sqrt(4.0) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("div-only"), "{reason}");
                assert!(reason.contains("sqrt"), "{reason}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert!(matches!(
            h.divide_in(FormatKind::F64, 1.0, 1.0),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }
}
