//! The threaded FPU service: lifecycle, backpressure, dispatch loop and
//! supervised worker pools. This is the event loop the paper's "divider
//! unit as a shared resource" maps onto: many clients, one (or a few)
//! expensive execution engines, a batching layer in between.
//!
//! Threading model (std threads + channels; no async runtime exists in
//! the offline environment, and none is needed). The coordinator is
//! **sharded**: [`ServiceConfig::shards`] independent copies of the
//! dispatch machinery, so the submit hot path never crosses a lock
//! shared between shards.
//!
//! * clients hold a [`ServiceHandle`]; each submission hashes
//!   `(op, format, handle key)` to a **shard** and publishes into that
//!   shard's *bounded lock-free MPSC ring* ([`super::ring::SubmitRing`])
//!   — one CAS plus one release store, no lock. The ring is the
//!   backpressure boundary: a full ring pushes back on blocking
//!   submitters (or returns [`ServiceError::Overloaded`] from the
//!   `try_submit` family) instead of growing without bound. One
//!   handle's stream for a given (op, format) always lands on one
//!   shard, so its submission order is preserved end to end;
//! * each shard runs one **dispatcher** thread owning that shard's
//!   [`Router`] + [`DynamicBatcher`] + [`DispatchPlane`] + plane pool.
//!   It parks on an event count when its ring runs dry, and turns the
//!   work stream into batches — shedding expired-deadline items,
//!   selecting a backend per batch (policy + circuit breakers, on the
//!   **shared** health board), and re-routing batches a backend fails.
//!   Formed, backend-selected batches pass through the shard's *ready
//!   queue*; an idle peer dispatcher may **steal** the oldest ready
//!   batch of a stalled shard (whole batches only, never individual
//!   lanes, so bit-identity and per-handle ordering invariants hold)
//!   and dispatch it on its own worker set;
//! * each shard × registered backend owns a **worker pool** of executor
//!   threads, each owning one [`Executor`] (one "divider unit" each),
//!   executing its backend's batches round-robin into a reused output
//!   plane and completing each item's ticket in place (ticket
//!   completion keeps its condvar — only submit-side contention is
//!   gone). Executor calls run under `catch_unwind`: a worker that
//!   panics fails its batch over like any executor error (the riders
//!   never see the panic) and then exits; outcomes are recorded on the
//!   backend's [`HealthBoard`] slot, which is what every shard's
//!   dispatcher routes by;
//! * one **supervisor** thread watches for abnormal worker exits
//!   (panic, injected death) across all shards and respawns
//!   replacements with capped exponential backoff; a pool whose
//!   respawns keep failing is marked *degraded* on the health board
//!   and routed around until a respawn sticks.
//!
//! Startup is fail-fast: every registered executor factory is probed
//! once on the caller thread (capability negotiation, merged into the
//! routing table), and every worker of every pool of every shard
//! reports its own factory result back before
//! [`FpuService::start_routed`] returns — a worker that cannot build
//! its executor fails start instead of silently eating a share of the
//! traffic.
//!
//! Metrics are sliced per shard and merged at read time
//! ([`ServiceMetrics`]), so reports and the stats emitter always cover
//! every shard.
//!
//! Two opt-in planes extend the lifecycle story:
//!
//! * **Durability** — with [`ServiceConfig::journal`] set, the service
//!   opens an append-only CRC-guarded [`Journal`] and exposes
//!   [`FpuService::submit_batch_durable`] / [`FpuService::poll_job`]:
//!   each durable submission is journalled `Pending` before it is
//!   queued and `Done`/`Failed` when its ticket resolves, and a
//!   restart replays still-`Pending` records through the normal submit
//!   path exactly once ([`FpuService::replayed_jobs`]).
//! * **Chaos** — with [`ServiceConfig::fault`] armed, a deterministic
//!   [`FaultPlan`] (see [`crate::fault`]) injects executor errors,
//!   panics, latency, bit flips, worker deaths and slow drains at
//!   seeded occurrence schedules, exercising every recovery path above
//!   reproducibly. An unarmed service pays one `Option` check.

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::dispatch::{
    BackendHealthSnapshot, DispatchPlane, ExecutorFactory, ExecutorRegistry, HealthBoard,
    RoutingTable,
};
use crate::fault::{FaultPlan, FaultSite};
use crate::formats::{PlaneRefMut, PlaneWidth};
use crate::obs::{TraceConfig, TraceEvent, TraceKind, TracePlane};
use crate::runtime::caps::BackendCaps;
use crate::runtime::executor::Executor;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PlanePool};
use super::journal::{coalesce, JobStatus, Journal, JournalRecord};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{op_format_slot, FormatKind, OpKind, ServiceError, Value, WorkItem};
use super::ring::{EventCount, SubmitRing};
use super::router::Router;
use super::ticket::{BatchTicket, Ticket};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Batching policy (global knobs + per-(op, format) overrides).
    pub batcher: BatcherConfig,
    /// Bounded submit-queue depth (the backpressure knob).
    pub queue_depth: usize,
    /// Number of executor workers **per backend pool** (parallel
    /// "divider units"; a registry entry can override its own pool
    /// size).
    pub workers: usize,
    /// Dispatcher poll granularity when idle.
    pub poll: Duration,
    /// Armed fault-injection plan (`None` = no chaos; see
    /// [`crate::fault`]). Wraps every registered executor and feeds the
    /// worker-thread hook points.
    pub fault: Option<Arc<FaultPlan>>,
    /// Path of the durable request journal (`None` = the
    /// `submit_batch_durable` family is rejected). Opened (and its torn
    /// tail truncated) at start; still-`Pending` records are replayed.
    pub journal: Option<PathBuf>,
    /// How long the shutdown retire loop keeps servicing the retry
    /// channel *without progress* while batches are in flight. Progress
    /// (a serviced retry) resets the clock, so a long candidate chain
    /// gets this budget per hop, not one shared bound.
    pub retire_budget: Duration,
    /// Trace-plane configuration (`None` = tracing off; an untraced
    /// service pays one `Option` check per hook point). See
    /// [`crate::obs`] for the sampling and export story.
    pub trace: Option<TraceConfig>,
    /// Emit a one-line service snapshot delta at this interval from a
    /// dedicated `fpu-stats-emitter` thread (`None` = no emitter).
    pub stats_interval: Option<Duration>,
    /// Coordinator shard count. Each shard owns its own submit ring,
    /// router, batcher, dispatch plane, plane pool, metrics slice and
    /// worker set; submissions hash `(op, format, handle)` to a shard.
    /// `1` (the default) reproduces the single-dispatcher service
    /// exactly; `0` means auto — one shard per available CPU (the CLI's
    /// `serve --shards` maps straight onto this field).
    pub shards: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            queue_depth: 16_384,
            workers: 1,
            poll: Duration::from_micros(50),
            fault: None,
            journal: None,
            retire_budget: SHUTDOWN_RETIRE_BUDGET,
            trace: None,
            stats_interval: None,
            shards: 1,
        }
    }
}

enum DispatchMsg {
    Req(WorkItem),
    Shutdown,
}

/// One shard's submit-side state, shared between client handles (the
/// publish side), the shard's own dispatcher (the consume side), and
/// peer dispatchers (the stealing side).
struct ShardShared {
    /// Bounded lock-free submit ring: the backpressure boundary. The
    /// submit hot path is one CAS plus one release store into here.
    ring: SubmitRing<DispatchMsg>,
    /// Parking for the shard's dispatcher when its ring runs dry;
    /// producers pay a fence + one relaxed load to wake it.
    events: EventCount,
    /// This shard's metrics slice (queue gauges, admission model,
    /// latency histograms). [`ServiceMetrics`] merges the slices at
    /// read time.
    metrics: Arc<Metrics>,
    /// Formed, backend-selected batches awaiting dispatch. The owner
    /// pushes and normally drains immediately; a peer may steal the
    /// **front** (oldest) batch once it has sat for [`STEAL_AGE`] —
    /// whole batches only, never individual lanes, so bit-identity and
    /// per-handle ordering invariants hold.
    ready: Mutex<VecDeque<Batch>>,
    /// Batches peers stole from this shard's ready queue.
    steals: AtomicU64,
    /// Batches this shard's dispatcher stole from peers' ready queues
    /// (the thief-side count; [`ShardShared::steals`] is the victim
    /// side).
    steals_in: AtomicU64,
    /// Submissions bounced with [`ServiceError::Overloaded`] because
    /// this shard's ring was full (the `try_submit` family) or the
    /// `ring-full` chaos site fired. The blocking submit path
    /// backpressures instead of rejecting, so it never counts here.
    ring_full_rejects: AtomicU64,
    /// Fault-site filter name (`"shard0"`, `"shard1"`, ...) for the
    /// `ring-stall` / `ring-full` chaos sites.
    name: String,
}

/// SplitMix64-style finalizer used for shard selection: cheap,
/// stateless, full-avalanche, so `hash(op, format, shard_key)` spreads
/// evenly over any shard count.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Client-side handle: cheap to clone, safe across threads. Every
/// submission returns a [`Ticket`] / [`BatchTicket`] backed by a shared
/// completion slot — no per-request channel — and every failure is a
/// typed [`ServiceError`].
///
/// Each handle carries a shard-hash key: its submissions for a given
/// (op, format) always land on the same shard (preserving the handle's
/// submission order end to end), while distinct clones spread across
/// shards. Clone one handle per client thread or connection.
pub struct ServiceHandle {
    shards: Arc<Vec<Arc<ShardShared>>>,
    next_id: Arc<AtomicU64>,
    /// Allocator for clones' shard keys (see [`Clone`] below).
    next_key: Arc<AtomicU64>,
    /// This handle's shard-hash key (see [`Self::shard_for`]).
    shard_key: u64,
    caps: Arc<BackendCaps>,
    fault: Option<Arc<FaultPlan>>,
    closed: Arc<AtomicBool>,
    trace: Option<Arc<TracePlane>>,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        Self {
            shards: self.shards.clone(),
            next_id: self.next_id.clone(),
            next_key: self.next_key.clone(),
            // every clone draws a fresh key so independent handles
            // spread their traffic across the shards
            shard_key: mix64(self.next_key.fetch_add(1, Ordering::Relaxed)),
            caps: self.caps.clone(),
            fault: self.fault.clone(),
            closed: self.closed.clone(),
            trace: self.trace.clone(),
        }
    }
}

impl ServiceHandle {
    /// Stamp the whole-lifecycle sampling decision (1-in-N by request
    /// id) and emit the Submit instant for sampled requests. Called
    /// once per constructed item, right after id assignment — every
    /// later stage keys off `item.sampled`, so a request is traced in
    /// full or not at all.
    fn mark_submit(&self, item: &mut WorkItem) {
        if let Some(t) = &self.trace {
            if t.sampled(item.id) {
                item.sampled = true;
                t.emit(
                    TraceEvent::new(TraceKind::Submit, t.now_ns())
                        .req(item.id, item.op, item.format())
                        .with_lanes(item.lanes())
                        .on_shard(self.shard_for(item.op, item.format())),
                );
            }
        }
    }

    /// Error-class Reject event (always captured; submit-time failures
    /// have no request id yet, so `id` stays 0).
    fn note_reject(&self, op: OpKind, format: FormatKind, lanes: usize) {
        if let Some(t) = &self.trace {
            t.emit(
                TraceEvent::new(TraceKind::Reject, t.now_ns())
                    .req(0, op, format)
                    .with_lanes(lanes),
            );
        }
    }
    /// The backend's negotiated capability table (what this service can
    /// serve, per (op, format), and at which batch sizes).
    pub fn capabilities(&self) -> &BackendCaps {
        &self.caps
    }

    /// Which shard serves (`op`, `format`) submissions from **this**
    /// handle: `hash(op, format, shard_key)`, stable for the handle's
    /// lifetime (one handle's stream for a given (op, format) always
    /// lands on one shard, preserving its submission order and batch
    /// locality), while distinct handles spread across shards. Public
    /// so tests and shard-affine front ends can pin work.
    pub fn shard_for(&self, op: OpKind, format: FormatKind) -> usize {
        let slot = op_format_slot(op, format) as u64;
        let h = mix64(self.shard_key ^ slot.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        (h % self.shards.len() as u64) as usize
    }

    fn shard(&self, op: OpKind, format: FormatKind) -> &ShardShared {
        &self.shards[self.shard_for(op, format)]
    }

    /// The `ring-full` chaos site: with a plan armed and matched on the
    /// target shard's name, the submit path treats the ring as full and
    /// sheds typed (forced backpressure).
    fn ring_full_injected(&self, shard: &ShardShared) -> bool {
        match &self.fault {
            Some(plan) => plan.check(FaultSite::RingFull, &shard.name).is_some(),
            None => false,
        }
    }

    /// Deadline admission control: a deadline-carrying submission whose
    /// budget is already smaller than the queue-delay estimate for its
    /// (op, format) slot is rejected **at submit time** with
    /// [`ServiceError::Deadline`] — the work never enters the queue
    /// only to be shed at batch formation. The estimate is a
    /// queue-depth × service-rate model (lanes queued ahead times the
    /// slot's windowed executor cost per lane, divided by the serving
    /// pool's worker parallelism, see
    /// [`Metrics::queue_delay_estimate_ns`]): a burst moves it the
    /// moment the burst is queued, and a drained queue clears it
    /// instantly — no latency window to age out. Every N-th
    /// would-reject is still admitted anyway as a probe
    /// ([`Metrics::admission_probe`]), so a slot whose rate window went
    /// stale keeps resampling the service. With no rate signal yet (a
    /// cold service) everything is admitted and deadline enforcement
    /// falls to the batcher's shed path as before.
    fn admit_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        lanes: usize,
        deadline: Duration,
    ) -> Result<(), ServiceError> {
        // admission runs against the shard the submission would land
        // on: its gauge and rate window describe exactly the queue this
        // request would wait in
        let m = &self.shard(op, format).metrics;
        if let Some(est_ns) = m.queue_delay_estimate_ns(op, format) {
            if Duration::from_nanos(est_ns) > deadline && !m.admission_probe(op, format) {
                m.record_admission_reject(op, format, lanes as u64);
                self.note_reject(op, format, lanes);
                return Err(ServiceError::Deadline);
            }
        }
        Ok(())
    }

    fn check_supported(&self, op: OpKind, format: FormatKind) -> Result<(), ServiceError> {
        if self.caps.supports(op, format) {
            Ok(())
        } else {
            self.note_reject(op, format, 0);
            Err(ServiceError::Rejected {
                reason: format!(
                    "backend {} does not serve ({}, {format})",
                    self.caps.backend(),
                    op.label()
                ),
            })
        }
    }

    fn send(&self, item: WorkItem) -> Result<(), ServiceError> {
        // a dropped item fails its ticket — but the caller gets the
        // error directly and never sees that ticket
        let (op, format, lanes) = (item.op, item.format(), item.lanes() as u64);
        let shard = self.shard(op, format);
        if self.closed.load(Ordering::Acquire) {
            return Err(ServiceError::Shutdown);
        }
        if self.ring_full_injected(shard) {
            shard.ring_full_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Overloaded);
        }
        // feed the admission model's queue-depth gauge BEFORE the
        // publish: the dispatcher may dequeue (and discount) the item
        // the moment it lands, and the gauge must never dip below zero
        shard.metrics.record_enqueued(op, format, lanes);
        let mut msg = DispatchMsg::Req(item);
        let mut spins = 0u32;
        loop {
            match shard.ring.try_push(msg) {
                Ok(()) => break,
                Err(back) => {
                    // full ring: backpressure. The dispatcher normally
                    // drains in microseconds, so yield first; fall back
                    // to a short sleep so a stalled consumer does not
                    // burn a core under us
                    if self.closed.load(Ordering::Acquire) {
                        // undo is safe: our own +lanes was never consumed
                        shard.metrics.record_dequeued(op, format, lanes);
                        return Err(ServiceError::Shutdown);
                    }
                    msg = back;
                    spins += 1;
                    if spins < 64 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
            }
        }
        shard.events.notify();
        Ok(())
    }

    /// Validation shared by the single-request submit family (cheap:
    /// two compares, no allocation — the admission reject path relies
    /// on that).
    fn check_single(&self, op: OpKind, a: Value, b: Value) -> Result<(), ServiceError> {
        if a.format() != b.format() {
            return Err(ServiceError::Rejected {
                reason: format!("operand format mismatch: {} vs {}", a.format(), b.format()),
            });
        }
        self.check_supported(op, a.format())
    }

    fn make_single(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Option<Duration>,
    ) -> Result<(WorkItem, Ticket), ServiceError> {
        self.check_single(op, a, b)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (mut item, ticket) =
            WorkItem::single(id, op, a, b, deadline.map(|d| Instant::now() + d));
        self.mark_submit(&mut item);
        Ok((item, ticket))
    }

    /// Submit one op on format-tagged operands; returns the [`Ticket`]
    /// resolving it. Blocks while the submit queue is full
    /// (backpressure). Both operands must share a format (pass
    /// `Value::one(format)` as `b` for unary ops).
    pub fn submit_value(&self, op: OpKind, a: Value, b: Value) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        self.send(item)?;
        Ok(ticket)
    }

    /// [`Self::submit_value`] with a completion deadline. Admission
    /// control runs first: when the queue-delay estimate already
    /// exceeds `deadline`, the submission fails immediately with
    /// [`ServiceError::Deadline`]. Once admitted, a request still
    /// queued when the deadline arrives is shed by the dispatcher
    /// (counted in metrics) and the ticket resolves to
    /// [`ServiceError::Deadline`] instead of executing stale work.
    pub fn submit_value_deadline(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Duration,
    ) -> Result<Ticket, ServiceError> {
        // validate first (a malformed submission is Rejected with its
        // reason, never misreported as a Deadline admission miss), and
        // only construct once admitted — the overload reject path
        // allocates nothing
        self.check_single(op, a, b)?;
        self.admit_deadline(op, a.format(), 1, deadline)?;
        let (item, ticket) = self.make_single(op, a, b, Some(deadline))?;
        self.send(item)?;
        Ok(ticket)
    }

    /// Submit one f32 op (the single-precision convenience path).
    pub fn submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.submit_value(op, Value::F32(a), Value::F32(b))
    }

    /// Non-blocking submit of format-tagged operands:
    /// [`ServiceError::Overloaded`] when the queue is full.
    pub fn try_submit_value(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
    ) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        let format = item.format();
        let shard = self.shard(op, format);
        if self.closed.load(Ordering::Acquire) {
            return Err(ServiceError::Shutdown);
        }
        if self.ring_full_injected(shard) {
            shard.ring_full_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Overloaded);
        }
        // gauge before publish, as in `send` (the undo on failure is
        // safe for the same reason); a full ring hands the message back
        // and dropping it here is fine — the caller never sees a ticket
        shard.metrics.record_enqueued(op, format, 1);
        match shard.ring.try_push(DispatchMsg::Req(item)) {
            Ok(()) => {
                shard.events.notify();
                Ok(ticket)
            }
            Err(_) => {
                shard.metrics.record_dequeued(op, format, 1);
                shard.ring_full_rejects.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::Overloaded)
            }
        }
    }

    /// Non-blocking f32 submit: [`ServiceError::Overloaded`] when full.
    pub fn try_submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.try_submit_value(op, Value::F32(a), Value::F32(b))
    }

    fn check_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<(), ServiceError> {
        if a.is_empty() {
            return Err(ServiceError::Rejected { reason: "empty batch".into() });
        }
        match op {
            OpKind::Divide if b.len() != a.len() => {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "divide needs matching operand planes ({} vs {})",
                        a.len(),
                        b.len()
                    ),
                });
            }
            OpKind::Sqrt | OpKind::Rsqrt if !b.is_empty() => {
                return Err(ServiceError::Rejected {
                    reason: format!("{} takes one operand plane", op.label()),
                });
            }
            _ => {}
        }
        // raw words must fit the format's container: the queue stores
        // planes width-true, so an oversized word would otherwise be a
        // debug panic / silent release truncation instead of a typed
        // rejection of bad client input
        if format.total_bits() < 64 {
            let mask = !((1u64 << format.total_bits()) - 1);
            if let Some(bad) = a.iter().chain(b.iter()).find(|&&w| w & mask != 0) {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "operand word {bad:#x} does not fit a {}-bit {format} container",
                        format.total_bits()
                    ),
                });
            }
        }
        self.check_supported(op, format)
    }

    /// Callers have already run [`Self::check_batch`]. `tag` overrides
    /// the service-allocated request id with a caller-assigned one (the
    /// wire front end passes the client's request id through so a wire
    /// request's trace spans join under the id the client knows); `None`
    /// draws from the service allocator as before.
    fn submit_batch_inner(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Option<Duration>,
        tag: Option<u64>,
    ) -> Result<BatchTicket, ServiceError> {
        let id = tag.unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let (mut item, ticket) =
            WorkItem::group(id, op, format, a, b, deadline.map(|d| Instant::now() + d));
        self.mark_submit(&mut item);
        self.send(item)?;
        Ok(ticket)
    }

    /// Vectored submission: a whole operand plane (raw `format` words)
    /// as **one** queue entry with **one** completion slot. The group
    /// enters the router pre-formed — batch locality is preserved, not
    /// re-discovered — and is split only at executable-ladder
    /// boundaries. `b` is the divisor plane for divide (same length as
    /// `a`) and must be empty for unary ops.
    pub fn submit_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<BatchTicket, ServiceError> {
        self.check_batch(op, format, a, b)?;
        self.submit_batch_inner(op, format, a, b, None, None)
    }

    /// [`Self::submit_batch`] under a **caller-assigned** request id
    /// (with an optional deadline): the wire front end's submit path.
    /// The tag becomes the item's id for the whole lifecycle, so a
    /// sampled wire request's trace spans join under the id the client
    /// chose (and the Chrome export groups them accordingly). Tags share
    /// the id space with service-allocated ids; collisions only blur
    /// trace grouping, never correctness (tickets resolve by completion
    /// slot, not by id).
    pub fn submit_batch_tagged(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Option<Duration>,
        tag: u64,
    ) -> Result<BatchTicket, ServiceError> {
        self.check_batch(op, format, a, b)?;
        if let Some(d) = deadline {
            self.admit_deadline(op, format, a.len(), d)?;
        }
        self.submit_batch_inner(op, format, a, b, deadline, Some(tag))
    }

    /// [`Self::submit_batch`] with a completion deadline covering the
    /// whole group. Admission control applies as in
    /// [`Self::submit_value_deadline`]: a budget the queue-delay
    /// estimate already exceeds is rejected here, before any queueing.
    pub fn submit_batch_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Duration,
    ) -> Result<BatchTicket, ServiceError> {
        // validation precedes admission (see submit_value_deadline)
        self.check_batch(op, format, a, b)?;
        self.admit_deadline(op, format, a.len(), deadline)?;
        self.submit_batch_inner(op, format, a, b, Some(deadline), None)
    }

    /// Convenience: blocking round-trip divide (f32).
    pub fn divide(&self, n: f32, d: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Divide, n, d)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip sqrt (f32).
    pub fn sqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Sqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip rsqrt (f32).
    pub fn rsqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Rsqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip divide in any format (operands
    /// encoded from f64 with round-to-nearest-even, result decoded
    /// exactly).
    pub fn divide_in(&self, format: FormatKind, n: f64, d: f64) -> Result<f64, ServiceError> {
        let t = self.submit_value(
            OpKind::Divide,
            Value::from_f64(format, n),
            Value::from_f64(format, d),
        )?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip sqrt in any format.
    pub fn sqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Sqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip rsqrt in any format.
    pub fn rsqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Rsqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }
}

/// A durable job's current outcome, as [`FpuService::poll_job`] reports
/// it.
#[derive(Clone, Debug, PartialEq)]
pub enum JobPoll {
    /// Journalled and queued (or replaying); not yet resolved.
    Pending,
    /// Completed: the result plane's raw words, lane order preserved.
    Done(Vec<u64>),
    /// Failed with a typed error (also journalled).
    Failed(ServiceError),
}

/// Shared state of the durable plane: the journal writer, the in-memory
/// job table the poll API reads, and the id allocator (seeded past the
/// highest replayed id).
struct DurableState {
    journal: Mutex<Journal>,
    jobs: Mutex<HashMap<u64, JobPoll>>,
    /// Notified whenever a job's table entry *resolves* (Done/Failed
    /// insert), so [`FpuService::wait_for_id`] blocks instead of
    /// polling. One condvar for the whole table: resolutions are rare
    /// relative to waits, and waiters re-check their own id.
    jobs_cv: Condvar,
    next_job: AtomicU64,
}

impl DurableState {
    /// Insert a **terminal** outcome and wake every `wait_for_id`
    /// waiter. All Done/Failed inserts go through here; `Pending`
    /// inserts don't notify (nothing resolved).
    fn resolve(&self, id: u64, outcome: JobPoll) {
        self.jobs.lock().unwrap().insert(id, outcome);
        self.jobs_cv.notify_all();
    }
}

/// What the journal retirer waits on: the job id, the routing key (a
/// `BatchResponse` does not carry its op), and the ticket.
type RetireMsg = (u64, OpKind, FormatKind, BatchTicket);

/// The journal retirer: waits each durable ticket to resolution and
/// appends the terminal `Done`/`Failed` record (operand planes are not
/// repeated — `coalesce` keeps the last record per id, and a terminal
/// record needs no replay data).
fn retirer_loop(rx: Receiver<RetireMsg>, state: Arc<DurableState>, trace: Option<Arc<TracePlane>>) {
    // journal-append instants are an id-less sampling site (the durable
    // job id is not the request id the submit sample keyed on), so they
    // are gated by the plane's occurrence counter instead
    let note_append = |id: u64, op: OpKind, format: FormatKind, arg: u64| {
        if let Some(t) = &trace {
            if t.tick_sampled() {
                t.emit(
                    TraceEvent::new(TraceKind::JournalAppend, t.now_ns())
                        .req(id, op, format)
                        .with_arg(arg),
                );
            }
        }
    };
    while let Ok((id, op, format, ticket)) = rx.recv() {
        let outcome = ticket.wait();
        let mut rec = JournalRecord::pending(id, op, format, Vec::new(), Vec::new());
        match outcome {
            Ok(resp) => {
                rec.status = JobStatus::Done;
                rec.result = resp.bits;
                // journal before the poll table: a job never reads Done
                // unless its record is on disk
                let _ = state.journal.lock().unwrap().append(&rec);
                note_append(id, op, format, 1);
                state.resolve(id, JobPoll::Done(rec.result));
            }
            Err(err) => {
                rec.status = JobStatus::Failed;
                rec.error = format!("{err}");
                let _ = state.journal.lock().unwrap().append(&rec);
                note_append(id, op, format, 2);
                state.resolve(id, JobPoll::Failed(err));
            }
        }
    }
}

/// Aggregated, clonable view over every shard's [`Metrics`] slice.
///
/// [`snapshot`](Self::snapshot) merges at read time — counters sum,
/// log-bucket latency histograms merge exactly — so reports always
/// cover all shards rather than silently showing one slice. The
/// per-shard gauges and rate windows stay separate on purpose:
/// admission control runs on the shard a submission would land on.
#[derive(Clone)]
pub struct ServiceMetrics {
    shards: Arc<Vec<Arc<Metrics>>>,
}

impl ServiceMetrics {
    /// Merged-across-shards snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        Metrics::merged_snapshot(self.shards.iter().map(Arc::as_ref))
    }

    /// Queued lanes for one (op, format), summed over shards.
    pub fn queued_lanes(&self, op: OpKind, format: FormatKind) -> u64 {
        self.shards.iter().map(|m| m.queued_lanes(op, format)).sum()
    }

    /// Number of shard slices (= the service's shard count).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// One shard's raw metrics slice — for targeted feeds in tests;
    /// with `shards = 1` this is the whole story.
    pub fn shard(&self, i: usize) -> &Metrics {
        &self.shards[i]
    }
}

/// One shard's live introspection row ([`FpuService::shard_stats`]):
/// the submit-ring occupancy, the ready-queue backlog and its age, the
/// work-stealing traffic in both directions, and typed ring-full
/// rejections. Gauges (`ring_depth`, `ready_batches`,
/// `oldest_ready_us`, `queued_lanes`) are racy point-in-time reads;
/// the counters are monotonic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStat {
    /// Messages sitting in the shard's submit ring right now.
    pub ring_depth: usize,
    /// The ring's slot count (the backpressure bound).
    pub ring_capacity: usize,
    /// Lanes queued on this shard across every (op, format) slot.
    pub queued_lanes: u64,
    /// Formed, backend-selected batches awaiting dispatch.
    pub ready_batches: usize,
    /// Age of the oldest ready batch, microseconds (0 when none) — the
    /// signal peer dispatchers steal by.
    pub oldest_ready_us: u64,
    /// Batches this shard's dispatcher stole from peers.
    pub steals_in: u64,
    /// Batches peers stole from this shard's ready queue.
    pub steals_out: u64,
    /// Submissions bounced typed because this shard's ring was full.
    pub ring_full_rejects: u64,
}

/// Read one shard's introspection row (shared by
/// [`FpuService::shard_stats`] and the stats emitter).
fn shard_stat_of(shard: &ShardShared) -> ShardStat {
    let queued_lanes = OpKind::ALL
        .iter()
        .flat_map(|&op| FormatKind::ALL.iter().map(move |&format| (op, format)))
        .map(|(op, format)| shard.metrics.queued_lanes(op, format))
        .sum();
    let (ready_batches, oldest_ready_us) = {
        let q = shard.ready.lock().unwrap();
        let age = q
            .front()
            .map(|b| b.formed_at.elapsed().as_micros().min(u64::MAX as u128) as u64)
            .unwrap_or(0);
        (q.len(), age)
    };
    ShardStat {
        ring_depth: shard.ring.len(),
        ring_capacity: shard.ring.capacity(),
        queued_lanes,
        ready_batches,
        oldest_ready_us,
        steals_in: shard.steals_in.load(Ordering::Relaxed),
        steals_out: shard.steals.load(Ordering::Relaxed),
        ring_full_rejects: shard.ring_full_rejects.load(Ordering::Relaxed),
    }
}

/// Net-plane figures a front end feeds the stats emitter (the
/// coordinator cannot depend on the net module, so the wire server
/// attaches a closure producing these; see
/// [`FpuService::attach_net_stats_source`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetPlaneStats {
    /// Connections currently open.
    pub active_connections: u64,
    /// Cumulative slow-client disconnects (bounded writer queue full).
    pub slow_client_drops: u64,
}

/// Pluggable producer of [`NetPlaneStats`] — attached after start
/// because the front end is built *around* a running service.
type NetStatsSource = Arc<dyn Fn() -> NetPlaneStats + Send + Sync>;

/// The running service.
pub struct FpuService {
    handle: ServiceHandle,
    shards: Arc<Vec<Arc<ShardShared>>>,
    metrics: ServiceMetrics,
    health: Arc<HealthBoard>,
    backend_names: Vec<&'static str>,
    dispatchers: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    closed: Arc<AtomicBool>,
    supervisor: Option<JoinHandle<()>>,
    supervisor_stop: Arc<AtomicBool>,
    durable: Option<Arc<DurableState>>,
    retirer: Option<JoinHandle<()>>,
    retirer_tx: Option<mpsc::Sender<RetireMsg>>,
    replayed: usize,
    trace: Option<Arc<TracePlane>>,
    stats_stop: Arc<AtomicBool>,
    stats_emitter: Option<JoinHandle<()>>,
    /// When [`Self::start_routed`] returned — the uptime epoch the
    /// STATS wire frame timestamps rates against.
    started: Instant,
    /// Net-plane stats producer, attached by the wire front end after
    /// start (shared with the stats emitter).
    net_source: Arc<Mutex<Option<NetStatsSource>>>,
}

/// A batch a worker could not execute, handed back to the dispatcher
/// for re-routing. `error: Some` blames the backend (the failure is
/// already on its breaker, and the message reaches the riders if every
/// candidate fails); `None` means the worker died *without* executing
/// (injected death, or drained from a dead worker's queue) — the
/// backend is not at fault and may serve the batch again once its pool
/// respawns.
struct FailedBatch {
    batch: Batch,
    error: Option<String>,
}

/// One live worker's batch channel, identified so the supervisor can
/// remove exactly the dead worker's slot.
struct WorkerSlot {
    id: u64,
    tx: SyncSender<Batch>,
}

/// A pool's slot list, shared between the dispatcher (sender side) and
/// the supervisor (respawn side).
struct PoolShared {
    slots: Mutex<Vec<WorkerSlot>>,
}

/// One backend's worker pool, as the dispatcher sees it: round-robin
/// over the live slots.
struct PoolSender {
    shared: Arc<PoolShared>,
    next: usize,
}

impl PoolSender {
    /// Round-robin one batch into the pool, dropping dead workers'
    /// slots. `Err` returns the batch when the whole pool is gone.
    fn send(&mut self, mut batch: Batch) -> std::result::Result<(), Batch> {
        loop {
            let (slot_id, tx) = {
                let slots = self.shared.slots.lock().unwrap();
                if slots.is_empty() {
                    return Err(batch);
                }
                let i = self.next % slots.len();
                self.next += 1;
                (slots[i].id, slots[i].tx.clone())
            };
            // send outside the lock: a full worker queue applies
            // backpressure here, and blocking must not hold up the
            // supervisor's slot maintenance
            match tx.send(batch) {
                Ok(()) => return Ok(()),
                Err(mpsc::SendError(returned)) => {
                    batch = returned;
                    // dead worker: never pick it again
                    self.shared.slots.lock().unwrap().retain(|s| s.id != slot_id);
                }
            }
        }
    }
}

/// Everything a worker thread needs, bundled so the supervisor can
/// clone it to build replacements. Deliberately does NOT hold the
/// pool's [`PoolShared`]: a worker holding its own pool's batch senders
/// would keep its own receiver alive and deadlock shutdown.
#[derive(Clone)]
struct WorkerCtx {
    shard: usize,
    backend: usize,
    name: &'static str,
    factory: ExecutorFactory,
    metrics: Arc<Metrics>,
    health: Arc<HealthBoard>,
    pool: PlanePool,
    retry_tx: mpsc::Sender<FailedBatch>,
    outstanding: Arc<AtomicI64>,
    fault: Option<Arc<FaultPlan>>,
    exit_tx: mpsc::Sender<ExitNotice>,
    next_slot_id: Arc<AtomicU64>,
    trace: Option<Arc<TracePlane>>,
}

/// An abnormal worker exit (panic or injected death), reported to the
/// supervisor so it can respawn a replacement in the right shard's
/// pool.
struct ExitNotice {
    shard: usize,
    backend: usize,
    slot_id: u64,
}

/// Worker batch-queue depth (per worker; backpressure onto the
/// dispatcher beyond it).
const WORKER_QUEUE: usize = 4;

/// How old the front batch of a shard's ready queue must be before a
/// peer may steal it. A healthy owner drains its own ready queue within
/// microseconds of forming it, so age is the imbalance signal: only a
/// stalled (or wedged) shard's batches ever cross this threshold, and
/// the steady state pays no cross-shard traffic at all.
const STEAL_AGE: Duration = Duration::from_millis(1);

/// How long the dispatcher keeps servicing the retry channel at
/// shutdown while batches are still in flight without making progress
/// (a failsafe bound — the normal case drains in microseconds, and
/// every serviced retry resets the clock).
const SHUTDOWN_RETIRE_BUDGET: Duration = Duration::from_secs(5);

/// How long a batch send waits for a dead pool to respawn before
/// walking the retry chain (covers the window where every worker of a
/// pool died at once but the supervisor is about to replace them).
const POOL_RESPAWN_WAIT: Duration = Duration::from_millis(100);

/// Consecutive respawn failures before a pool is marked degraded (and
/// routed around) instead of retried forever.
const DEGRADE_AFTER_RESPAWN_FAILURES: u32 = 5;

/// Capped exponential respawn backoff: 10ms doubling to a 500ms cap.
fn backoff_for(streak: u32) -> Duration {
    Duration::from_millis((10u64 << streak.min(6)).min(500))
}

/// Build one replacement worker for `ctx`'s backend: spawn the thread,
/// wait for its factory result, and only publish the slot once the
/// executor exists — a replacement that cannot build its executor is a
/// respawn *failure* (fed to the supervisor's backoff), never a live
/// slot that eats traffic.
fn respawn_worker(
    ctx: &WorkerCtx,
    shared: &Arc<PoolShared>,
) -> std::result::Result<JoinHandle<()>, String> {
    let slot_id = ctx.next_slot_id.fetch_add(1, Ordering::Relaxed);
    let (btx, brx) = mpsc::sync_channel::<Batch>(WORKER_QUEUE);
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let ctx2 = ctx.clone();
    let handle = std::thread::Builder::new()
        .name(format!("fpu-{}-r{slot_id}", ctx.name))
        .spawn(move || match (ctx2.factory)() {
            Ok(executor) => {
                let _ = ready_tx.send(Ok(()));
                drop(ready_tx);
                worker_loop(brx, executor, ctx2, slot_id);
            }
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
            }
        })
        .map_err(|e| format!("spawn failed: {e}"))?;
    match ready_rx.recv() {
        Ok(Ok(())) => {
            shared.slots.lock().unwrap().push(WorkerSlot { id: slot_id, tx: btx });
            Ok(handle)
        }
        Ok(Err(msg)) => {
            let _ = handle.join();
            Err(msg)
        }
        Err(_) => {
            let _ = handle.join();
            Err("worker exited before reporting executor init".into())
        }
    }
}

/// The pool supervisor: waits for [`ExitNotice`]s, removes the dead
/// worker's slot, and respawns a replacement with capped exponential
/// backoff. One supervisor serves every shard — `ctxs` / `shareds` are
/// shard-major (`[shard][backend]`). Respawns that keep failing mark
/// the pool's backend degraded on the (shared) health board — the
/// dispatchers route around it; a later successful respawn clears the
/// mark.
fn supervisor_loop(
    exit_rx: Receiver<ExitNotice>,
    ctxs: Vec<Vec<WorkerCtx>>,
    shareds: Vec<Vec<Arc<PoolShared>>>,
    stop: Arc<AtomicBool>,
) {
    let mut respawned: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        let notice = match exit_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(n) => n,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let (s, b) = (notice.shard, notice.backend);
        shareds[s][b].slots.lock().unwrap().retain(|sl| sl.id != notice.slot_id);
        let ctx = &ctxs[s][b];
        let mut streak = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                break;
            }
            std::thread::sleep(backoff_for(streak));
            match respawn_worker(ctx, &shareds[s][b]) {
                Ok(handle) => {
                    ctx.health.record_respawn(b);
                    ctx.health.set_degraded(b, false);
                    if let Some(t) = &ctx.trace {
                        t.emit(TraceEvent::new(TraceKind::Respawn, t.now_ns()).on_backend(b));
                    }
                    respawned.push(handle);
                    break;
                }
                Err(_) => {
                    streak += 1;
                    if streak >= DEGRADE_AFTER_RESPAWN_FAILURES {
                        ctx.health.set_degraded(b, true);
                        break;
                    }
                }
            }
        }
    }
    // teardown: unplug every slot (disconnects any respawned workers'
    // receivers too — a dispatcher's own clear cannot see slots
    // published after it exited), drop the ctxs' senders, then join
    for shard in &shareds {
        for shared in shard {
            shared.slots.lock().unwrap().clear();
        }
    }
    drop(ctxs);
    for h in respawned {
        let _ = h.join();
    }
}

/// The `fpu-stats-emitter` thread: one `stats:` line per interval,
/// reporting **deltas** where counters are cumulative (qps, respawns,
/// trace drops, net slow-client drops — the `+N` fields) and **levels**
/// elsewhere (queued lanes, per-slot latency percentiles,
/// breaker/degraded states, per-shard ring depth and steal counts, net
/// active connections). Reads through [`ServiceMetrics`], so every
/// line aggregates all shards' slices (counters summed, histograms
/// merged exactly); the per-shard `sN=` fields then break the same
/// tick down by shard. Sleeps in short slices so shutdown never waits
/// out a full interval.
#[allow(clippy::too_many_arguments)]
fn stats_emitter_loop(
    interval: Duration,
    stop: Arc<AtomicBool>,
    metrics: ServiceMetrics,
    health: Arc<HealthBoard>,
    names: Vec<&'static str>,
    trace: Option<Arc<TracePlane>>,
    shards: Arc<Vec<Arc<ShardShared>>>,
    net_source: Arc<Mutex<Option<NetStatsSource>>>,
) {
    let mut last_requests = 0u64;
    let mut last_respawns = 0u64;
    let mut last_drops = 0u64;
    let mut last_net_drops = 0u64;
    let mut last = Instant::now();
    loop {
        while last.elapsed() < interval {
            if stop.load(Ordering::Acquire) {
                return;
            }
            std::thread::sleep(interval.min(Duration::from_millis(20)));
        }
        let elapsed = last.elapsed().as_secs_f64();
        last = Instant::now();
        let snap = metrics.snapshot();
        let requests = snap.total_requests();
        let qps = (requests - last_requests) as f64 / elapsed.max(1e-9);
        last_requests = requests;
        let queued: u64 = OpKind::ALL
            .iter()
            .flat_map(|&op| FormatKind::ALL.iter().map(move |&format| (op, format)))
            .map(|(op, format)| metrics.queued_lanes(op, format))
            .sum();
        // only slots that served traffic carry a latency story
        let slots: Vec<String> = snap
            .op_formats
            .iter()
            .filter(|s| s.requests > 0)
            .map(|s| {
                format!(
                    "{}/{} p50={}ns p99={}ns",
                    s.op.label(),
                    s.format.label(),
                    s.p50_latency_ns,
                    s.p99_latency_ns
                )
            })
            .collect();
        let boards = health.snapshot();
        let respawns: u64 = boards.iter().map(|b| b.respawns).sum();
        let open: Vec<&str> = boards
            .iter()
            .zip(&names)
            .filter(|(b, _)| b.breaker_open || b.degraded)
            .map(|(_, n)| *n)
            .collect();
        let breakers = if open.is_empty() { "all-closed".to_string() } else { open.join(",") };
        let drops = trace.as_ref().map(|t| t.drops()).unwrap_or(0);
        // per-shard rows: ring depth / queued lanes / ready backlog /
        // steals in:out / ring-full rejects, one compact field per shard
        let shard_rows: Vec<String> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let st = shard_stat_of(s);
                format!(
                    "s{i}=d{}:q{}:r{}:st{}:{}:rf{}",
                    st.ring_depth,
                    st.queued_lanes,
                    st.ready_batches,
                    st.steals_in,
                    st.steals_out,
                    st.ring_full_rejects,
                )
            })
            .collect();
        // the net plane reports through its attached source; before a
        // front end attaches (or without one) the fields are absent
        let net_part = {
            let source = net_source.lock().unwrap().clone();
            match source {
                Some(f) => {
                    let n = f();
                    let part = format!(
                        " net-conns={} net-drops=+{}",
                        n.active_connections,
                        n.slow_client_drops - last_net_drops.min(n.slow_client_drops),
                    );
                    last_net_drops = n.slow_client_drops;
                    part
                }
                None => String::new(),
            }
        };
        println!(
            "stats: qps={qps:.0} queued={queued} breakers={breakers} respawns=+{} \
             trace-drops=+{}{net_part} {} {}",
            respawns - last_respawns,
            drops - last_drops,
            shard_rows.join(" "),
            slots.join(" "),
        );
        last_respawns = respawns;
        last_drops = drops;
    }
}

impl FpuService {
    /// Start a single-backend service. `make_executor` is called once
    /// on the caller thread (capability negotiation: the probe's
    /// [`BackendCaps`] are kept for the life of the service) and once
    /// *inside each worker thread* — executors are not `Send` (the PJRT
    /// client wraps thread-local FFI state), so each worker owns an
    /// executor it built itself: one "divider unit" per worker. Any
    /// worker whose factory fails makes `start` return that error — no
    /// silently dead workers.
    ///
    /// This is sugar for [`Self::start_routed`] with a one-entry
    /// registry: a single backend routes trivially.
    pub fn start<F>(config: ServiceConfig, make_executor: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        Self::start_routed(config, ExecutorRegistry::new().register(make_executor))
    }

    /// Start a routed service over every backend in the registry.
    ///
    /// Each registered factory is probed once on the caller thread; the
    /// probed capability tables are merged into a [`RoutingTable`]
    /// (candidate lists per (op, format) + the union table the client
    /// handle admits against), and each backend gets its **own worker
    /// pool** (`config.workers` threads, or the registry entry's
    /// override), its own batch shapes (ladders + plane widths) and its
    /// own health tracking. The dispatcher selects a backend per formed
    /// batch (registry policy: static preference or measured latency),
    /// routes around open circuit breakers and degraded pools, probes
    /// broken backends back to life, and re-routes failed batches down
    /// the candidate chain so riders only ever see an error when every
    /// candidate failed. A supervisor thread respawns workers that die
    /// abnormally (panic / injected death).
    ///
    /// With [`ServiceConfig::fault`] armed, every executor is wrapped
    /// in the plan's injector ([`crate::fault::wrap_registry`]) and the
    /// worker threads consult the worker-level sites. With
    /// [`ServiceConfig::journal`] set, the journal is opened (torn tail
    /// truncated), still-`Pending` records are replayed through the
    /// normal submit path exactly once, and the durable API goes live.
    pub fn start_routed(config: ServiceConfig, registry: ExecutorRegistry) -> Result<Self> {
        assert!(config.workers >= 1, "need at least one worker");
        let nshards = match config.shards {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        let trace = config.trace.clone().map(|c| Arc::new(TracePlane::new(c)));
        let registry = match &config.fault {
            Some(plan) => {
                crate::fault::wrap_registry_traced(registry, plan.clone(), trace.clone())
            }
            None => registry,
        };
        let (entries, policy) = registry.into_parts();
        if entries.is_empty() {
            bail!("dispatch registry has no backends");
        }
        if entries.len() > 8 {
            bail!("at most 8 backends per service (the retry mask is a u8)");
        }

        // probe every backend once: validates each factory and
        // negotiates its capability table (support + ladders + widths).
        // Every shard's routing table is built over this same list in
        // the same order — backend indices are shard-invariant, which
        // is what lets a stolen batch dispatch on the stealer's pools.
        let mut caps_list = Vec::with_capacity(entries.len());
        for (i, entry) in entries.iter().enumerate() {
            let probe = entry
                .make()
                .with_context(|| format!("probing backend #{i} capabilities"))?;
            caps_list.push(probe.capabilities());
        }
        let table = RoutingTable::merge(caps_list.clone())?;
        let names = table.names();
        let union = Arc::new(table.union().clone());
        let health = Arc::new(HealthBoard::new(table.backend_count()));
        let (exit_tx, exit_rx) = mpsc::channel::<ExitNotice>();
        let next_slot_id = Arc::new(AtomicU64::new(0));

        // the admission model divides each slot's queue-delay estimate
        // by the serving pool's worker parallelism: tell each shard's
        // metrics slice how many workers the preferred backend of each
        // (op, format) runs
        let pool_sizes: Vec<usize> =
            entries.iter().map(|e| e.workers().unwrap_or(config.workers).max(1)).collect();

        // per-shard submit-side state: ring + event count + metrics
        // slice + ready queue. Every ring gets the full queue_depth —
        // the knob bounds each shard's backlog, as before.
        let mut shard_list = Vec::with_capacity(nshards);
        for s in 0..nshards {
            let metrics = Arc::new(Metrics::new());
            for &op in &OpKind::ALL {
                for &format in &FormatKind::ALL {
                    if let Some(&b) = table.candidates(op, format).first() {
                        metrics.set_slot_workers(op, format, pool_sizes[b]);
                    }
                }
            }
            shard_list.push(Arc::new(ShardShared {
                ring: SubmitRing::with_capacity(config.queue_depth),
                events: EventCount::new(),
                metrics,
                ready: Mutex::new(VecDeque::new()),
                steals: AtomicU64::new(0),
                steals_in: AtomicU64::new(0),
                ring_full_rejects: AtomicU64::new(0),
                name: format!("shard{s}"),
            }));
        }
        let shards = Arc::new(shard_list);
        let metrics = ServiceMetrics {
            shards: Arc::new(shards.iter().map(|s| s.metrics.clone()).collect()),
        };

        // per-shard × per-backend worker pools: shard s's dispatcher
        // round-robins a backend's batches across shard s's live slots
        let (init_tx, init_rx) = mpsc::channel::<(String, std::result::Result<(), String>)>();
        let mut all_shareds: Vec<Vec<Arc<PoolShared>>> = Vec::with_capacity(nshards);
        let mut all_ctxs: Vec<Vec<WorkerCtx>> = Vec::with_capacity(nshards);
        let mut shard_pools: Vec<Vec<PoolSender>> = Vec::with_capacity(nshards);
        let mut shard_retry_rx: Vec<Receiver<FailedBatch>> = Vec::with_capacity(nshards);
        let mut shard_plane_pools: Vec<PlanePool> = Vec::with_capacity(nshards);
        let mut shard_outstanding: Vec<Arc<AtomicI64>> = Vec::with_capacity(nshards);
        let mut workers = Vec::new();
        let mut total_workers = 0usize;
        for s in 0..nshards {
            let plane_pool = PlanePool::new();
            let outstanding = Arc::new(AtomicI64::new(0));
            let (retry_tx, retry_rx) = mpsc::channel::<FailedBatch>();
            let mut shareds: Vec<Arc<PoolShared>> = Vec::with_capacity(entries.len());
            let mut ctxs: Vec<WorkerCtx> = Vec::with_capacity(entries.len());
            let mut pools = Vec::with_capacity(entries.len());
            for (b, entry) in entries.iter().enumerate() {
                let shared = Arc::new(PoolShared { slots: Mutex::new(Vec::new()) });
                let ctx = WorkerCtx {
                    shard: s,
                    backend: b,
                    name: names[b],
                    factory: entry.factory(),
                    metrics: shards[s].metrics.clone(),
                    health: health.clone(),
                    pool: plane_pool.clone(),
                    retry_tx: retry_tx.clone(),
                    outstanding: outstanding.clone(),
                    fault: config.fault.clone(),
                    exit_tx: exit_tx.clone(),
                    next_slot_id: next_slot_id.clone(),
                    trace: trace.clone(),
                };
                for w in 0..pool_sizes[b] {
                    total_workers += 1;
                    let slot_id = next_slot_id.fetch_add(1, Ordering::Relaxed);
                    let (btx, brx) = mpsc::sync_channel::<Batch>(WORKER_QUEUE);
                    shared.slots.lock().unwrap().push(WorkerSlot { id: slot_id, tx: btx });
                    let ctx2 = ctx.clone();
                    let init_tx = init_tx.clone();
                    let wname = format!("fpu-{}-s{s}w{w}", names[b]);
                    workers.push(
                        std::thread::Builder::new()
                            .name(wname.clone())
                            .spawn(move || match (ctx2.factory)() {
                                Ok(executor) => {
                                    let _ = init_tx.send((wname, Ok(())));
                                    drop(init_tx);
                                    worker_loop(brx, executor, ctx2, slot_id);
                                }
                                Err(e) => {
                                    let _ = init_tx.send((wname, Err(format!("{e:#}"))));
                                }
                            })
                            .expect("spawn worker"),
                    );
                }
                pools.push(PoolSender { shared: shared.clone(), next: 0 });
                shareds.push(shared);
                ctxs.push(ctx);
            }
            all_shareds.push(shareds);
            all_ctxs.push(ctxs);
            shard_pools.push(pools);
            shard_retry_rx.push(retry_rx);
            shard_plane_pools.push(plane_pool);
            shard_outstanding.push(outstanding);
        }
        drop(init_tx);
        drop(exit_tx); // workers + supervisor ctxs hold the exit senders

        // fail-fast: every worker of every shard reports its init
        // before we go live
        for _ in 0..total_workers {
            let failure = match init_rx.recv() {
                Ok((_, Ok(()))) => None,
                Ok((wname, Err(msg))) => Some(format!("{wname}: executor init failed: {msg}")),
                Err(_) => Some("a worker exited before reporting executor init".into()),
            };
            if let Some(msg) = failure {
                // unplug every slot -> live workers exit; then join
                for shareds in &all_shareds {
                    for shared in shareds {
                        shared.slots.lock().unwrap().clear();
                    }
                }
                drop(shard_pools);
                drop(all_ctxs);
                for h in workers {
                    let _ = h.join();
                }
                bail!(msg);
            }
        }

        let supervisor_stop = Arc::new(AtomicBool::new(false));
        let supervisor = {
            let stop = supervisor_stop.clone();
            std::thread::Builder::new()
                .name("fpu-supervisor".into())
                .spawn(move || supervisor_loop(exit_rx, all_ctxs, all_shareds, stop))
                .expect("spawn supervisor")
        };

        // one dispatcher thread per shard, each owning its own router,
        // batcher and dispatch plane (built over a clone of the shared
        // routing data, on the shared health board)
        let mut dispatchers = Vec::with_capacity(nshards);
        for s in (0..nshards).rev() {
            // reverse order so pop() hands each shard its own parts
            let table = RoutingTable::merge(caps_list.clone())?;
            let batcher = DynamicBatcher::routed(config.batcher.clone(), table.caps_list())
                .with_trace(trace.clone());
            let plane =
                DispatchPlane::new(table, policy, health.clone()).with_trace(trace.clone());
            let rt = ShardRuntime {
                index: s,
                shards: shards.clone(),
                retry_rx: shard_retry_rx.pop().expect("one retry channel per shard"),
                batcher,
                plane,
                pools: shard_pools.pop().expect("one pool set per shard"),
                poll: config.poll,
                retire_budget: config.retire_budget,
                plane_pool: shard_plane_pools.pop().expect("one plane pool per shard"),
                outstanding: shard_outstanding.pop().expect("one counter per shard"),
                metrics: shards[s].metrics.clone(),
                fault: config.fault.clone(),
            };
            dispatchers.push(
                std::thread::Builder::new()
                    .name(format!("fpu-dispatcher-{s}"))
                    .spawn(move || shard_dispatcher_loop(rt))
                    .expect("spawn dispatcher"),
            );
        }
        dispatchers.reverse();

        let closed = Arc::new(AtomicBool::new(false));
        let handle = ServiceHandle {
            shards: shards.clone(),
            next_id: Arc::new(AtomicU64::new(0)),
            next_key: Arc::new(AtomicU64::new(1)),
            shard_key: mix64(0),
            caps: union,
            fault: config.fault.clone(),
            closed: closed.clone(),
            trace: trace.clone(),
        };

        // the live stats emitter: one snapshot-delta line per interval
        let stats_stop = Arc::new(AtomicBool::new(false));
        let net_source: Arc<Mutex<Option<NetStatsSource>>> = Arc::new(Mutex::new(None));
        let stats_emitter = config.stats_interval.map(|interval| {
            let stop = stats_stop.clone();
            let metrics = metrics.clone();
            let health = health.clone();
            let names = names.clone();
            let trace = trace.clone();
            let shards = shards.clone();
            let net_source = net_source.clone();
            std::thread::Builder::new()
                .name("fpu-stats-emitter".into())
                .spawn(move || {
                    stats_emitter_loop(
                        interval, stop, metrics, health, names, trace, shards, net_source,
                    )
                })
                .expect("spawn stats emitter")
        });

        // the durable plane: open (and tail-truncate) the journal, spawn
        // the retirer, replay still-Pending records exactly once
        let mut durable = None;
        let mut retirer = None;
        let mut retirer_tx = None;
        let mut replayed = 0usize;
        if let Some(path) = &config.journal {
            let (mut journal, records) = Journal::open(path)
                .with_context(|| format!("opening request journal {}", path.display()))?;
            // arm the journal-io fault sites (append-fail, fsync-stall)
            // only after open+replay read the file: injection targets
            // live appends, not recovery
            if let Some(plan) = &config.fault {
                journal.set_fault(plan.clone());
            }
            let state = Arc::new(DurableState {
                journal: Mutex::new(journal),
                jobs: Mutex::new(HashMap::new()),
                jobs_cv: Condvar::new(),
                next_job: AtomicU64::new(0),
            });
            let (rtx, rrx) = mpsc::channel::<RetireMsg>();
            let retirer_state = state.clone();
            let retirer_trace = trace.clone();
            let retirer_handle = std::thread::Builder::new()
                .name("fpu-journal-retirer".into())
                .spawn(move || retirer_loop(rrx, retirer_state, retirer_trace))
                .expect("spawn journal retirer");
            let mut max_id = 0u64;
            for rec in coalesce(records) {
                max_id = max_id.max(rec.id + 1);
                match rec.status {
                    JobStatus::Done => {
                        state.jobs.lock().unwrap().insert(rec.id, JobPoll::Done(rec.result));
                    }
                    JobStatus::Failed => {
                        state.jobs.lock().unwrap().insert(
                            rec.id,
                            JobPoll::Failed(ServiceError::ExecFailed { backend: rec.error }),
                        );
                    }
                    JobStatus::Pending => {
                        // interrupted before its outcome was journalled:
                        // replay through the normal submit path, once
                        state.jobs.lock().unwrap().insert(rec.id, JobPoll::Pending);
                        match handle.submit_batch(rec.op, rec.format, &rec.a, &rec.b) {
                            Ok(ticket) => {
                                let _ = rtx.send((rec.id, rec.op, rec.format, ticket));
                                replayed += 1;
                            }
                            Err(err) => {
                                let mut failed = JournalRecord::pending(
                                    rec.id, rec.op, rec.format, rec.a, rec.b,
                                );
                                failed.status = JobStatus::Failed;
                                failed.error = format!("{err}");
                                let _ = state.journal.lock().unwrap().append(&failed);
                                state.resolve(rec.id, JobPoll::Failed(err));
                            }
                        }
                    }
                }
            }
            state.next_job.store(max_id, Ordering::Relaxed);
            durable = Some(state);
            retirer = Some(retirer_handle);
            retirer_tx = Some(rtx);
        }

        Ok(Self {
            handle,
            shards,
            metrics,
            health,
            backend_names: names,
            dispatchers,
            workers,
            closed,
            supervisor: Some(supervisor),
            supervisor_stop,
            durable,
            retirer,
            retirer_tx,
            replayed,
            trace,
            stats_stop,
            stats_emitter,
            started: Instant::now(),
            net_source,
        })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live metrics: the merged view over every shard's slice (see
    /// [`ServiceMetrics`]).
    pub fn metrics(&self) -> ServiceMetrics {
        self.metrics.clone()
    }

    /// How many coordinator shards this service runs.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total batches peer dispatchers stole from other shards' ready
    /// queues — the work-stealing imbalance path; 0 in a balanced
    /// steady state.
    pub fn steal_count(&self) -> u64 {
        self.shards.iter().map(|s| s.steals.load(Ordering::Relaxed)).sum()
    }

    /// Per-shard introspection rows, shard order: ring occupancy,
    /// queued lanes, ready-queue backlog and age, steal traffic both
    /// ways, and ring-full rejects. This is what the `STATS` wire frame
    /// and the Prometheus surface render per shard.
    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.shards.iter().map(|s| shard_stat_of(s)).collect()
    }

    /// Nanoseconds since [`Self::start_routed`] returned — the
    /// monotonic clock STATS clients difference qps against.
    pub fn uptime_ns(&self) -> u64 {
        self.started.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// Attach (or replace) the net-plane stats producer the stats
    /// emitter folds into its line (`net-conns=`, `net-drops=+`). The
    /// wire front end calls this once its listener is up; an
    /// in-process-only service never attaches one and the fields stay
    /// absent.
    pub fn attach_net_stats_source<F>(&self, source: F)
    where
        F: Fn() -> NetPlaneStats + Send + Sync + 'static,
    {
        *self.net_source.lock().unwrap() = Some(Arc::new(source));
    }

    /// The negotiated capability table (for a routed service: the
    /// union of every registered backend's).
    pub fn capabilities(&self) -> &BackendCaps {
        self.handle.capabilities()
    }

    /// Registered backend names, routing-preference order.
    pub fn backend_names(&self) -> &[&'static str] {
        &self.backend_names
    }

    /// Per-backend dispatch health and traffic counters, registration
    /// order: (name, snapshot). The health board is shared by every
    /// shard's dispatch plane, so these counters already aggregate all
    /// shards' traffic.
    pub fn dispatch_report(&self) -> Vec<(&'static str, BackendHealthSnapshot)> {
        self.backend_names.iter().copied().zip(self.health.snapshot()).collect()
    }

    /// The armed trace plane (`None` when started without
    /// [`ServiceConfig::trace`]). Drain its events with
    /// [`TracePlane::events`] and export via
    /// [`crate::obs::write_trace`].
    pub fn trace(&self) -> Option<Arc<TracePlane>> {
        self.trace.clone()
    }

    /// Durable vectored submission: the request is appended to the
    /// journal as `Pending` *before* it is queued, so a crash after
    /// this returns can never lose it — a restart replays it through
    /// the normal submit path. Returns the stable job id to poll with
    /// [`Self::poll_job`]; the terminal outcome is journalled by the
    /// retirer when the ticket resolves.
    ///
    /// Requires [`ServiceConfig::journal`]; otherwise every call is
    /// [`ServiceError::Rejected`].
    pub fn submit_batch_durable(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<u64, ServiceError> {
        let Some(state) = &self.durable else {
            return Err(ServiceError::Rejected {
                reason: "service started without a journal (set ServiceConfig::journal)".into(),
            });
        };
        self.handle.check_batch(op, format, a, b)?;
        let id = state.next_job.fetch_add(1, Ordering::Relaxed);
        let rec = JournalRecord::pending(id, op, format, a.to_vec(), b.to_vec());
        if let Err(e) = state.journal.lock().unwrap().append(&rec) {
            return Err(ServiceError::Rejected {
                reason: format!("journal append failed: {e:#}"),
            });
        }
        state.jobs.lock().unwrap().insert(id, JobPoll::Pending);
        if let Some(t) = &self.trace {
            if t.tick_sampled() {
                t.emit(TraceEvent::new(TraceKind::JournalAppend, t.now_ns()).req(id, op, format));
            }
        }
        match self.handle.submit_batch_inner(op, format, a, b, None, None) {
            Ok(ticket) => {
                if let Some(rtx) = &self.retirer_tx {
                    let _ = rtx.send((id, op, format, ticket));
                }
                Ok(id)
            }
            Err(err) => {
                // journalled Pending but never queued: journal the
                // failure so a restart does not replay it
                let mut failed = rec;
                failed.status = JobStatus::Failed;
                failed.error = format!("{err}");
                let _ = state.journal.lock().unwrap().append(&failed);
                state.resolve(id, JobPoll::Failed(err.clone()));
                Err(err)
            }
        }
    }

    /// A durable job's current outcome (`None`: unknown id, or the
    /// service has no journal).
    pub fn poll_job(&self, id: u64) -> Option<JobPoll> {
        self.durable.as_ref().and_then(|s| s.jobs.lock().unwrap().get(&id).cloned())
    }

    /// Block until durable job `id` **resolves** (Done/Failed) or
    /// `timeout` elapses — the streaming replacement for the
    /// [`Self::poll_job`] + sleep loop: waiters park on the job table's
    /// condvar and are woken by the retirer the moment the outcome
    /// lands.
    ///
    /// Returns the job's state at return time: `Some(Done/Failed)` on
    /// resolution, `Some(Pending)` when the timeout expired first, and
    /// `None` for an unknown id (or a service without a journal) —
    /// checked immediately, an unknown id never blocks.
    pub fn wait_for_id(&self, id: u64, timeout: Duration) -> Option<JobPoll> {
        let state = self.durable.as_ref()?;
        let deadline = Instant::now() + timeout;
        let mut jobs = state.jobs.lock().unwrap();
        loop {
            match jobs.get(&id) {
                Some(JobPoll::Pending) => {}
                other => return other.cloned(),
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(JobPoll::Pending);
            }
            // re-checks on every resolution notify; spurious wakes just
            // loop (the deadline guard above bounds the total wait)
            jobs = state.jobs_cv.wait_timeout(jobs, deadline - now).unwrap().0;
        }
    }

    /// How many still-`Pending` journal records this start replayed.
    pub fn replayed_jobs(&self) -> usize {
        self.replayed
    }

    /// Whether the durable plane is armed ([`ServiceConfig::journal`]
    /// was set) — the wire handshake grants the durable flag by this.
    pub fn is_durable(&self) -> bool {
        self.durable.is_some()
    }

    /// Shared by [`Self::shutdown`] and `Drop`; idempotent. Order
    /// matters: the dispatchers drain and retire first (resolving
    /// every ticket), then the retirer (whose waits now return
    /// instantly), then the supervisor (which unplugs and joins any
    /// respawned workers), then the original workers.
    fn teardown(&mut self) {
        self.stats_stop.store(true, Ordering::Release);
        if let Some(s) = self.stats_emitter.take() {
            let _ = s.join();
        }
        // refuse new submissions (and unblock submitters spinning on a
        // full ring) before asking the dispatchers to drain
        self.closed.store(true, Ordering::Release);
        if !self.dispatchers.is_empty() {
            for shard in self.shards.iter() {
                // one Shutdown marker per ring; a full ring clears as
                // its dispatcher drains, so bound the wait instead of
                // spinning forever should a dispatcher have died
                let deadline = Instant::now() + SHUTDOWN_RETIRE_BUDGET;
                let mut msg = DispatchMsg::Shutdown;
                loop {
                    match shard.ring.try_push(msg) {
                        Ok(()) => {
                            shard.events.notify();
                            break;
                        }
                        Err(back) => {
                            if Instant::now() >= deadline {
                                break;
                            }
                            msg = back;
                            std::thread::sleep(Duration::from_micros(50));
                        }
                    }
                }
            }
            for d in self.dispatchers.drain(..) {
                let _ = d.join();
            }
            // a submission racing shutdown may have published after its
            // dispatcher's final drain: fail those riders typed instead
            // of leaving them to the ring's drop-drain
            for shard in self.shards.iter() {
                while let Some(msg) = shard.ring.pop() {
                    if let DispatchMsg::Req(item) = msg {
                        shard.metrics.record_dequeued(item.op, item.format(), item.lanes() as u64);
                        item.fail(ServiceError::Shutdown);
                    }
                }
            }
        }
        drop(self.retirer_tx.take());
        if let Some(r) = self.retirer.take() {
            let _ = r.join();
        }
        self.supervisor_stop.store(true, Ordering::Release);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful shutdown: drains queued work, retires in-flight
    /// batches, joins all threads.
    pub fn shutdown(mut self) {
        self.teardown();
    }
}

impl Drop for FpuService {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Fail every rider of a batch with a typed error and recycle its
/// planes (the terminal outcome of the retry chain). Emits the
/// error-class BatchFailed event when a trace plane is armed.
fn fail_batch(
    mut batch: Batch,
    err: ServiceError,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
    trace: Option<&Arc<TracePlane>>,
) {
    outstanding.fetch_sub(1, Ordering::AcqRel);
    metrics.record_error(batch.op, batch.format, batch.live() as u64);
    if let Some(t) = trace {
        t.emit(
            TraceEvent::new(TraceKind::BatchFailed, t.now_ns())
                .req(batch.items.first().map_or(0, |i| i.id), batch.op, batch.format)
                .on_backend(batch.backend)
                .with_lanes(batch.live()),
        );
    }
    for item in batch.items.drain(..) {
        item.fail(err.clone());
    }
    plane_pool.give(std::mem::take(&mut batch.a));
    plane_pool.give(std::mem::take(&mut batch.b));
}

/// Re-shape a batch for a different backend: planes are rebuilt at the
/// new backend's negotiated width and re-padded to its ladder. The
/// common case (same width, same padded size — e.g. failover between
/// backends sharing the default ladder) is a no-op; the lane-copy slow
/// path only runs on the rare cross-shape retry.
fn reshape_for_backend(
    batch: &mut Batch,
    backend: usize,
    batcher: &DynamicBatcher,
    plane_pool: &PlanePool,
) {
    let width = batcher.plane_width_for(backend, batch.format);
    let live = batch.live();
    // never below `live`: a failover target whose largest ladder rung
    // is smaller than this batch must still receive every lane (an
    // off-ladder size is at worst a typed executor error that continues
    // the retry chain; a truncated plane would drop riders' lanes and
    // panic the completion loop)
    let padded = batcher.padded_for(backend, batch.op, batch.format, live).max(live);
    if width == batch.a.width() && padded == batch.padded {
        return;
    }
    let one = batch.format.one_bits();
    let mut a = plane_pool.take(width);
    a.reserve(padded);
    for i in 0..live {
        a.push(batch.a.get(i));
    }
    a.resize(padded, one);
    plane_pool.give(std::mem::replace(&mut batch.a, a));
    if batch.op == OpKind::Divide {
        let mut b = plane_pool.take(width);
        b.reserve(padded);
        for i in 0..live {
            b.push(batch.b.get(i));
        }
        b.resize(padded, one);
        plane_pool.give(std::mem::replace(&mut batch.b, b));
    }
    batch.padded = padded;
}

/// Send into a pool, briefly waiting out a *total* worker die-off: when
/// every worker of a pool died at once the supervisor is already
/// respawning one, and failing the batch over (or failing the riders,
/// on a single-backend service) during that window would turn a
/// recoverable blip into user-visible errors. Gives up immediately once
/// the pool is marked degraded (respawns are failing) and after
/// [`POOL_RESPAWN_WAIT`] otherwise.
fn send_with_respawn_wait(
    pool: &mut PoolSender,
    batch: Batch,
    health: &HealthBoard,
    backend: usize,
) -> std::result::Result<(), Batch> {
    let deadline = Instant::now() + POOL_RESPAWN_WAIT;
    let mut batch = batch;
    loop {
        match pool.send(batch) {
            Ok(()) => return Ok(()),
            Err(returned) => {
                if health.is_degraded(backend) || Instant::now() >= deadline {
                    return Err(returned);
                }
                batch = returned;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Hand one batch to `backend`'s pool; if that pool's workers are all
/// gone (and stay gone past the respawn wait), walk the retry chain to
/// the next untried candidate (reshaping the batch). When every
/// candidate pool is gone the riders fail with the execution error that
/// started the retry (`exec_error`, if this batch already failed
/// somewhere) — [`ServiceError::Shutdown`] is reserved for a batch that
/// never reached any executor.
#[allow(clippy::too_many_arguments)]
fn send_batch(
    mut batch: Batch,
    mut backend: usize,
    exec_error: Option<String>,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    batcher: &DynamicBatcher,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    loop {
        batch.backend = backend;
        batch.tried |= 1u8 << backend;
        match send_with_respawn_wait(&mut pools[backend], batch, plane.health(), backend) {
            Ok(()) => return,
            Err(returned) => {
                batch = returned;
                match plane.select_excluding(batch.op, batch.format, batch.tried) {
                    Some(sel) => {
                        reshape_for_backend(&mut batch, sel.backend, batcher, plane_pool);
                        backend = sel.backend;
                    }
                    None => {
                        let err = match exec_error {
                            Some(backend_msg) => {
                                ServiceError::ExecFailed { backend: backend_msg }
                            }
                            None => ServiceError::Shutdown,
                        };
                        fail_batch(batch, err, metrics, plane_pool, outstanding, plane.trace());
                        return;
                    }
                }
            }
        }
    }
}

/// Re-route a batch a worker handed back. A *blamed* failure
/// (`error: Some`) goes to the next untried candidate — a reshaped copy
/// of the same lanes, rider-invisible failover — and with no candidate
/// left, every rider gets the backend's error, typed. An *unblamed*
/// hand-back (`error: None`: the worker died without executing) first
/// clears the batch's own tried bit, so the same backend's respawned
/// pool is allowed to serve it again.
fn reroute_failed(
    failed: FailedBatch,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    batcher: &DynamicBatcher,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    let FailedBatch { mut batch, error } = failed;
    if error.is_none() {
        batch.tried &= !(1u8 << batch.backend);
    }
    match plane.select_excluding(batch.op, batch.format, batch.tried) {
        Some(sel) => {
            if error.is_some() {
                plane.health().record_reroute(batch.backend);
                // error-class: the hop is always captured, blaming the
                // backend that failed the batch (`arg` = the next one)
                if let Some(t) = plane.trace() {
                    t.emit(
                        TraceEvent::new(TraceKind::FailoverHop, t.now_ns())
                            .req(
                                batch.items.first().map_or(0, |i| i.id),
                                batch.op,
                                batch.format,
                            )
                            .on_backend(batch.backend)
                            .with_lanes(batch.live())
                            .with_arg(sel.backend as u64),
                    );
                }
            }
            reshape_for_backend(&mut batch, sel.backend, batcher, plane_pool);
            send_batch(
                batch,
                sel.backend,
                error,
                plane,
                pools,
                batcher,
                metrics,
                plane_pool,
                outstanding,
            );
        }
        None => {
            let err = match error {
                Some(backend) => ServiceError::ExecFailed { backend },
                None => ServiceError::Shutdown,
            };
            fail_batch(batch, err, metrics, plane_pool, outstanding, plane.trace());
        }
    }
}

/// Form batches for every queue that should flush (`flush` = drain
/// unconditionally), select each one's backend, and expose them on the
/// shard's **ready queue**. Dispatch happens separately — normally the
/// owner's [`drain_own_ready`] an instant later, or a peer's
/// [`steal_one`] when the owner stalls: the ready queue is the hand-off
/// point that makes whole-batch work stealing possible without sharing
/// the router or batcher across shards.
fn form_ready(
    flush: bool,
    router: &mut Router,
    me: &ShardShared,
    batcher: &DynamicBatcher,
    plane: &mut DispatchPlane,
    plane_pool: &PlanePool,
) {
    let now = Instant::now();
    for &op in &OpKind::ALL {
        for &format in &FormatKind::ALL {
            loop {
                if router.len(op, format) == 0 {
                    break;
                }
                let Some(peek) = plane.peek_candidate(op, format) else {
                    // unreachable through the handle (union-caps checked
                    // at submit), but a direct router feed must not
                    // wedge: fail the queue typed
                    for item in router.drain(op, format, usize::MAX) {
                        me.metrics.record_dequeued(op, format, item.lanes() as u64);
                        me.metrics.record_error(op, format, item.lanes() as u64);
                        item.fail(ServiceError::Rejected {
                            reason: format!("no backend serves ({}, {format})", op.label()),
                        });
                    }
                    break;
                };
                // the flush decision peeks a candidate's shape without
                // consuming probe/exploration state; only a batch that
                // actually forms pays a select()
                if !flush && !batcher.should_flush_for(peek, router, op, format, now) {
                    break;
                }
                let sel = plane.select(op, format).expect("peeked candidate exists");
                match batcher
                    .form_batch_for(sel.backend, router, op, format, now, plane_pool, &me.metrics)
                {
                    Some(mut batch) => {
                        // carry the selection to whoever dispatches —
                        // backend indices are shard-invariant, so the
                        // choice is valid on a stealer's pools too
                        batch.backend = sel.backend;
                        me.ready.lock().unwrap().push_back(batch);
                    }
                    None => {
                        if router.len(op, format) == 0 {
                            break; // everything drained was shed
                        }
                    }
                }
            }
        }
    }
}

/// The shutdown retire loop: keep servicing the retry channel until
/// every dispatched batch reached a terminal outcome, so a backend
/// dying during shutdown still fails over down its candidate chain
/// instead of stranding riders. Each serviced retry **resets** the
/// budget clock (progress earns more time — a chain of N candidates
/// gets the budget per hop); the trailing drain then services anything
/// already queued on the channel even when the budget is zero.
#[allow(clippy::too_many_arguments)]
fn retire_outstanding(
    retry_rx: &Receiver<FailedBatch>,
    retire_budget: Duration,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    batcher: &DynamicBatcher,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    let mut give_up = Instant::now() + retire_budget;
    while outstanding.load(Ordering::Acquire) > 0 && Instant::now() < give_up {
        match retry_rx.recv_timeout(Duration::from_millis(1)) {
            Ok(failed) => {
                reroute_failed(failed, plane, pools, batcher, metrics, plane_pool, outstanding);
                give_up = Instant::now() + retire_budget;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // final drain: a retry already on the channel must exhaust its
    // candidate chain before the pools close — without this, a batch a
    // dying backend handed back in the last instant would be dropped
    // (its riders stranded as Shutdown) despite a live candidate
    while let Ok(failed) = retry_rx.try_recv() {
        reroute_failed(failed, plane, pools, batcher, metrics, plane_pool, outstanding);
    }
}

/// Everything one shard's dispatcher owns (or shares read-only): its
/// routing plane, pools and retry channel, plus the shared shard list
/// it may steal from when idle.
struct ShardRuntime {
    index: usize,
    shards: Arc<Vec<Arc<ShardShared>>>,
    retry_rx: Receiver<FailedBatch>,
    batcher: DynamicBatcher,
    plane: DispatchPlane,
    pools: Vec<PoolSender>,
    poll: Duration,
    retire_budget: Duration,
    plane_pool: PlanePool,
    outstanding: Arc<AtomicI64>,
    metrics: Arc<Metrics>,
    fault: Option<Arc<FaultPlan>>,
}

/// Dispatch one ready batch on `rt`'s pools, counting it against `rt`'s
/// outstanding counter — the dispatching shard (owner or stealer) owns
/// the batch through its terminal outcome, including failover re-routes
/// through its own retry channel.
fn dispatch_one(batch: Batch, rt: &mut ShardRuntime) {
    let backend = batch.backend;
    rt.outstanding.fetch_add(1, Ordering::AcqRel);
    send_batch(
        batch,
        backend,
        None,
        &mut rt.plane,
        &mut rt.pools,
        &rt.batcher,
        &rt.metrics,
        &rt.plane_pool,
        &rt.outstanding,
    );
}

/// Drain every batch from this shard's own ready queue (oldest first).
/// Returns how many were dispatched. The lock is released between pops
/// so a stealing peer is never held out for a whole drain.
fn drain_own_ready(me: &ShardShared, rt: &mut ShardRuntime) -> usize {
    let mut n = 0;
    loop {
        let batch = me.ready.lock().unwrap().pop_front();
        match batch {
            Some(b) => {
                dispatch_one(b, rt);
                n += 1;
            }
            None => return n,
        }
    }
}

/// Steal the oldest sufficiently-aged ready batch from one peer, if
/// any, and dispatch it on **this** shard's pools. Whole batches only,
/// front (oldest) first: lanes stay together and a peer's per-handle
/// order is preserved, so bit-identity invariants hold. Backend indices
/// are shard-invariant (every plane is built over the same registration
/// order), so the owner's backend selection is valid on the stealer.
fn steal_one(rt: &mut ShardRuntime) -> bool {
    let now = Instant::now();
    for offset in 1..rt.shards.len() {
        let j = (rt.index + offset) % rt.shards.len();
        let peer = rt.shards[j].clone();
        let batch = {
            let mut q = peer.ready.lock().unwrap();
            match q.front() {
                Some(front) if now.saturating_duration_since(front.formed_at) >= STEAL_AGE => {
                    q.pop_front()
                }
                _ => None,
            }
        };
        if let Some(batch) = batch {
            peer.steals.fetch_add(1, Ordering::Relaxed);
            rt.shards[rt.index].steals_in.fetch_add(1, Ordering::Relaxed);
            dispatch_one(batch, rt);
            return true;
        }
    }
    false
}

/// One shard's dispatcher loop: drain the ring into the router, form
/// ready batches, dispatch them, and steal from stalled peers when
/// otherwise idle.
fn shard_dispatcher_loop(mut rt: ShardRuntime) {
    let me = rt.shards[rt.index].clone();
    let mut router = Router::new();
    router.set_trace(rt.plane.trace().cloned());
    'outer: loop {
        let mut busy = false;
        // park until work arrives (bounded by the poll tick), then
        // greedily drain the ring so the batcher sees the whole burst
        // at once (otherwise a stale-age flush would emit singleton
        // batches while the ring still holds work)
        if me.ring.is_empty() {
            me.events.park_timeout(|| !me.ring.is_empty(), rt.poll);
        }
        loop {
            match me.ring.pop() {
                Some(DispatchMsg::Req(req)) => {
                    router.route(req);
                    busy = true;
                }
                Some(DispatchMsg::Shutdown) => break 'outer,
                None => break,
            }
        }
        // failed batches re-route before new work dispatches: their
        // riders have waited longest
        while let Ok(failed) = rt.retry_rx.try_recv() {
            busy = true;
            reroute_failed(
                failed,
                &mut rt.plane,
                &mut rt.pools,
                &rt.batcher,
                &rt.metrics,
                &rt.plane_pool,
                &rt.outstanding,
            );
        }
        form_ready(false, &mut router, &me, &rt.batcher, &mut rt.plane, &rt.plane_pool);
        // the ring-stall chaos site: delay this consumer between batch
        // formation and dispatch — exactly the window where its ready
        // queue is exposed to peer stealing and its ring backs up onto
        // submitters. Consulted only when batches are actually exposed,
        // so idle poll ticks do not burn the plan's occurrence window.
        if let Some(plan) = &rt.fault {
            if !me.ready.lock().unwrap().is_empty() {
                if let Some(shot) = plan.check(FaultSite::RingStall, &me.name) {
                    std::thread::sleep(Duration::from_micros(shot.micros));
                }
            }
        }
        if drain_own_ready(&me, &mut rt) > 0 {
            busy = true;
        }
        // only an idle tick pays the peer scan: work stealing is the
        // imbalance path, not the steady state
        if !busy {
            steal_one(&mut rt);
        }
    }
    // drain everything left on this shard's ring
    while let Some(msg) = me.ring.pop() {
        if let DispatchMsg::Req(req) = msg {
            router.route(req);
        }
    }
    form_ready(true, &mut router, &me, &rt.batcher, &mut rt.plane, &rt.plane_pool);
    drain_own_ready(&me, &mut rt);
    // retire in-flight batches before closing the pools
    retire_outstanding(
        &rt.retry_rx,
        rt.retire_budget,
        &mut rt.plane,
        &mut rt.pools,
        &rt.batcher,
        &rt.metrics,
        &rt.plane_pool,
        &rt.outstanding,
    );
    // unplug every worker channel explicitly: the supervisor shares the
    // slot lists (behind `Arc`), so dropping `pools` alone would not
    // disconnect the workers' receivers
    for p in &rt.pools {
        p.shared.slots.lock().unwrap().clear();
    }
}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// literal or a formatted message covers the executor cases).
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload".to_string()
    }
}

/// Hand a batch back to the dispatcher's retry channel; if the
/// dispatcher is already gone (teardown), resolve the riders here,
/// typed (`Some` error: the backend's own message; `None`: shutdown).
fn send_failed_or_fail(ctx: &WorkerCtx, failed: FailedBatch) {
    if let Err(mpsc::SendError(failed)) = ctx.retry_tx.send(failed) {
        let FailedBatch { mut batch, error } = failed;
        let err = match error {
            Some(backend) => ServiceError::ExecFailed { backend },
            None => ServiceError::Shutdown,
        };
        ctx.metrics.record_error(batch.op, batch.format, batch.live() as u64);
        if let Some(t) = &ctx.trace {
            t.emit(
                TraceEvent::new(TraceKind::BatchFailed, t.now_ns())
                    .req(batch.items.first().map_or(0, |i| i.id), batch.op, batch.format)
                    .on_backend(ctx.backend)
                    .with_lanes(batch.live()),
            );
        }
        for item in batch.items.drain(..) {
            item.fail(err.clone());
        }
        ctx.outstanding.fetch_sub(1, Ordering::AcqRel);
        ctx.pool.give(std::mem::take(&mut batch.a));
        ctx.pool.give(std::mem::take(&mut batch.b));
    }
}

/// A dying worker's exit protocol: notify the supervisor (which removes
/// this worker's slot, disconnecting its channel), then forward any
/// batches still buffered on the channel to the retry path, unblamed —
/// they were never executed.
fn abnormal_exit(rx: &Receiver<Batch>, ctx: &WorkerCtx, slot_id: u64) {
    let _ = ctx.exit_tx.send(ExitNotice { shard: ctx.shard, backend: ctx.backend, slot_id });
    while let Ok(batch) = rx.recv() {
        send_failed_or_fail(ctx, FailedBatch { batch, error: None });
    }
}

fn worker_loop(rx: Receiver<Batch>, mut executor: Box<dyn Executor>, ctx: WorkerCtx, slot_id: u64) {
    // all buffers persist across batches: the steady-state hot path
    // performs no allocation in this loop (execute_into writes in place
    // at the batch's plane width, operand planes go back to the pool).
    // One output buffer per width; `widened` is the u64 view the ticket
    // boundary needs for u32 batches.
    let mut out32: Vec<u32> = Vec::new();
    let mut out64: Vec<u64> = Vec::new();
    let mut widened: Vec<u64> = Vec::new();
    let mut lat: Vec<(u64, usize)> = Vec::new();
    loop {
        let mut batch = match rx.recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        // worker-level fault sites (executor-level sites live inside
        // the FaultInjectingExecutor wrapper)
        if let Some(plan) = &ctx.fault {
            if let Some(shot) = plan.check(FaultSite::SlowDrain, ctx.name) {
                std::thread::sleep(Duration::from_micros(shot.micros));
            }
            if plan.check(FaultSite::WorkerDeath, ctx.name).is_some() {
                // error-class: an injected death is always captured,
                // blamed on this worker's backend
                if let Some(t) = &ctx.trace {
                    t.emit(
                        TraceEvent::new(TraceKind::WorkerDeath, t.now_ns())
                            .req(batch.items.first().map_or(0, |i| i.id), batch.op, batch.format)
                            .on_backend(ctx.backend)
                            .on_shard(ctx.shard)
                            .with_lanes(batch.live()),
                    );
                }
                send_failed_or_fail(&ctx, FailedBatch { batch, error: None });
                abnormal_exit(&rx, &ctx, slot_id);
                return;
            }
        }
        let width = batch.a.width();
        let t0 = Instant::now();
        // the executor call runs under catch_unwind: a panicking
        // executor (a bug, or an injected exec-panic) must not take the
        // whole service down — the batch fails over like any executor
        // error and this worker exits for the supervisor to replace
        let result = {
            let (op, format) = (batch.op, batch.format);
            match width {
                PlaneWidth::W32 => {
                    out32.clear();
                    out32.resize(batch.padded, 0);
                    let out = &mut out32;
                    catch_unwind(AssertUnwindSafe(|| {
                        let b_plane =
                            if op == OpKind::Divide { Some(batch.b.as_ref()) } else { None };
                        executor.execute_into(
                            op,
                            format,
                            batch.a.as_ref(),
                            b_plane,
                            PlaneRefMut::W32(out),
                        )
                    }))
                }
                PlaneWidth::W64 => {
                    out64.clear();
                    out64.resize(batch.padded, 0);
                    let out = &mut out64;
                    catch_unwind(AssertUnwindSafe(|| {
                        let b_plane =
                            if op == OpKind::Divide { Some(batch.b.as_ref()) } else { None };
                        executor.execute_into(
                            op,
                            format,
                            batch.a.as_ref(),
                            b_plane,
                            PlaneRefMut::W64(out),
                        )
                    }))
                }
            }
        };
        let exec_ns = t0.elapsed().as_nanos() as u64;
        match result {
            Ok(Ok(())) => {
                let live = batch.live() as u64;
                ctx.health.record_success(ctx.backend, batch.op, batch.format, live, exec_ns);
                let done = Instant::now();
                lat.clear();
                for item in &batch.items {
                    lat.push((
                        done.duration_since(item.enqueued_at).as_nanos() as u64,
                        item.lanes(),
                    ));
                }
                // record metrics BEFORE completing: once a client observes
                // its response, the snapshot already includes it
                ctx.metrics.record_batch(batch.op, batch.format, &lat, exec_ns, batch.padded);
                // stage spans for sampled riders: the four stages tile
                // [done - total, done] exactly, so they always sum to
                // the rider-observed latency (`Complete.arg`). Clamping
                // order matters: exec is the best-measured quantity,
                // then queue wait, then failover; the batch stage
                // absorbs the residual (dispatch + worker-queue time).
                if batch.sampled {
                    if let Some(t) = &ctx.trace {
                        let done_ns = t.ns_of(done);
                        for (k, item) in batch.items.iter().enumerate() {
                            if !item.sampled {
                                continue;
                            }
                            let total = lat[k].0;
                            let exec = exec_ns.min(total);
                            let queue = batch
                                .formed_at
                                .saturating_duration_since(item.enqueued_at)
                                .as_nanos()
                                .min(total.saturating_sub(exec) as u128)
                                as u64;
                            let failover =
                                batch.failover_ns.min(total.saturating_sub(exec + queue));
                            let residual = total - queue - exec - failover;
                            let t0 = done_ns.saturating_sub(total);
                            // the dispatching shard is not knowable here
                            // (a stolen batch executes on the thief's
                            // workers), so stage spans carry the worker's
                            // own shard — exactly the attribution the
                            // per-shard report wants
                            let stamp = |kind: TraceKind, at: u64, dur: u64| {
                                TraceEvent::new(kind, at)
                                    .req(item.id, batch.op, batch.format)
                                    .on_backend(ctx.backend)
                                    .on_shard(ctx.shard)
                                    .with_lanes(item.lanes())
                                    .spanning(dur)
                            };
                            t.emit(stamp(TraceKind::StageQueue, t0, queue));
                            t.emit(stamp(TraceKind::StageBatch, t0 + queue, residual));
                            t.emit(stamp(
                                TraceKind::StageFailover,
                                t0 + queue + residual,
                                failover,
                            ));
                            t.emit(stamp(
                                TraceKind::StageExec,
                                t0 + queue + residual + failover,
                                exec,
                            ));
                            t.emit(
                                TraceEvent::new(TraceKind::Complete, t0 + total)
                                    .req(item.id, batch.op, batch.format)
                                    .on_backend(ctx.backend)
                                    .on_shard(ctx.shard)
                                    .with_lanes(item.lanes())
                                    .with_arg(total),
                            );
                        }
                    }
                }
                // tickets store u64 result words: widen u32 result
                // planes once per batch (the one narrowing boundary)
                let view: &[u64] = match width {
                    PlaneWidth::W32 => {
                        widened.clear();
                        widened.extend(out32.iter().map(|&w| w as u64));
                        &widened
                    }
                    PlaneWidth::W64 => &out64,
                };
                let mut off = 0usize;
                for (k, item) in batch.items.drain(..).enumerate() {
                    let lanes = item.lanes();
                    item.complete(&view[off..off + lanes], lat[k].0, batch.padded);
                    off += lanes;
                }
                ctx.outstanding.fetch_sub(1, Ordering::AcqRel);
                ctx.pool.give(std::mem::take(&mut batch.a));
                ctx.pool.give(std::mem::take(&mut batch.b));
            }
            Ok(Err(e)) => {
                // hand the batch (planes intact) back to the dispatcher
                // for re-routing; the riders only see an error if every
                // candidate backend fails it
                ctx.health.record_failure(ctx.backend);
                if let Some(t) = &ctx.trace {
                    t.emit(
                        TraceEvent::new(TraceKind::ExecError, t.now_ns())
                            .req(batch.items.first().map_or(0, |i| i.id), batch.op, batch.format)
                            .on_backend(ctx.backend)
                            .on_shard(ctx.shard)
                            .with_lanes(batch.live()),
                    );
                }
                // the failed attempt's executor time is failover
                // overhead from the riders' point of view
                batch.failover_ns += exec_ns;
                let error = Some(format!("{e:#}"));
                send_failed_or_fail(&ctx, FailedBatch { batch, error });
            }
            Err(payload) => {
                // the executor panicked: blame the backend (breaker +
                // failover, riders see the panic text only if every
                // candidate fails), then die for the supervisor
                ctx.health.record_failure(ctx.backend);
                if let Some(t) = &ctx.trace {
                    t.emit(
                        TraceEvent::new(TraceKind::WorkerDeath, t.now_ns())
                            .req(batch.items.first().map_or(0, |i| i.id), batch.op, batch.format)
                            .on_backend(ctx.backend)
                            .on_shard(ctx.shard)
                            .with_lanes(batch.live()),
                    );
                }
                batch.failover_ns += exec_ns;
                let error = Some(format!("worker panicked: {}", panic_message(&*payload)));
                send_failed_or_fail(&ctx, FailedBatch { batch, error });
                abnormal_exit(&rx, &ctx, slot_id);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::PlaneRef;
    use crate::runtime::executor::NativeExecutor;

    fn quick_config() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig::new(64, Duration::from_micros(100)),
            queue_depth: 1024,
            workers: 1,
            poll: Duration::from_micros(50),
            ..ServiceConfig::default()
        }
    }

    fn native() -> Result<Box<dyn Executor>> {
        Ok(Box::new(NativeExecutor::with_defaults()))
    }

    #[test]
    fn round_trip_divide() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(10.0, 4.0).unwrap(), 2.5);
        assert_eq!(h.sqrt(81.0).unwrap(), 9.0);
        assert_eq!(h.rsqrt(4.0).unwrap(), 0.5);
        svc.shutdown();
    }

    #[test]
    fn round_trip_every_format() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
            // the response carries the request's format tag
            let t = h
                .submit_value(
                    OpKind::Divide,
                    Value::from_f64(format, 6.0),
                    Value::from_f64(format, 2.0),
                )
                .unwrap();
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.format(), format);
            assert_eq!(resp.value.to_f64(), 3.0);
        }
        let snap = svc.metrics().snapshot();
        for format in FormatKind::ALL {
            assert!(snap.op_format(OpKind::Divide, format).requests >= 2, "{format}");
        }
        svc.shutdown();
    }

    #[test]
    fn mixed_format_operands_rejected() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_value(OpKind::Divide, Value::F32(1.0), Value::F64(2.0)) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("format mismatch"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        svc.shutdown();
    }

    #[test]
    fn capabilities_visible_on_handle() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let caps = svc.handle().capabilities().clone();
        assert_eq!(caps.backend(), "native-fixed-point");
        assert!(caps.supports(OpKind::Divide, FormatKind::BF16));
        assert_eq!(caps.ladder(OpKind::Divide, FormatKind::F32), &[64, 256, 1024]);
        assert_eq!(svc.capabilities().backend(), "native-fixed-point");
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for i in 1..50u32 {
                    let n = (t * 100 + i) as f32;
                    let q = h.divide(n * 3.0, 3.0).unwrap();
                    assert_eq!(q, n);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op(OpKind::Divide).requests, 8 * 49);
        assert_eq!(snap.total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn batches_actually_form() {
        // long wait + many pipelined submissions => multi-request batches
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_millis(5));
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..200).map(|i| h.submit(OpKind::Divide, i as f32, 1.0).unwrap()).collect();
        let mut max_batch = 0usize;
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.f32(), i as f32);
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_round_trip() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let n: Vec<u64> = (1..=100u32).map(|i| ((3 * i) as f32).to_bits() as u64).collect();
        let d: Vec<u64> = (1..=100u32).map(|_| 3.0f32.to_bits() as u64).collect();
        let ticket = h.submit_batch(OpKind::Divide, FormatKind::F32, &n, &d).unwrap();
        assert_eq!(ticket.lanes(), 100);
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.len(), 100);
        for (i, v) in resp.values().enumerate() {
            assert_eq!(v.f32(), (i + 1) as f32, "lane {i}");
        }
        // unary vectored path
        let x: Vec<u64> = [4.0f32, 9.0, 16.0].iter().map(|v| v.to_bits() as u64).collect();
        let resp = h.submit_batch(OpKind::Sqrt, FormatKind::F32, &x, &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 3);
        assert_eq!(resp.value(0).f32(), 2.0);
        assert_eq!(resp.value(2).f32(), 4.0);
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_validates_arity() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let a = [1.0f32.to_bits() as u64];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::F32, &a, &[]),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &a, &a),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &[], &[]),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_rejects_oversized_words() {
        // a raw word that does not fit the format's container is a
        // typed Rejected, not a narrowing panic or silent truncation
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x1_0000], &[]) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("does not fit"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        // the divisor plane is checked too
        let ok = [0x3C00u64, 0x4000];
        let bad = [0x3C00u64, u64::MAX];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::BF16, &ok, &bad),
            Err(ServiceError::Rejected { .. })
        ));
        // in-range f16 words and full-width f64 words pass
        let resp =
            h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x4400], &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 1);
        let w = (-2.0f64).to_bits(); // high bit set: fine for a 64-bit container
        assert!(h.submit_batch(OpKind::Sqrt, FormatKind::F64, &[w], &[]).is_ok());
        svc.shutdown();
    }

    #[test]
    fn deadline_admission_rejects_at_submit() {
        // the ROADMAP admission-control item, v2: a queue-depth x
        // service-rate model. Once (queued lanes) x (windowed executor
        // cost per lane) exceeds a submission's budget, the submission
        // fails with Deadline at submit time — before any queueing
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        // a cold service has no rate signal: even a tiny budget is
        // admitted
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(6.0),
                Value::F32(2.0),
                Duration::from_secs(5),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 3.0);
        // seed the rate window: ~1ms of executor time per lane on
        // (divide, f32)
        // (fed straight into shard 0's slice — the only shard here, so
        // the handle's admission check reads exactly this slice)
        for _ in 0..8 {
            svc.metrics().shard(0).record_batch(
                OpKind::Divide,
                FormatKind::F32,
                &[(10_000_000, 1)],
                1_000_000,
                1,
            );
        }
        // ... and a standing backlog of 200 lanes: the model predicts
        // ~200ms of queue delay (the gauge is what the router's lane
        // counts feed in production; the test feeds it directly)
        svc.metrics().shard(0).record_enqueued(OpKind::Divide, FormatKind::F32, 200);
        // a 50us budget is now hopeless: rejected at submit, typed
        match h.submit_value_deadline(
            OpKind::Divide,
            Value::F32(6.0),
            Value::F32(2.0),
            Duration::from_micros(50),
        ) {
            Err(ServiceError::Deadline) => {}
            other => panic!("expected Deadline at submit, got {:?}", other.map(|t| t.id())),
        }
        // the vectored path is gated the same way, counting every lane
        let a: Vec<u64> = vec![2.0f32.to_bits() as u64; 10];
        assert!(matches!(
            h.submit_batch_deadline(
                OpKind::Divide,
                FormatKind::F32,
                &a,
                &a,
                Duration::from_micros(50)
            ),
            Err(ServiceError::Deadline)
        ));
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op_format(OpKind::Divide, FormatKind::F32).admission_rejected, 11);
        assert_eq!(snap.total_shed(), 0, "admission rejects are not queue sheds");
        // clearing the backlog re-opens admission instantly — the depth
        // model needs no latency window to decay. (The request may
        // still shed *in the queue* on a slow run; the property under
        // test is that submit no longer rejects.)
        svc.metrics().shard(0).record_dequeued(OpKind::Divide, FormatKind::F32, 200);
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(8.0),
                Value::F32(2.0),
                Duration::from_micros(50),
            )
            .expect("empty queue admits any budget");
        let _ = t.wait();
        // and a generous budget completes end to end
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(8.0),
                Value::F32(2.0),
                Duration::from_secs(30),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 4.0);
        // other (op, format) slots are unaffected by this slot's history
        svc.metrics().shard(0).record_enqueued(OpKind::Divide, FormatKind::F32, 200);
        let t = h
            .submit_value_deadline(
                OpKind::Sqrt,
                Value::F32(9.0),
                Value::F32(1.0),
                Duration::from_micros(50),
            )
            .unwrap();
        let _ = t.wait(); // may complete or shed; must not reject at submit
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_secs(10)); // only drain flushes
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..10).map(|i| h.submit(OpKind::Sqrt, (i * i) as f32, 1.0).unwrap()).collect();
        svc.shutdown(); // must flush the waiting batch
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), i as f32);
        }
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        svc.shutdown();
        assert_eq!(h.divide(1.0, 1.0).unwrap_err(), ServiceError::Shutdown);
    }

    #[test]
    fn multiple_workers() {
        let mut cfg = quick_config();
        cfg.workers = 4;
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (1..=500).map(|i| h.submit(OpKind::Divide, (2 * i) as f32, 2.0).unwrap()).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), (i + 1) as f32);
        }
        svc.shutdown();
    }

    #[test]
    fn failing_executor_reports_typed_errors() {
        struct Failing;
        impl Executor for Failing {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::uniform("failing", &[64])
            }
            fn execute_into(
                &mut self,
                _: OpKind,
                _: FormatKind,
                _: PlaneRef<'_>,
                _: Option<PlaneRef<'_>>,
                _: PlaneRefMut<'_>,
            ) -> Result<()> {
                bail!("injected failure")
            }
        }
        let svc =
            FpuService::start(quick_config(), || Ok(Box::new(Failing) as Box<dyn Executor>))
                .unwrap();
        let h = svc.handle();
        let t = h.submit(OpKind::Divide, 1.0, 1.0).unwrap();
        // the backend's message reaches the client, typed
        match t.wait() {
            Err(ServiceError::ExecFailed { backend }) => {
                assert!(backend.contains("injected failure"), "{backend}");
            }
            other => panic!("expected ExecFailed, got {other:?}"),
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.total_errors(), 1);
        svc.shutdown();
    }

    #[test]
    fn unsupported_pair_rejected_at_submit() {
        // a backend that only serves f32 divide: everything else is
        // rejected before queueing, with the backend named
        struct DivOnly(NativeExecutor);
        impl Executor for DivOnly {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::new("div-only").with(OpKind::Divide, FormatKind::F32, &[64])
            }
            fn execute_into(
                &mut self,
                op: OpKind,
                format: FormatKind,
                a: PlaneRef<'_>,
                b: Option<PlaneRef<'_>>,
                out: PlaneRefMut<'_>,
            ) -> Result<()> {
                self.0.execute_into(op, format, a, b, out)
            }
        }
        let svc = FpuService::start(quick_config(), || {
            Ok(Box::new(DivOnly(NativeExecutor::with_defaults())) as Box<dyn Executor>)
        })
        .unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(6.0, 2.0).unwrap(), 3.0);
        match h.sqrt(4.0) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("div-only"), "{reason}");
                assert!(reason.contains("sqrt"), "{reason}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert!(matches!(
            h.divide_in(FormatKind::F64, 1.0, 1.0),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }

    #[test]
    fn routed_service_merges_capabilities_and_serves() {
        use crate::runtime::executor::{ScalarReferenceExecutor, U128BaselineExecutor};
        // u128 first (divide-only preference), scalar second: the union
        // must admit every pair, divide routes to u128, sqrt to scalar
        let registry = ExecutorRegistry::new()
            .register(|| Ok(Box::new(U128BaselineExecutor::with_defaults()) as _))
            .register(|| Ok(Box::new(ScalarReferenceExecutor::with_defaults()) as _));
        let svc = FpuService::start_routed(quick_config(), registry).unwrap();
        assert_eq!(svc.backend_names(), &["u128-baseline", "scalar-reference"]);
        let caps = svc.capabilities();
        assert_eq!(caps.backend(), "dispatch");
        assert_eq!(caps.supported().len(), 12, "union admits what either serves");
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
        }
        let report = svc.dispatch_report();
        assert_eq!(report.len(), 2);
        let (u128_snap, scalar_snap) = (report[0].1, report[1].1);
        assert!(u128_snap.ok_batches > 0, "divide batches route to the preferred backend");
        assert!(scalar_snap.ok_batches > 0, "unary batches route to the only capable backend");
        assert_eq!(u128_snap.failed_batches, 0);
        assert!(!u128_snap.breaker_open);
        assert_eq!(svc.metrics().snapshot().total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn routed_worker_init_failure_names_the_backend() {
        use crate::runtime::executor::ScalarReferenceExecutor;
        use std::sync::atomic::AtomicU64;
        // probe succeeds, the pool worker's factory call fails: start
        // must fail and name the backend's worker
        let count = Arc::new(AtomicU64::new(0));
        let c2 = count.clone();
        let registry = ExecutorRegistry::new()
            .register(|| Ok(Box::new(NativeExecutor::with_defaults()) as _))
            .register(move || {
                if c2.fetch_add(1, Ordering::SeqCst) == 0 {
                    Ok(Box::new(ScalarReferenceExecutor::with_defaults()) as _)
                } else {
                    Err(anyhow::anyhow!("scalar pool refused to start"))
                }
            });
        let err = match FpuService::start_routed(quick_config(), registry) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("start must fail when a pool worker cannot build its executor"),
        };
        assert!(err.contains("fpu-scalar-reference"), "{err}");
        assert!(err.contains("refused to start"), "{err}");
    }

    #[test]
    fn worker_panic_is_contained_and_respawned() {
        // the tentpole's supervision contract on the real service: a
        // panicking executor fails its riders typed (never a poisoned
        // service or a hang), and the supervisor respawns the worker so
        // the service keeps serving
        struct PanicOnce(NativeExecutor, Arc<AtomicU64>);
        impl Executor for PanicOnce {
            fn capabilities(&self) -> BackendCaps {
                self.0.capabilities()
            }
            fn execute_into(
                &mut self,
                op: OpKind,
                format: FormatKind,
                a: PlaneRef<'_>,
                b: Option<PlaneRef<'_>>,
                out: PlaneRefMut<'_>,
            ) -> Result<()> {
                if self.1.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("injected worker panic");
                }
                self.0.execute_into(op, format, a, b, out)
            }
        }
        let calls = Arc::new(AtomicU64::new(0));
        let c2 = calls.clone();
        let svc = FpuService::start(quick_config(), move || {
            Ok(Box::new(PanicOnce(NativeExecutor::with_defaults(), c2.clone()))
                as Box<dyn Executor>)
        })
        .unwrap();
        let h = svc.handle();
        // first execution panics: contained, the rider sees a typed
        // error carrying the panic text (single backend, no failover
        // candidate)
        match h.divide(10.0, 4.0) {
            Err(ServiceError::ExecFailed { backend }) => {
                assert!(backend.contains("panicked"), "{backend}");
                assert!(backend.contains("injected worker panic"), "{backend}");
            }
            other => panic!("expected ExecFailed from the panicking worker, got {other:?}"),
        }
        // the supervisor respawns the worker (fresh executor, shared
        // counter now past the panic) and the service keeps serving
        let mut recovered = None;
        for _ in 0..50 {
            match h.divide(10.0, 4.0) {
                Ok(q) => {
                    recovered = Some(q);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
        assert_eq!(recovered, Some(2.5), "service recovers after the worker panic");
        let report = svc.dispatch_report();
        assert!(report[0].1.respawns >= 1, "supervisor recorded the respawn");
        assert!(!report[0].1.degraded, "a successful respawn leaves the pool undegraded");
        svc.shutdown();
    }

    #[test]
    fn durable_submission_round_trips_and_journals() {
        let path = std::env::temp_dir()
            .join(format!("goldschmidt-svc-journal-{}", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut cfg = quick_config();
        cfg.journal = Some(path.clone());
        let svc = FpuService::start(cfg, native).unwrap();
        assert_eq!(svc.replayed_jobs(), 0, "a fresh journal replays nothing");
        let a: Vec<u64> = [6.0f32, 9.0].iter().map(|v| v.to_bits() as u64).collect();
        let b: Vec<u64> = [2.0f32, 3.0].iter().map(|v| v.to_bits() as u64).collect();
        let id = svc.submit_batch_durable(OpKind::Divide, FormatKind::F32, &a, &b).unwrap();
        let mut done = None;
        for _ in 0..500 {
            match svc.poll_job(id) {
                Some(JobPoll::Done(bits)) => {
                    done = Some(bits);
                    break;
                }
                Some(JobPoll::Pending) => std::thread::sleep(Duration::from_millis(2)),
                other => panic!("unexpected durable poll outcome: {other:?}"),
            }
        }
        let bits = done.expect("durable job resolved to Done");
        let expect: Vec<u64> = [3.0f32, 3.0].iter().map(|v| v.to_bits() as u64).collect();
        assert_eq!(bits, expect);
        svc.shutdown();
        // on disk: the Pending record (with operands) then the Done
        // record (with the result plane), same id
        let (_journal, records) = Journal::open(&path).unwrap();
        let recs: Vec<_> = records.into_iter().filter(|r| r.id == id).collect();
        assert_eq!(recs.len(), 2, "one Pending + one Done record");
        assert_eq!(recs[0].status, JobStatus::Pending);
        assert_eq!(recs[0].a, a);
        assert_eq!(recs[1].status, JobStatus::Done);
        assert_eq!(recs[1].result, expect);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn durable_api_requires_a_journal() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let a = [6.0f32.to_bits() as u64];
        let b = [2.0f32.to_bits() as u64];
        match svc.submit_batch_durable(OpKind::Divide, FormatKind::F32, &a, &b) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("journal"), "{reason}");
            }
            other => panic!("expected Rejected without a journal, got {other:?}"),
        }
        assert_eq!(svc.poll_job(0), None);
        svc.shutdown();
    }

    #[test]
    fn shutdown_retires_through_remaining_candidates() {
        use crate::dispatch::RoutePolicy;
        // the shutdown retire loop must walk a failed batch down its
        // remaining candidate chain even with a zero time budget: a
        // retry already queued on the channel reaches backend b's pool
        // (the final drain), it is not dropped as Shutdown
        let caps_a = BackendCaps::uniform("retire-a", &[64]);
        let caps_b = BackendCaps::uniform("retire-b", &[64]);
        let table = RoutingTable::merge(vec![caps_a, caps_b]).unwrap();
        let batcher = DynamicBatcher::routed(
            BatcherConfig::new(64, Duration::from_micros(100)),
            table.caps_list(),
        );
        let health = Arc::new(HealthBoard::new(2));
        let mut plane = DispatchPlane::new(table, RoutePolicy::Static, health.clone());
        let metrics = Metrics::new();
        let plane_pool = PlanePool::new();
        // backend a's pool is empty (all workers gone); backend b has
        // one live slot whose receiver the test holds
        let shared_a = Arc::new(PoolShared { slots: Mutex::new(Vec::new()) });
        let (btx, brx) = mpsc::sync_channel::<Batch>(WORKER_QUEUE);
        let shared_b = Arc::new(PoolShared {
            slots: Mutex::new(vec![WorkerSlot { id: 0, tx: btx }]),
        });
        let mut pools = vec![
            PoolSender { shared: shared_a, next: 0 },
            PoolSender { shared: shared_b, next: 0 },
        ];
        // one formed batch that already failed on backend a
        let mut router = Router::new();
        metrics.record_enqueued(OpKind::Divide, FormatKind::F32, 1);
        let (item, ticket) = WorkItem::group(
            7,
            OpKind::Divide,
            FormatKind::F32,
            &[6.0f32.to_bits() as u64],
            &[2.0f32.to_bits() as u64],
            None,
        );
        router.route(item);
        let mut batch = batcher
            .form_batch_for(
                0,
                &mut router,
                OpKind::Divide,
                FormatKind::F32,
                Instant::now(),
                &plane_pool,
                &metrics,
            )
            .expect("batch forms");
        batch.backend = 0;
        batch.tried = 0b01;
        let (retry_tx, retry_rx) = mpsc::channel::<FailedBatch>();
        retry_tx
            .send(FailedBatch { batch, error: Some("backend a exploded".into()) })
            .unwrap();
        drop(retry_tx);
        let outstanding = AtomicI64::new(1);
        retire_outstanding(
            &retry_rx,
            Duration::ZERO,
            &mut plane,
            &mut pools,
            &batcher,
            &metrics,
            &plane_pool,
            &outstanding,
        );
        let got = brx.try_recv().expect("the retry failed over into backend b's pool");
        assert_eq!(got.backend, 1, "rerouted to the untried candidate");
        assert!(!ticket.is_done(), "the rider is still waiting on backend b, not failed");
    }

    #[test]
    fn sampled_requests_emit_tiled_stage_spans() {
        use crate::obs::TraceKind;
        let mut cfg = quick_config();
        cfg.trace = Some(TraceConfig { sample: 1, capacity: 4096 });
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let resp = h.submit(OpKind::Divide, 10.0, 4.0).unwrap().wait().unwrap();
        assert_eq!(resp.value.f32(), 2.5);
        let trace = svc.trace().expect("trace armed");
        svc.shutdown();
        let events = trace.events();
        let count = |k: TraceKind| events.iter().filter(|e| e.kind == k).count();
        assert!(count(TraceKind::Submit) >= 1, "submit instant present");
        assert!(count(TraceKind::Enqueue) >= 1, "enqueue instant present");
        assert!(count(TraceKind::BatchFormed) >= 1, "batch-formed instant present");
        assert!(count(TraceKind::Complete) >= 1, "complete instant present");
        // the four stage spans tile the rider-observed latency exactly
        let complete = events.iter().find(|e| e.kind == TraceKind::Complete).unwrap();
        let spans: Vec<_> =
            events.iter().filter(|e| e.id == complete.id && e.kind.is_span()).collect();
        assert_eq!(spans.len(), 4, "queue/batch/failover/exec, one each");
        let stage_sum: u64 = spans.iter().map(|e| e.dur_ns).sum();
        assert_eq!(stage_sum, complete.arg, "stage spans sum to the total");
        assert_eq!(trace.drops(), 0, "a roomy ring drops nothing");
    }

    #[test]
    fn unsampled_requests_trace_nothing() {
        let mut cfg = quick_config();
        // sample rate above any id issued here: no lifecycle events at
        // all, even though the plane is armed
        cfg.trace = Some(TraceConfig { sample: u64::MAX, capacity: 256 });
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        // id 0 is sampled by any rate (0 % n == 0); burn it first and
        // check only the later ids stay silent
        let _ = h.divide(1.0, 1.0).unwrap();
        let trace = svc.trace().expect("trace armed");
        let baseline = trace.events().len();
        for _ in 0..10 {
            assert_eq!(h.divide(9.0, 3.0).unwrap(), 3.0);
        }
        svc.shutdown();
        let events = trace.events();
        assert_eq!(events.len(), baseline, "unsampled requests emit no lifecycle events");
    }

    #[test]
    fn stats_emitter_thread_starts_and_stops() {
        let mut cfg = quick_config();
        cfg.stats_interval = Some(Duration::from_millis(5));
        cfg.trace = Some(TraceConfig::default());
        let svc = FpuService::start(cfg, native).unwrap();
        // a net source attached mid-flight shows up on later lines
        svc.attach_net_stats_source(|| NetPlaneStats {
            active_connections: 1,
            slow_client_drops: 0,
        });
        let h = svc.handle();
        assert_eq!(h.divide(9.0, 3.0).unwrap(), 3.0);
        std::thread::sleep(Duration::from_millis(20));
        // the property under test: shutdown joins the emitter promptly
        svc.shutdown();
    }

    #[test]
    fn shard_stats_report_every_shard() {
        let mut cfg = quick_config();
        cfg.shards = 2;
        let svc = FpuService::start(cfg, native).unwrap();
        assert!(svc.uptime_ns() > 0, "uptime epoch set at start");
        let h = svc.handle();
        for i in 1..=50u32 {
            assert_eq!(h.divide((2 * i) as f32, 2.0).unwrap(), i as f32);
        }
        let rows = svc.shard_stats();
        assert_eq!(rows.len(), 2, "one row per shard");
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.ring_capacity, 1024, "shard {i} reports the ring bound");
            assert_eq!(r.ring_full_rejects, 0, "shard {i}: nothing bounced");
        }
        // the service is quiescent: all gauges drained
        let after = svc.shard_stats();
        for (i, r) in after.iter().enumerate() {
            assert_eq!(r.ring_depth, 0, "shard {i} ring drained");
            assert_eq!(r.queued_lanes, 0, "shard {i} lanes drained");
            assert_eq!(r.ready_batches, 0, "shard {i} ready queue drained");
        }
        svc.shutdown();
    }

    #[test]
    fn injected_ring_full_counts_on_the_shard_row() {
        let mut cfg = quick_config();
        cfg.fault =
            Some(Arc::new(FaultPlan::parse("ring-full@shard0:after=0,count=1", 11).unwrap()));
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        // single shard: the first submit trips the injected full ring
        match h.submit(OpKind::Divide, 6.0, 2.0) {
            Err(ServiceError::Overloaded) => {}
            other => panic!("expected Overloaded, got {:?}", other.map(|t| t.id())),
        }
        assert_eq!(svc.shard_stats()[0].ring_full_rejects, 1, "the bounce is on the row");
        // the site's count window is spent: service serves normally
        assert_eq!(h.divide(6.0, 2.0).unwrap(), 3.0);
        assert_eq!(svc.shard_stats()[0].ring_full_rejects, 1);
        svc.shutdown();
    }
}
