//! The threaded FPU service: lifecycle, backpressure, dispatch loop and
//! worker pool. This is the event loop the paper's "divider unit as a
//! shared resource" maps onto: many clients, one (or a few) expensive
//! execution engines, a batching layer in between.
//!
//! Threading model (std threads + channels; no async runtime exists in
//! the offline environment, and none is needed):
//!
//! * clients hold a [`ServiceHandle`] and submit into a *bounded*
//!   channel — the backpressure boundary; a full queue pushes back on
//!   submitters (or returns [`ServiceError::Overloaded`] from the
//!   `try_submit` family) instead of growing without bound;
//! * one **dispatcher** thread owns the [`Router`] + [`DynamicBatcher`]
//!   + [`DispatchPlane`] and turns the work stream into batches —
//!   shedding expired-deadline items, selecting a backend per batch
//!   (policy + circuit breakers), and re-routing batches a backend
//!   fails so riders never see a single backend's death;
//! * each registered backend owns a **worker pool** of executor
//!   threads, each owning one [`Executor`] (one "divider unit" each),
//!   executing its backend's batches round-robin into a reused output
//!   plane and completing each item's ticket in place. Outcomes are
//!   recorded on the backend's [`HealthBoard`] slot, which is what the
//!   dispatcher routes by.
//!
//! Startup is fail-fast: every registered executor factory is probed
//! once on the caller thread (capability negotiation, merged into the
//! routing table), and every worker of every pool reports its own
//! factory result back before [`FpuService::start_routed`] returns — a
//! worker that cannot build its executor fails start instead of
//! silently eating a share of the traffic.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::dispatch::{
    BackendHealthSnapshot, DispatchPlane, ExecutorRegistry, HealthBoard, RoutingTable,
};
use crate::formats::{PlaneRefMut, PlaneWidth};
use crate::runtime::caps::BackendCaps;
use crate::runtime::executor::Executor;

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PlanePool};
use super::metrics::Metrics;
use super::request::{FormatKind, OpKind, ServiceError, Value, WorkItem};
use super::router::Router;
use super::ticket::{BatchTicket, Ticket};

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Batching policy (global knobs + per-(op, format) overrides).
    pub batcher: BatcherConfig,
    /// Bounded submit-queue depth (the backpressure knob).
    pub queue_depth: usize,
    /// Number of executor workers **per backend pool** (parallel
    /// "divider units"; a registry entry can override its own pool
    /// size).
    pub workers: usize,
    /// Dispatcher poll granularity when idle.
    pub poll: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            queue_depth: 16_384,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }
}

enum DispatchMsg {
    Req(WorkItem),
    Shutdown,
}

/// Client-side handle: cheap to clone, safe across threads. Every
/// submission returns a [`Ticket`] / [`BatchTicket`] backed by a shared
/// completion slot — no per-request channel — and every failure is a
/// typed [`ServiceError`].
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<DispatchMsg>,
    next_id: Arc<AtomicU64>,
    caps: Arc<BackendCaps>,
    metrics: Arc<Metrics>,
}

impl ServiceHandle {
    /// The backend's negotiated capability table (what this service can
    /// serve, per (op, format), and at which batch sizes).
    pub fn capabilities(&self) -> &BackendCaps {
        &self.caps
    }

    /// Deadline admission control: a deadline-carrying submission whose
    /// budget is already smaller than the queue-delay estimate for its
    /// (op, format) slot is rejected **at submit time** with
    /// [`ServiceError::Deadline`] — the work never enters the queue
    /// only to be shed at batch formation. The estimate is a
    /// queue-depth × service-rate model (lanes queued ahead times the
    /// slot's windowed executor cost per lane, see
    /// [`Metrics::queue_delay_estimate_ns`]): a burst moves it the
    /// moment the burst is queued, and a drained queue clears it
    /// instantly — no latency window to age out. Every N-th
    /// would-reject is still admitted anyway as a probe
    /// ([`Metrics::admission_probe`]), so a slot whose rate window went
    /// stale keeps resampling the service. With no rate signal yet (a
    /// cold service) everything is admitted and deadline enforcement
    /// falls to the batcher's shed path as before.
    fn admit_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        lanes: usize,
        deadline: Duration,
    ) -> Result<(), ServiceError> {
        if let Some(est_ns) = self.metrics.queue_delay_estimate_ns(op, format) {
            if Duration::from_nanos(est_ns) > deadline && !self.metrics.admission_probe(op, format)
            {
                self.metrics.record_admission_reject(op, format, lanes as u64);
                return Err(ServiceError::Deadline);
            }
        }
        Ok(())
    }

    fn check_supported(&self, op: OpKind, format: FormatKind) -> Result<(), ServiceError> {
        if self.caps.supports(op, format) {
            Ok(())
        } else {
            Err(ServiceError::Rejected {
                reason: format!(
                    "backend {} does not serve ({}, {format})",
                    self.caps.backend(),
                    op.label()
                ),
            })
        }
    }

    fn send(&self, item: WorkItem) -> Result<(), ServiceError> {
        // a failed send drops the item, which fails its ticket — but the
        // caller gets the error directly and never sees that ticket
        let (op, format, lanes) = (item.op, item.format(), item.lanes() as u64);
        self.tx.send(DispatchMsg::Req(item)).map_err(|_| ServiceError::Shutdown)?;
        // feed the admission model's queue-depth gauge the moment the
        // work is queued (batch formation discounts it)
        self.metrics.record_enqueued(op, format, lanes);
        Ok(())
    }

    /// Validation shared by the single-request submit family (cheap:
    /// two compares, no allocation — the admission reject path relies
    /// on that).
    fn check_single(&self, op: OpKind, a: Value, b: Value) -> Result<(), ServiceError> {
        if a.format() != b.format() {
            return Err(ServiceError::Rejected {
                reason: format!("operand format mismatch: {} vs {}", a.format(), b.format()),
            });
        }
        self.check_supported(op, a.format())
    }

    fn make_single(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Option<Duration>,
    ) -> Result<(WorkItem, Ticket), ServiceError> {
        self.check_single(op, a, b)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(WorkItem::single(id, op, a, b, deadline.map(|d| Instant::now() + d)))
    }

    /// Submit one op on format-tagged operands; returns the [`Ticket`]
    /// resolving it. Blocks while the submit queue is full
    /// (backpressure). Both operands must share a format (pass
    /// `Value::one(format)` as `b` for unary ops).
    pub fn submit_value(&self, op: OpKind, a: Value, b: Value) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        self.send(item)?;
        Ok(ticket)
    }

    /// [`Self::submit_value`] with a completion deadline. Admission
    /// control runs first: when the queue-delay estimate already
    /// exceeds `deadline`, the submission fails immediately with
    /// [`ServiceError::Deadline`]. Once admitted, a request still
    /// queued when the deadline arrives is shed by the dispatcher
    /// (counted in metrics) and the ticket resolves to
    /// [`ServiceError::Deadline`] instead of executing stale work.
    pub fn submit_value_deadline(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
        deadline: Duration,
    ) -> Result<Ticket, ServiceError> {
        // validate first (a malformed submission is Rejected with its
        // reason, never misreported as a Deadline admission miss), and
        // only construct once admitted — the overload reject path
        // allocates nothing
        self.check_single(op, a, b)?;
        self.admit_deadline(op, a.format(), 1, deadline)?;
        let (item, ticket) = self.make_single(op, a, b, Some(deadline))?;
        self.send(item)?;
        Ok(ticket)
    }

    /// Submit one f32 op (the single-precision convenience path).
    pub fn submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.submit_value(op, Value::F32(a), Value::F32(b))
    }

    /// Non-blocking submit of format-tagged operands:
    /// [`ServiceError::Overloaded`] when the queue is full.
    pub fn try_submit_value(
        &self,
        op: OpKind,
        a: Value,
        b: Value,
    ) -> Result<Ticket, ServiceError> {
        let (item, ticket) = self.make_single(op, a, b, None)?;
        let format = item.format();
        match self.tx.try_send(DispatchMsg::Req(item)) {
            Ok(()) => {
                self.metrics.record_enqueued(op, format, 1);
                Ok(ticket)
            }
            Err(TrySendError::Full(_)) => Err(ServiceError::Overloaded),
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::Shutdown),
        }
    }

    /// Non-blocking f32 submit: [`ServiceError::Overloaded`] when full.
    pub fn try_submit(&self, op: OpKind, a: f32, b: f32) -> Result<Ticket, ServiceError> {
        self.try_submit_value(op, Value::F32(a), Value::F32(b))
    }

    fn check_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<(), ServiceError> {
        if a.is_empty() {
            return Err(ServiceError::Rejected { reason: "empty batch".into() });
        }
        match op {
            OpKind::Divide if b.len() != a.len() => {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "divide needs matching operand planes ({} vs {})",
                        a.len(),
                        b.len()
                    ),
                });
            }
            OpKind::Sqrt | OpKind::Rsqrt if !b.is_empty() => {
                return Err(ServiceError::Rejected {
                    reason: format!("{} takes one operand plane", op.label()),
                });
            }
            _ => {}
        }
        // raw words must fit the format's container: the queue stores
        // planes width-true, so an oversized word would otherwise be a
        // debug panic / silent release truncation instead of a typed
        // rejection of bad client input
        if format.total_bits() < 64 {
            let mask = !((1u64 << format.total_bits()) - 1);
            if let Some(bad) = a.iter().chain(b.iter()).find(|&&w| w & mask != 0) {
                return Err(ServiceError::Rejected {
                    reason: format!(
                        "operand word {bad:#x} does not fit a {}-bit {format} container",
                        format.total_bits()
                    ),
                });
            }
        }
        self.check_supported(op, format)
    }

    /// Callers have already run [`Self::check_batch`].
    fn submit_batch_inner(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Option<Duration>,
    ) -> Result<BatchTicket, ServiceError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (item, ticket) =
            WorkItem::group(id, op, format, a, b, deadline.map(|d| Instant::now() + d));
        self.send(item)?;
        Ok(ticket)
    }

    /// Vectored submission: a whole operand plane (raw `format` words)
    /// as **one** queue entry with **one** completion slot. The group
    /// enters the router pre-formed — batch locality is preserved, not
    /// re-discovered — and is split only at executable-ladder
    /// boundaries. `b` is the divisor plane for divide (same length as
    /// `a`) and must be empty for unary ops.
    pub fn submit_batch(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<BatchTicket, ServiceError> {
        self.check_batch(op, format, a, b)?;
        self.submit_batch_inner(op, format, a, b, None)
    }

    /// [`Self::submit_batch`] with a completion deadline covering the
    /// whole group. Admission control applies as in
    /// [`Self::submit_value_deadline`]: a budget the queue-delay
    /// estimate already exceeds is rejected here, before any queueing.
    pub fn submit_batch_deadline(
        &self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        deadline: Duration,
    ) -> Result<BatchTicket, ServiceError> {
        // validation precedes admission (see submit_value_deadline)
        self.check_batch(op, format, a, b)?;
        self.admit_deadline(op, format, a.len(), deadline)?;
        self.submit_batch_inner(op, format, a, b, Some(deadline))
    }

    /// Convenience: blocking round-trip divide (f32).
    pub fn divide(&self, n: f32, d: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Divide, n, d)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip sqrt (f32).
    pub fn sqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Sqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip rsqrt (f32).
    pub fn rsqrt(&self, x: f32) -> Result<f32, ServiceError> {
        Ok(self.submit(OpKind::Rsqrt, x, 1.0)?.wait()?.value.f32())
    }

    /// Convenience: blocking round-trip divide in any format (operands
    /// encoded from f64 with round-to-nearest-even, result decoded
    /// exactly).
    pub fn divide_in(&self, format: FormatKind, n: f64, d: f64) -> Result<f64, ServiceError> {
        let t = self.submit_value(
            OpKind::Divide,
            Value::from_f64(format, n),
            Value::from_f64(format, d),
        )?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip sqrt in any format.
    pub fn sqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Sqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }

    /// Convenience: blocking round-trip rsqrt in any format.
    pub fn rsqrt_in(&self, format: FormatKind, x: f64) -> Result<f64, ServiceError> {
        let t =
            self.submit_value(OpKind::Rsqrt, Value::from_f64(format, x), Value::one(format))?;
        Ok(t.wait()?.value.to_f64())
    }
}

/// The running service.
pub struct FpuService {
    handle: ServiceHandle,
    metrics: Arc<Metrics>,
    health: Arc<HealthBoard>,
    backend_names: Vec<&'static str>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shutdown_tx: SyncSender<DispatchMsg>,
}

/// A batch a worker could not execute, handed back to the dispatcher
/// for re-routing (the failure is already on the backend's breaker).
struct FailedBatch {
    batch: Batch,
    error: String,
}

/// One backend's worker pool: the batch channels of its live workers.
struct PoolSender {
    txs: Vec<SyncSender<Batch>>,
    next: usize,
}

impl PoolSender {
    /// Round-robin one batch into the pool, dropping dead workers'
    /// channels. `Err` returns the batch when the whole pool is gone.
    fn send(&mut self, mut batch: Batch) -> std::result::Result<(), Batch> {
        while !self.txs.is_empty() {
            let i = self.next % self.txs.len();
            self.next += 1;
            // round-robin; a full worker queue applies backpressure here
            match self.txs[i].send(batch) {
                Ok(()) => return Ok(()),
                Err(mpsc::SendError(returned)) => {
                    batch = returned;
                    self.txs.remove(i); // dead worker: never pick it again
                }
            }
        }
        Err(batch)
    }
}

/// How long the dispatcher keeps servicing the retry channel at
/// shutdown while batches are still in flight (a failsafe bound — the
/// normal case drains in microseconds).
const SHUTDOWN_RETIRE_BUDGET: Duration = Duration::from_secs(5);

impl FpuService {
    /// Start a single-backend service. `make_executor` is called once
    /// on the caller thread (capability negotiation: the probe's
    /// [`BackendCaps`] are kept for the life of the service) and once
    /// *inside each worker thread* — executors are not `Send` (the PJRT
    /// client wraps thread-local FFI state), so each worker owns an
    /// executor it built itself: one "divider unit" per worker. Any
    /// worker whose factory fails makes `start` return that error — no
    /// silently dead workers.
    ///
    /// This is sugar for [`Self::start_routed`] with a one-entry
    /// registry: a single backend routes trivially.
    pub fn start<F>(config: ServiceConfig, make_executor: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        Self::start_routed(config, ExecutorRegistry::new().register(make_executor))
    }

    /// Start a routed service over every backend in the registry.
    ///
    /// Each registered factory is probed once on the caller thread; the
    /// probed capability tables are merged into a [`RoutingTable`]
    /// (candidate lists per (op, format) + the union table the client
    /// handle admits against), and each backend gets its **own worker
    /// pool** (`config.workers` threads, or the registry entry's
    /// override), its own batch shapes (ladders + plane widths) and its
    /// own health tracking. The dispatcher selects a backend per formed
    /// batch (registry policy: static preference or measured latency),
    /// routes around open circuit breakers, probes broken backends back
    /// to life, and re-routes failed batches down the candidate chain
    /// so riders only ever see an error when every candidate failed.
    pub fn start_routed(config: ServiceConfig, registry: ExecutorRegistry) -> Result<Self> {
        assert!(config.workers >= 1, "need at least one worker");
        let (entries, policy) = registry.into_parts();
        if entries.is_empty() {
            bail!("dispatch registry has no backends");
        }
        if entries.len() > 8 {
            bail!("at most 8 backends per service (the retry mask is a u8)");
        }
        let metrics = Arc::new(Metrics::new());
        let pool = PlanePool::new();
        let (tx, rx) = mpsc::sync_channel::<DispatchMsg>(config.queue_depth);

        // probe every backend once: validates each factory and
        // negotiates its capability table (support + ladders + widths)
        let mut caps_list = Vec::with_capacity(entries.len());
        for (i, entry) in entries.iter().enumerate() {
            let probe = entry
                .make()
                .with_context(|| format!("probing backend #{i} capabilities"))?;
            caps_list.push(probe.capabilities());
        }
        let table = RoutingTable::merge(caps_list)?;
        let names = table.names();
        let union = Arc::new(table.union().clone());
        let batcher = DynamicBatcher::routed(config.batcher, table.caps_list());
        let health = Arc::new(HealthBoard::new(table.backend_count()));
        let outstanding = Arc::new(AtomicI64::new(0));
        let (retry_tx, retry_rx) = mpsc::channel::<FailedBatch>();

        // per-backend worker pools: the dispatcher round-robins a
        // backend's batches across that backend's own channels
        let (init_tx, init_rx) = mpsc::channel::<(String, std::result::Result<(), String>)>();
        let mut pools = Vec::with_capacity(entries.len());
        let mut workers = Vec::new();
        let mut total_workers = 0usize;
        for (b, entry) in entries.iter().enumerate() {
            let pool_workers = entry.workers().unwrap_or(config.workers).max(1);
            let mut txs = Vec::with_capacity(pool_workers);
            for w in 0..pool_workers {
                total_workers += 1;
                let (btx, brx) = mpsc::sync_channel::<Batch>(4);
                txs.push(btx);
                let metrics = metrics.clone();
                let pool = pool.clone();
                let health = health.clone();
                let retry_tx = retry_tx.clone();
                let outstanding = outstanding.clone();
                let factory = entry.factory();
                let init_tx = init_tx.clone();
                let wname = format!("fpu-{}-{w}", names[b]);
                workers.push(
                    std::thread::Builder::new()
                        .name(wname.clone())
                        .spawn(move || match factory() {
                            Ok(executor) => {
                                let _ = init_tx.send((wname, Ok(())));
                                drop(init_tx);
                                worker_loop(
                                    brx,
                                    executor,
                                    b,
                                    metrics,
                                    health,
                                    pool,
                                    retry_tx,
                                    outstanding,
                                );
                            }
                            Err(e) => {
                                let _ = init_tx.send((wname, Err(format!("{e:#}"))));
                            }
                        })
                        .expect("spawn worker"),
                );
            }
            pools.push(PoolSender { txs, next: 0 });
        }
        drop(init_tx);
        drop(retry_tx); // workers hold the only retry senders

        // fail-fast: every worker reports its init before we go live
        for _ in 0..total_workers {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((wname, Err(msg))) => {
                    drop(pools); // close channels -> live workers exit
                    for h in workers {
                        let _ = h.join();
                    }
                    bail!("{wname}: executor init failed: {msg}");
                }
                Err(_) => {
                    drop(pools);
                    for h in workers {
                        let _ = h.join();
                    }
                    bail!("a worker exited before reporting executor init");
                }
            }
        }

        let dispatcher = {
            let metrics = metrics.clone();
            let pool = pool.clone();
            let plane = DispatchPlane::new(table, policy, health.clone());
            let outstanding = outstanding.clone();
            std::thread::Builder::new()
                .name("fpu-dispatcher".into())
                .spawn(move || {
                    dispatcher_loop(
                        rx,
                        retry_rx,
                        batcher,
                        plane,
                        pools,
                        config.poll,
                        metrics,
                        pool,
                        outstanding,
                    )
                })
                .expect("spawn dispatcher")
        };

        let handle = ServiceHandle {
            tx: tx.clone(),
            next_id: Arc::new(AtomicU64::new(0)),
            caps: union,
            metrics: metrics.clone(),
        };
        Ok(Self {
            handle,
            metrics,
            health,
            backend_names: names,
            dispatcher: Some(dispatcher),
            workers,
            shutdown_tx: tx,
        })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The negotiated capability table (for a routed service: the
    /// union of every registered backend's).
    pub fn capabilities(&self) -> &BackendCaps {
        self.handle.capabilities()
    }

    /// Registered backend names, routing-preference order.
    pub fn backend_names(&self) -> &[&'static str] {
        &self.backend_names
    }

    /// Per-backend dispatch health and traffic counters, registration
    /// order: (name, snapshot).
    pub fn dispatch_report(&self) -> Vec<(&'static str, BackendHealthSnapshot)> {
        self.backend_names.iter().copied().zip(self.health.snapshot()).collect()
    }

    /// Graceful shutdown: drains queued work, joins all threads.
    pub fn shutdown(mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for FpuService {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fail every rider of a batch with a typed error and recycle its
/// planes (the terminal outcome of the retry chain).
fn fail_batch(
    mut batch: Batch,
    err: ServiceError,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    outstanding.fetch_sub(1, Ordering::AcqRel);
    metrics.record_error(batch.op, batch.format, batch.live() as u64);
    for item in batch.items.drain(..) {
        item.fail(err.clone());
    }
    plane_pool.give(std::mem::take(&mut batch.a));
    plane_pool.give(std::mem::take(&mut batch.b));
}

/// Re-shape a batch for a different backend: planes are rebuilt at the
/// new backend's negotiated width and re-padded to its ladder. The
/// common case (same width, same padded size — e.g. failover between
/// backends sharing the default ladder) is a no-op; the lane-copy slow
/// path only runs on the rare cross-shape retry.
fn reshape_for_backend(
    batch: &mut Batch,
    backend: usize,
    batcher: &DynamicBatcher,
    plane_pool: &PlanePool,
) {
    let width = batcher.plane_width_for(backend, batch.format);
    let live = batch.live();
    // never below `live`: a failover target whose largest ladder rung
    // is smaller than this batch must still receive every lane (an
    // off-ladder size is at worst a typed executor error that continues
    // the retry chain; a truncated plane would drop riders' lanes and
    // panic the completion loop)
    let padded = batcher.padded_for(backend, batch.op, batch.format, live).max(live);
    if width == batch.a.width() && padded == batch.padded {
        return;
    }
    let one = batch.format.one_bits();
    let mut a = plane_pool.take(width);
    a.reserve(padded);
    for i in 0..live {
        a.push(batch.a.get(i));
    }
    a.resize(padded, one);
    plane_pool.give(std::mem::replace(&mut batch.a, a));
    if batch.op == OpKind::Divide {
        let mut b = plane_pool.take(width);
        b.reserve(padded);
        for i in 0..live {
            b.push(batch.b.get(i));
        }
        b.resize(padded, one);
        plane_pool.give(std::mem::replace(&mut batch.b, b));
    }
    batch.padded = padded;
}

/// Hand one batch to `backend`'s pool; if that pool's workers are all
/// gone, walk the retry chain to the next untried candidate (reshaping
/// the batch). When every candidate pool is gone the riders fail with
/// the execution error that started the retry (`exec_error`, if this
/// batch already failed somewhere) — [`ServiceError::Shutdown`] is
/// reserved for a batch that never reached any executor.
#[allow(clippy::too_many_arguments)]
fn send_batch(
    mut batch: Batch,
    mut backend: usize,
    exec_error: Option<String>,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    batcher: &DynamicBatcher,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    loop {
        batch.backend = backend;
        batch.tried |= 1u8 << backend;
        match pools[backend].send(batch) {
            Ok(()) => return,
            Err(returned) => {
                batch = returned;
                match plane.select_excluding(batch.op, batch.format, batch.tried) {
                    Some(sel) => {
                        reshape_for_backend(&mut batch, sel.backend, batcher, plane_pool);
                        backend = sel.backend;
                    }
                    None => {
                        let err = match exec_error {
                            Some(backend_msg) => {
                                ServiceError::ExecFailed { backend: backend_msg }
                            }
                            None => ServiceError::Shutdown,
                        };
                        fail_batch(batch, err, metrics, plane_pool, outstanding);
                        return;
                    }
                }
            }
        }
    }
}

/// Re-route a batch a worker failed: the next untried candidate gets a
/// reshaped copy of the same lanes (rider-invisible failover); with no
/// candidate left, every rider gets the backend's error, typed.
fn reroute_failed(
    failed: FailedBatch,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    batcher: &DynamicBatcher,
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    let FailedBatch { mut batch, error } = failed;
    match plane.select_excluding(batch.op, batch.format, batch.tried) {
        Some(sel) => {
            plane.health().record_reroute(batch.backend);
            reshape_for_backend(&mut batch, sel.backend, batcher, plane_pool);
            send_batch(
                batch,
                sel.backend,
                Some(error),
                plane,
                pools,
                batcher,
                metrics,
                plane_pool,
                outstanding,
            );
        }
        None => {
            fail_batch(
                batch,
                ServiceError::ExecFailed { backend: error },
                metrics,
                plane_pool,
                outstanding,
            );
        }
    }
}

/// Form batches for every queue that should flush (`flush` = drain
/// unconditionally) and dispatch each to the backend the plane
/// selects.
#[allow(clippy::too_many_arguments)]
fn form_and_dispatch(
    flush: bool,
    router: &mut Router,
    batcher: &DynamicBatcher,
    plane: &mut DispatchPlane,
    pools: &mut [PoolSender],
    metrics: &Metrics,
    plane_pool: &PlanePool,
    outstanding: &AtomicI64,
) {
    let now = Instant::now();
    for &op in &OpKind::ALL {
        for &format in &FormatKind::ALL {
            loop {
                if router.len(op, format) == 0 {
                    break;
                }
                let Some(peek) = plane.peek_candidate(op, format) else {
                    // unreachable through the handle (union-caps checked
                    // at submit), but a direct router feed must not
                    // wedge: fail the queue typed
                    for item in router.drain(op, format, usize::MAX) {
                        metrics.record_dequeued(op, format, item.lanes() as u64);
                        metrics.record_error(op, format, item.lanes() as u64);
                        item.fail(ServiceError::Rejected {
                            reason: format!("no backend serves ({}, {format})", op.label()),
                        });
                    }
                    break;
                };
                // the flush decision peeks a candidate's shape without
                // consuming probe/exploration state; only a batch that
                // actually forms pays a select()
                if !flush && !batcher.should_flush_for(peek, router, op, format, now) {
                    break;
                }
                let sel = plane.select(op, format).expect("peeked candidate exists");
                match batcher
                    .form_batch_for(sel.backend, router, op, format, now, plane_pool, metrics)
                {
                    Some(batch) => {
                        // counted outstanding from send to terminal
                        // outcome (success, final failure, or shutdown)
                        outstanding.fetch_add(1, Ordering::AcqRel);
                        send_batch(
                            batch,
                            sel.backend,
                            None,
                            plane,
                            pools,
                            batcher,
                            metrics,
                            plane_pool,
                            outstanding,
                        );
                    }
                    None => {
                        if router.len(op, format) == 0 {
                            break; // everything drained was shed
                        }
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    retry_rx: Receiver<FailedBatch>,
    batcher: DynamicBatcher,
    mut plane: DispatchPlane,
    mut pools: Vec<PoolSender>,
    poll: Duration,
    metrics: Arc<Metrics>,
    plane_pool: PlanePool,
    outstanding: Arc<AtomicI64>,
) {
    let mut router = Router::new();
    'outer: loop {
        // block for the first message (bounded by the poll tick) ...
        match rx.recv_timeout(poll) {
            Ok(DispatchMsg::Req(req)) => router.route(req),
            Ok(DispatchMsg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // ... then greedily drain the backlog so the batcher sees the
        // whole burst at once (otherwise a stale-age flush would emit
        // singleton batches while the queue still holds work)
        loop {
            match rx.try_recv() {
                Ok(DispatchMsg::Req(req)) => router.route(req),
                Ok(DispatchMsg::Shutdown) => break 'outer,
                Err(_) => break,
            }
        }
        // failed batches re-route before new work dispatches: their
        // riders have waited longest
        while let Ok(failed) = retry_rx.try_recv() {
            reroute_failed(
                failed,
                &mut plane,
                &mut pools,
                &batcher,
                &metrics,
                &plane_pool,
                &outstanding,
            );
        }
        form_and_dispatch(
            false,
            &mut router,
            &batcher,
            &mut plane,
            &mut pools,
            &metrics,
            &plane_pool,
            &outstanding,
        );
    }
    // drain everything left
    while let Ok(DispatchMsg::Req(req)) = rx.try_recv() {
        router.route(req);
    }
    form_and_dispatch(
        true,
        &mut router,
        &batcher,
        &mut plane,
        &mut pools,
        &metrics,
        &plane_pool,
        &outstanding,
    );
    // retire in-flight batches before closing the pools: keep serving
    // the retry chain until every dispatched batch reached a terminal
    // outcome, so a backend dying during shutdown still fails over
    // instead of stranding riders
    let give_up = Instant::now() + SHUTDOWN_RETIRE_BUDGET;
    while outstanding.load(Ordering::Acquire) > 0 && Instant::now() < give_up {
        match retry_rx.recv_timeout(Duration::from_millis(1)) {
            Ok(failed) => reroute_failed(
                failed,
                &mut plane,
                &mut pools,
                &batcher,
                &metrics,
                &plane_pool,
                &outstanding,
            ),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // dropping batch senders closes worker channels -> workers exit
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: Receiver<Batch>,
    mut executor: Box<dyn Executor>,
    backend: usize,
    metrics: Arc<Metrics>,
    health: Arc<HealthBoard>,
    pool: PlanePool,
    retry_tx: mpsc::Sender<FailedBatch>,
    outstanding: Arc<AtomicI64>,
) {
    // all buffers persist across batches: the steady-state hot path
    // performs no allocation in this loop (execute_into writes in place
    // at the batch's plane width, operand planes go back to the pool).
    // One output buffer per width; `widened` is the u64 view the ticket
    // boundary needs for u32 batches.
    let mut out32: Vec<u32> = Vec::new();
    let mut out64: Vec<u64> = Vec::new();
    let mut widened: Vec<u64> = Vec::new();
    let mut lat: Vec<(u64, usize)> = Vec::new();
    while let Ok(mut batch) = rx.recv() {
        let width = batch.a.width();
        let b_plane = if batch.op == OpKind::Divide { Some(batch.b.as_ref()) } else { None };
        let t0 = Instant::now();
        let result = match width {
            PlaneWidth::W32 => {
                out32.clear();
                out32.resize(batch.padded, 0);
                executor.execute_into(
                    batch.op,
                    batch.format,
                    batch.a.as_ref(),
                    b_plane,
                    PlaneRefMut::W32(&mut out32),
                )
            }
            PlaneWidth::W64 => {
                out64.clear();
                out64.resize(batch.padded, 0);
                executor.execute_into(
                    batch.op,
                    batch.format,
                    batch.a.as_ref(),
                    b_plane,
                    PlaneRefMut::W64(&mut out64),
                )
            }
        };
        let exec_ns = t0.elapsed().as_nanos() as u64;
        match result {
            Ok(()) => {
                let live = batch.live() as u64;
                health.record_success(backend, batch.op, batch.format, live, exec_ns);
                let done = Instant::now();
                lat.clear();
                for item in &batch.items {
                    lat.push((
                        done.duration_since(item.enqueued_at).as_nanos() as u64,
                        item.lanes(),
                    ));
                }
                // record metrics BEFORE completing: once a client observes
                // its response, the snapshot already includes it
                metrics.record_batch(batch.op, batch.format, &lat, exec_ns, batch.padded);
                // tickets store u64 result words: widen u32 result
                // planes once per batch (the one narrowing boundary)
                let view: &[u64] = match width {
                    PlaneWidth::W32 => {
                        widened.clear();
                        widened.extend(out32.iter().map(|&w| w as u64));
                        &widened
                    }
                    PlaneWidth::W64 => &out64,
                };
                let mut off = 0usize;
                for (k, item) in batch.items.drain(..).enumerate() {
                    let lanes = item.lanes();
                    item.complete(&view[off..off + lanes], lat[k].0, batch.padded);
                    off += lanes;
                }
                outstanding.fetch_sub(1, Ordering::AcqRel);
                pool.give(std::mem::take(&mut batch.a));
                pool.give(std::mem::take(&mut batch.b));
            }
            Err(e) => {
                // hand the batch (planes intact) back to the dispatcher
                // for re-routing; the riders only see an error if every
                // candidate backend fails it
                health.record_failure(backend);
                let error = format!("{e:#}");
                if let Err(mpsc::SendError(failed)) = retry_tx.send(FailedBatch { batch, error }) {
                    // dispatcher already gone (teardown): fail typed
                    let FailedBatch { mut batch, error } = failed;
                    metrics.record_error(batch.op, batch.format, batch.live() as u64);
                    for item in batch.items.drain(..) {
                        item.fail(ServiceError::ExecFailed { backend: error.clone() });
                    }
                    outstanding.fetch_sub(1, Ordering::AcqRel);
                    pool.give(std::mem::take(&mut batch.a));
                    pool.give(std::mem::take(&mut batch.b));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::PlaneRef;
    use crate::runtime::executor::NativeExecutor;

    fn quick_config() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig::new(64, Duration::from_micros(100)),
            queue_depth: 1024,
            workers: 1,
            poll: Duration::from_micros(50),
        }
    }

    fn native() -> Result<Box<dyn Executor>> {
        Ok(Box::new(NativeExecutor::with_defaults()))
    }

    #[test]
    fn round_trip_divide() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(10.0, 4.0).unwrap(), 2.5);
        assert_eq!(h.sqrt(81.0).unwrap(), 9.0);
        assert_eq!(h.rsqrt(4.0).unwrap(), 0.5);
        svc.shutdown();
    }

    #[test]
    fn round_trip_every_format() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
            // the response carries the request's format tag
            let t = h
                .submit_value(
                    OpKind::Divide,
                    Value::from_f64(format, 6.0),
                    Value::from_f64(format, 2.0),
                )
                .unwrap();
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.format(), format);
            assert_eq!(resp.value.to_f64(), 3.0);
        }
        let snap = svc.metrics().snapshot();
        for format in FormatKind::ALL {
            assert!(snap.op_format(OpKind::Divide, format).requests >= 2, "{format}");
        }
        svc.shutdown();
    }

    #[test]
    fn mixed_format_operands_rejected() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_value(OpKind::Divide, Value::F32(1.0), Value::F64(2.0)) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("format mismatch"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        svc.shutdown();
    }

    #[test]
    fn capabilities_visible_on_handle() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let caps = svc.handle().capabilities().clone();
        assert_eq!(caps.backend(), "native-fixed-point");
        assert!(caps.supports(OpKind::Divide, FormatKind::BF16));
        assert_eq!(caps.ladder(OpKind::Divide, FormatKind::F32), &[64, 256, 1024]);
        assert_eq!(svc.capabilities().backend(), "native-fixed-point");
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for i in 1..50u32 {
                    let n = (t * 100 + i) as f32;
                    let q = h.divide(n * 3.0, 3.0).unwrap();
                    assert_eq!(q, n);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op(OpKind::Divide).requests, 8 * 49);
        assert_eq!(snap.total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn batches_actually_form() {
        // long wait + many pipelined submissions => multi-request batches
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_millis(5));
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..200).map(|i| h.submit(OpKind::Divide, i as f32, 1.0).unwrap()).collect();
        let mut max_batch = 0usize;
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().unwrap();
            assert_eq!(resp.value.f32(), i as f32);
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_round_trip() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let n: Vec<u64> = (1..=100u32).map(|i| ((3 * i) as f32).to_bits() as u64).collect();
        let d: Vec<u64> = (1..=100u32).map(|_| 3.0f32.to_bits() as u64).collect();
        let ticket = h.submit_batch(OpKind::Divide, FormatKind::F32, &n, &d).unwrap();
        assert_eq!(ticket.lanes(), 100);
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.len(), 100);
        for (i, v) in resp.values().enumerate() {
            assert_eq!(v.f32(), (i + 1) as f32, "lane {i}");
        }
        // unary vectored path
        let x: Vec<u64> = [4.0f32, 9.0, 16.0].iter().map(|v| v.to_bits() as u64).collect();
        let resp = h.submit_batch(OpKind::Sqrt, FormatKind::F32, &x, &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 3);
        assert_eq!(resp.value(0).f32(), 2.0);
        assert_eq!(resp.value(2).f32(), 4.0);
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_validates_arity() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        let a = [1.0f32.to_bits() as u64];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::F32, &a, &[]),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &a, &a),
            Err(ServiceError::Rejected { .. })
        ));
        assert!(matches!(
            h.submit_batch(OpKind::Sqrt, FormatKind::F32, &[], &[]),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }

    #[test]
    fn vectored_submission_rejects_oversized_words() {
        // a raw word that does not fit the format's container is a
        // typed Rejected, not a narrowing panic or silent truncation
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        match h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x1_0000], &[]) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("does not fit"), "{reason}");
            }
            other => panic!("expected Rejected, got {:?}", other.map(|t| t.id())),
        }
        // the divisor plane is checked too
        let ok = [0x3C00u64, 0x4000];
        let bad = [0x3C00u64, u64::MAX];
        assert!(matches!(
            h.submit_batch(OpKind::Divide, FormatKind::BF16, &ok, &bad),
            Err(ServiceError::Rejected { .. })
        ));
        // in-range f16 words and full-width f64 words pass
        let resp =
            h.submit_batch(OpKind::Sqrt, FormatKind::F16, &[0x4400], &[]).unwrap().wait().unwrap();
        assert_eq!(resp.bits.len(), 1);
        let w = (-2.0f64).to_bits(); // high bit set: fine for a 64-bit container
        assert!(h.submit_batch(OpKind::Sqrt, FormatKind::F64, &[w], &[]).is_ok());
        svc.shutdown();
    }

    #[test]
    fn deadline_admission_rejects_at_submit() {
        // the ROADMAP admission-control item, v2: a queue-depth x
        // service-rate model. Once (queued lanes) x (windowed executor
        // cost per lane) exceeds a submission's budget, the submission
        // fails with Deadline at submit time — before any queueing
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        // a cold service has no rate signal: even a tiny budget is
        // admitted
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(6.0),
                Value::F32(2.0),
                Duration::from_secs(5),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 3.0);
        // seed the rate window: ~1ms of executor time per lane on
        // (divide, f32)
        for _ in 0..8 {
            svc.metrics().record_batch(
                OpKind::Divide,
                FormatKind::F32,
                &[(10_000_000, 1)],
                1_000_000,
                1,
            );
        }
        // ... and a standing backlog of 200 lanes: the model predicts
        // ~200ms of queue delay (the gauge is what the router's lane
        // counts feed in production; the test feeds it directly)
        svc.metrics().record_enqueued(OpKind::Divide, FormatKind::F32, 200);
        // a 50us budget is now hopeless: rejected at submit, typed
        match h.submit_value_deadline(
            OpKind::Divide,
            Value::F32(6.0),
            Value::F32(2.0),
            Duration::from_micros(50),
        ) {
            Err(ServiceError::Deadline) => {}
            other => panic!("expected Deadline at submit, got {:?}", other.map(|t| t.id())),
        }
        // the vectored path is gated the same way, counting every lane
        let a: Vec<u64> = vec![2.0f32.to_bits() as u64; 10];
        assert!(matches!(
            h.submit_batch_deadline(
                OpKind::Divide,
                FormatKind::F32,
                &a,
                &a,
                Duration::from_micros(50)
            ),
            Err(ServiceError::Deadline)
        ));
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op_format(OpKind::Divide, FormatKind::F32).admission_rejected, 11);
        assert_eq!(snap.total_shed(), 0, "admission rejects are not queue sheds");
        // clearing the backlog re-opens admission instantly — the depth
        // model needs no latency window to decay. (The request may
        // still shed *in the queue* on a slow run; the property under
        // test is that submit no longer rejects.)
        svc.metrics().record_dequeued(OpKind::Divide, FormatKind::F32, 200);
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(8.0),
                Value::F32(2.0),
                Duration::from_micros(50),
            )
            .expect("empty queue admits any budget");
        let _ = t.wait();
        // and a generous budget completes end to end
        let t = h
            .submit_value_deadline(
                OpKind::Divide,
                Value::F32(8.0),
                Value::F32(2.0),
                Duration::from_secs(30),
            )
            .unwrap();
        assert_eq!(t.wait().unwrap().value.f32(), 4.0);
        // other (op, format) slots are unaffected by this slot's history
        svc.metrics().record_enqueued(OpKind::Divide, FormatKind::F32, 200);
        let t = h
            .submit_value_deadline(
                OpKind::Sqrt,
                Value::F32(9.0),
                Value::F32(1.0),
                Duration::from_micros(50),
            )
            .unwrap();
        let _ = t.wait(); // may complete or shed; must not reject at submit
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut cfg = quick_config();
        cfg.batcher = BatcherConfig::new(64, Duration::from_secs(10)); // only drain flushes
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (0..10).map(|i| h.submit(OpKind::Sqrt, (i * i) as f32, 1.0).unwrap()).collect();
        svc.shutdown(); // must flush the waiting batch
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), i as f32);
        }
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let svc = FpuService::start(quick_config(), native).unwrap();
        let h = svc.handle();
        svc.shutdown();
        assert_eq!(h.divide(1.0, 1.0).unwrap_err(), ServiceError::Shutdown);
    }

    #[test]
    fn multiple_workers() {
        let mut cfg = quick_config();
        cfg.workers = 4;
        let svc = FpuService::start(cfg, native).unwrap();
        let h = svc.handle();
        let tickets: Vec<_> =
            (1..=500).map(|i| h.submit(OpKind::Divide, (2 * i) as f32, 2.0).unwrap()).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().value.f32(), (i + 1) as f32);
        }
        svc.shutdown();
    }

    #[test]
    fn failing_executor_reports_typed_errors() {
        struct Failing;
        impl Executor for Failing {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::uniform("failing", &[64])
            }
            fn execute_into(
                &mut self,
                _: OpKind,
                _: FormatKind,
                _: PlaneRef<'_>,
                _: Option<PlaneRef<'_>>,
                _: PlaneRefMut<'_>,
            ) -> Result<()> {
                bail!("injected failure")
            }
        }
        let svc =
            FpuService::start(quick_config(), || Ok(Box::new(Failing) as Box<dyn Executor>))
                .unwrap();
        let h = svc.handle();
        let t = h.submit(OpKind::Divide, 1.0, 1.0).unwrap();
        // the backend's message reaches the client, typed
        match t.wait() {
            Err(ServiceError::ExecFailed { backend }) => {
                assert!(backend.contains("injected failure"), "{backend}");
            }
            other => panic!("expected ExecFailed, got {other:?}"),
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.total_errors(), 1);
        svc.shutdown();
    }

    #[test]
    fn unsupported_pair_rejected_at_submit() {
        // a backend that only serves f32 divide: everything else is
        // rejected before queueing, with the backend named
        struct DivOnly(NativeExecutor);
        impl Executor for DivOnly {
            fn capabilities(&self) -> BackendCaps {
                BackendCaps::new("div-only").with(OpKind::Divide, FormatKind::F32, &[64])
            }
            fn execute_into(
                &mut self,
                op: OpKind,
                format: FormatKind,
                a: PlaneRef<'_>,
                b: Option<PlaneRef<'_>>,
                out: PlaneRefMut<'_>,
            ) -> Result<()> {
                self.0.execute_into(op, format, a, b, out)
            }
        }
        let svc = FpuService::start(quick_config(), || {
            Ok(Box::new(DivOnly(NativeExecutor::with_defaults())) as Box<dyn Executor>)
        })
        .unwrap();
        let h = svc.handle();
        assert_eq!(h.divide(6.0, 2.0).unwrap(), 3.0);
        match h.sqrt(4.0) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("div-only"), "{reason}");
                assert!(reason.contains("sqrt"), "{reason}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert!(matches!(
            h.divide_in(FormatKind::F64, 1.0, 1.0),
            Err(ServiceError::Rejected { .. })
        ));
        svc.shutdown();
    }

    #[test]
    fn routed_service_merges_capabilities_and_serves() {
        use crate::runtime::executor::{ScalarReferenceExecutor, U128BaselineExecutor};
        // u128 first (divide-only preference), scalar second: the union
        // must admit every pair, divide routes to u128, sqrt to scalar
        let registry = ExecutorRegistry::new()
            .register(|| Ok(Box::new(U128BaselineExecutor::with_defaults()) as _))
            .register(|| Ok(Box::new(ScalarReferenceExecutor::with_defaults()) as _));
        let svc = FpuService::start_routed(quick_config(), registry).unwrap();
        assert_eq!(svc.backend_names(), &["u128-baseline", "scalar-reference"]);
        let caps = svc.capabilities();
        assert_eq!(caps.backend(), "dispatch");
        assert_eq!(caps.supported().len(), 12, "union admits what either serves");
        let h = svc.handle();
        for format in FormatKind::ALL {
            assert_eq!(h.divide_in(format, 10.0, 4.0).unwrap(), 2.5, "{format}");
            assert_eq!(h.sqrt_in(format, 81.0).unwrap(), 9.0, "{format}");
            assert_eq!(h.rsqrt_in(format, 4.0).unwrap(), 0.5, "{format}");
        }
        let report = svc.dispatch_report();
        assert_eq!(report.len(), 2);
        let (u128_snap, scalar_snap) = (report[0].1, report[1].1);
        assert!(u128_snap.ok_batches > 0, "divide batches route to the preferred backend");
        assert!(scalar_snap.ok_batches > 0, "unary batches route to the only capable backend");
        assert_eq!(u128_snap.failed_batches, 0);
        assert!(!u128_snap.breaker_open);
        assert_eq!(svc.metrics().snapshot().total_errors(), 0);
        svc.shutdown();
    }

    #[test]
    fn routed_worker_init_failure_names_the_backend() {
        use crate::runtime::executor::ScalarReferenceExecutor;
        use std::sync::atomic::AtomicU64;
        // probe succeeds, the pool worker's factory call fails: start
        // must fail and name the backend's worker
        let count = Arc::new(AtomicU64::new(0));
        let c2 = count.clone();
        let registry = ExecutorRegistry::new()
            .register(|| Ok(Box::new(NativeExecutor::with_defaults()) as _))
            .register(move || {
                if c2.fetch_add(1, Ordering::SeqCst) == 0 {
                    Ok(Box::new(ScalarReferenceExecutor::with_defaults()) as _)
                } else {
                    Err(anyhow::anyhow!("scalar pool refused to start"))
                }
            });
        let err = match FpuService::start_routed(quick_config(), registry) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("start must fail when a pool worker cannot build its executor"),
        };
        assert!(err.contains("fpu-scalar-reference"), "{err}");
        assert!(err.contains("refused to start"), "{err}");
    }
}
