//! Bounded lock-free MPSC submit rings with event-count parking.
//!
//! Each coordinator shard owns one [`SubmitRing`]: submitting threads
//! race a single CAS to claim a slot, write their message, and publish
//! it with one release store — no lock anywhere on the submit path.
//! The shard dispatcher is the only steady-state consumer; when its
//! ring runs dry it parks on an [`EventCount`], and producers wake it
//! with a notify that costs one fence plus one relaxed load in the
//! common (unparked) case.
//!
//! The slot protocol is the Vyukov bounded queue, the same discipline
//! as the `obs` trace event rings, generalized to non-`Copy` payloads:
//! slots hold `MaybeUninit<T>` and the ring drains itself on drop so
//! queued-but-never-popped messages are not leaked.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One ring slot: a sequence word encoding whether the slot is
/// free/full for the current lap, plus the payload cell.
struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free multi-producer ring (Vyukov bounded queue).
///
/// Any number of producers may [`try_push`](SubmitRing::try_push)
/// concurrently. [`pop`](SubmitRing::pop) follows the full MPMC
/// discipline (CAS on the dequeue cursor) even though each shard has a
/// single steady-state consumer, so the shutdown path may drain a ring
/// from a different thread than the dispatcher that normally owns it.
pub struct SubmitRing<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
}

// SAFETY: slot payloads are only written by the producer that won the
// slot's sequence CAS and only read after the matching release store,
// exactly the Vyukov bounded-queue protocol, so sharing the ring across
// threads is sound whenever the payload itself is `Send`.
unsafe impl<T: Send> Send for SubmitRing<T> {}
unsafe impl<T: Send> Sync for SubmitRing<T> {}

impl<T> SubmitRing<T> {
    /// Build a ring holding up to `capacity` messages (rounded up to a
    /// power of two, minimum 8).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(8).next_power_of_two();
        let slots = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self {
            slots,
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
        }
    }

    /// The rounded slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Publish one message: one CAS plus one release store in the
    /// common case. `Err(v)` hands the message back when the ring is
    /// full — the caller decides between backoff and typed shedding.
    pub fn try_push(&self, v: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS grants exclusive write
                        // access to this slot until the release store
                        // below publishes it to the consumer side.
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return Err(v);
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Take the oldest message, or `None` when the ring is empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos.wrapping_add(1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS grants exclusive read
                        // access to the initialized payload published by
                        // the matching release store in `try_push`.
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq
                            .store(pos.wrapping_add(self.mask).wrapping_add(1), Ordering::Release);
                        return Some(v);
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return None;
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Approximate occupancy: enqueue cursor minus dequeue cursor,
    /// clamped to `[0, capacity]`. Racy by nature — an introspection
    /// gauge (per-shard ring depth in the stats surface), never a
    /// synchronization primitive.
    pub fn len(&self) -> usize {
        let e = self.enqueue_pos.load(Ordering::Relaxed);
        let d = self.dequeue_pos.load(Ordering::Relaxed);
        (e.wrapping_sub(d) as isize).clamp(0, self.slots.len() as isize) as usize
    }

    /// Racy emptiness probe used by the consumer's parking double-check.
    /// Exact under quiescence, conservative under concurrency; the park
    /// timeout bounds the cost of any stale answer.
    pub fn is_empty(&self) -> bool {
        let pos = self.dequeue_pos.load(Ordering::Relaxed);
        let seq = self.slots[pos & self.mask].seq.load(Ordering::Acquire);
        (seq as isize - pos.wrapping_add(1) as isize) < 0
    }
}

impl<T> Drop for SubmitRing<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

/// Consumer-side parking for a [`SubmitRing`].
///
/// The dispatcher parks when its ring runs dry; producers pay a fence
/// plus one relaxed load to decide whether a wakeup is needed, so the
/// submit fast path never takes the condvar lock while the consumer is
/// running.
pub struct EventCount {
    parked: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Default for EventCount {
    fn default() -> Self {
        Self::new()
    }
}

impl EventCount {
    /// A fresh, unparked event count.
    pub fn new() -> Self {
        Self {
            parked: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Producer side, called after publishing into the ring. The SeqCst
    /// fence orders the ring publish before the parked-flag load
    /// (Dekker pairing with [`park_timeout`](EventCount::park_timeout)):
    /// either the consumer's emptiness re-check sees the message, or we
    /// see its parked flag and take the lock to wake it.
    pub fn notify(&self) {
        fence(Ordering::SeqCst);
        if self.parked.load(Ordering::Relaxed) {
            let _guard = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Consumer side: park for up to `timeout` unless `ready()` already
    /// holds. The flag-store / fence / re-check sequence mirrors
    /// [`notify`](EventCount::notify); the timeout bounds any missed
    /// wakeup, though the fence pairing makes that window theoretical.
    pub fn park_timeout<F: Fn() -> bool>(&self, ready: F, timeout: Duration) {
        let guard = self.lock.lock().unwrap();
        self.parked.store(true, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        if ready() {
            self.parked.store(false, Ordering::Relaxed);
            return;
        }
        let (_guard, _) = self.cv.wait_timeout(guard, timeout).unwrap();
        self.parked.store(false, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(SubmitRing::<u64>::with_capacity(0).capacity(), 8);
        assert_eq!(SubmitRing::<u64>::with_capacity(9).capacity(), 16);
        assert_eq!(SubmitRing::<u64>::with_capacity(1024).capacity(), 1024);
    }

    #[test]
    fn fifo_order_single_thread() {
        let ring = SubmitRing::with_capacity(128);
        assert_eq!(ring.len(), 0);
        for i in 0..100u64 {
            ring.try_push(i).unwrap();
        }
        assert!(!ring.is_empty());
        assert_eq!(ring.len(), 100, "occupancy gauge exact under quiescence");
        for i in 0..100u64 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
        assert!(ring.is_empty());
        assert_eq!(ring.len(), 0);
    }

    #[test]
    fn full_ring_hands_the_message_back() {
        let ring = SubmitRing::with_capacity(8);
        for i in 0..8u64 {
            ring.try_push(i).unwrap();
        }
        assert_eq!(ring.try_push(99), Err(99));
        assert_eq!(ring.pop(), Some(0));
        ring.try_push(99).unwrap();
    }

    #[test]
    fn drop_drains_unpopped_payloads() {
        let tracker = Arc::new(());
        let ring = SubmitRing::with_capacity(16);
        for _ in 0..10 {
            ring.try_push(Arc::clone(&tracker)).unwrap();
        }
        assert_eq!(Arc::strong_count(&tracker), 11);
        drop(ring);
        assert_eq!(Arc::strong_count(&tracker), 1);
    }

    #[test]
    fn four_producers_one_consumer_loses_nothing() {
        const PER_THREAD: u64 = 5_000;
        let ring = Arc::new(SubmitRing::with_capacity(256));
        let mut producers = Vec::new();
        for tid in 0..4u64 {
            let ring = Arc::clone(&ring);
            producers.push(std::thread::spawn(move || {
                for seq in 0..PER_THREAD {
                    let mut msg = (tid, seq);
                    loop {
                        match ring.try_push(msg) {
                            Ok(()) => break,
                            Err(back) => {
                                msg = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        let mut next = [0u64; 4];
        let mut seen = 0u64;
        while seen < 4 * PER_THREAD {
            match ring.pop() {
                Some((tid, seq)) => {
                    // per-producer order is preserved even though the
                    // four publish streams interleave
                    assert_eq!(seq, next[tid as usize], "producer {tid} out of order");
                    next[tid as usize] += 1;
                    seen += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(ring.pop(), None);
        assert_eq!(next, [PER_THREAD; 4]);
    }

    #[test]
    fn parked_consumer_is_woken_by_notify() {
        let ring = Arc::new(SubmitRing::with_capacity(8));
        let ev = Arc::new(EventCount::new());
        let consumer = {
            let (ring, ev) = (Arc::clone(&ring), Arc::clone(&ev));
            std::thread::spawn(move || {
                let start = Instant::now();
                loop {
                    if let Some(v) = ring.pop() {
                        return (v, start.elapsed());
                    }
                    ev.park_timeout(|| !ring.is_empty(), Duration::from_secs(10));
                }
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        ring.try_push(7u64).unwrap();
        ev.notify();
        let (v, waited) = consumer.join().unwrap();
        assert_eq!(v, 7);
        // woken by the notify, not the 10s park timeout
        assert!(waited < Duration::from_secs(5), "consumer waited {waited:?}");
    }

    #[test]
    fn ready_check_preempts_parking() {
        let ring = SubmitRing::with_capacity(8);
        let ev = EventCount::new();
        ring.try_push(1u64).unwrap();
        let start = Instant::now();
        // a message published before the park must short-circuit it
        ev.park_timeout(|| !ring.is_empty(), Duration::from_secs(10));
        assert!(start.elapsed() < Duration::from_secs(5));
        assert_eq!(ring.pop(), Some(1));
    }
}
