//! Request router: fans incoming requests into per-(op, format) queues.
//!
//! The router is deliberately simple — (op kind, IEEE format) is the
//! full routing key the FPU needs — but it enforces the invariants the
//! batcher relies on: FIFO order within a queue, format purity (a
//! queue's requests all share one format, so a batch's planes are
//! uniform), and conservation (every request routed exactly once, none
//! dropped, none duplicated).

use std::collections::VecDeque;

use super::request::{FormatKind, op_format_slot as slot, OP_FORMAT_SLOTS, OpKind, Request};

/// Per-(op, format) FIFO queues.
#[derive(Debug)]
pub struct Router {
    queues: [VecDeque<Request>; OP_FORMAT_SLOTS],
    routed: u64,
    drained: u64,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self {
            queues: std::array::from_fn(|_| VecDeque::new()),
            routed: 0,
            drained: 0,
        }
    }

    /// Route one request to its (op, format) queue.
    pub fn route(&mut self, req: Request) {
        self.routed += 1;
        self.queues[slot(req.op, req.format())].push_back(req);
    }

    /// Queue length for an (op, format) pair.
    pub fn len(&self, op: OpKind, format: FormatKind) -> usize {
        self.queues[slot(op, format)].len()
    }

    /// Total queued across all queues.
    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Oldest enqueue time in one (op, format) queue (FIFO: its front).
    pub fn oldest_enqueue_in(&self, op: OpKind, format: FormatKind) -> Option<std::time::Instant> {
        self.queues[slot(op, format)].front().map(|r| r.enqueued_at)
    }

    /// Oldest enqueue time across all queues (drives idle wake-up).
    pub fn oldest_enqueue(&self) -> Option<std::time::Instant> {
        self.queues.iter().filter_map(|q| q.front().map(|r| r.enqueued_at)).min()
    }

    /// Pop up to `max` requests from one (op, format) queue, FIFO.
    pub fn drain(&mut self, op: OpKind, format: FormatKind, max: usize) -> Vec<Request> {
        let q = &mut self.queues[slot(op, format)];
        let take = max.min(q.len());
        let out: Vec<Request> = q.drain(..take).collect();
        self.drained += out.len() as u64;
        out
    }

    /// Lifetime counters: (routed, drained). Conservation invariant:
    /// `routed == drained + total_len()` at all times.
    pub fn counters(&self) -> (u64, u64) {
        (self.routed, self.drained)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use crate::formats::Value;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req_fmt(id: u64, op: OpKind, format: FormatKind) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep rx alive by leaking in tests that don't need replies
        std::mem::forget(_rx);
        Request {
            id,
            op,
            a: Value::one(format),
            b: Value::one(format),
            enqueued_at: Instant::now(),
            reply: tx,
        }
    }

    fn req(id: u64, op: OpKind) -> Request {
        req_fmt(id, op, FormatKind::F32)
    }

    #[test]
    fn routes_by_op() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Divide));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 2);
        assert_eq!(r.len(OpKind::Sqrt, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Rsqrt, FormatKind::F32), 0);
        assert_eq!(r.total_len(), 3);
    }

    #[test]
    fn routes_by_format_within_one_op() {
        let mut r = Router::new();
        r.route(req_fmt(1, OpKind::Divide, FormatKind::F32));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F64));
        r.route(req_fmt(3, OpKind::Divide, FormatKind::F16));
        r.route(req_fmt(4, OpKind::Divide, FormatKind::F64));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F64), 2);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F16), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::BF16), 0);
        // draining one format leaves the others untouched
        let got = r.drain(OpKind::Divide, FormatKind::F64, 10);
        assert_eq!(got.iter().map(|x| x.id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(got.iter().all(|x| x.format() == FormatKind::F64));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F16), 1);
    }

    #[test]
    fn fifo_within_op() {
        let mut r = Router::new();
        for id in 0..10 {
            r.route(req(id, OpKind::Divide));
        }
        let got = r.drain(OpKind::Divide, FormatKind::F32, 4);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let got = r.drain(OpKind::Divide, FormatKind::F32, 100);
        assert_eq!(got.first().unwrap().id, 4);
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn conservation_property() {
        check::property("router conserves requests", |g| {
            let mut r = Router::new();
            let mut routed = 0u64;
            let mut drained = 0u64;
            for step in 0..g.usize_in(1, 60) {
                let op = *g.pick(&OpKind::ALL);
                let fmt = *g.pick(&FormatKind::ALL);
                if g.chance(0.6) {
                    r.route(req_fmt(step as u64, op, fmt));
                    routed += 1;
                } else {
                    drained += r.drain(op, fmt, g.usize_in(0, 8) + 1).len() as u64;
                }
            }
            let (cr, cd) = r.counters();
            ensure(cr == routed && cd == drained, format!("{cr}/{routed} {cd}/{drained}"))?;
            ensure(
                routed == drained + r.total_len() as u64,
                format!("conservation: {routed} != {drained} + {}", r.total_len()),
            )
        });
    }

    #[test]
    fn oldest_enqueue_across_queues() {
        let mut r = Router::new();
        assert!(r.oldest_enqueue().is_none());
        let first = req(1, OpKind::Sqrt);
        let t0 = first.enqueued_at;
        r.route(first);
        std::thread::sleep(std::time::Duration::from_millis(1));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F64));
        assert_eq!(r.oldest_enqueue().unwrap(), t0);
        assert_eq!(r.oldest_enqueue_in(OpKind::Sqrt, FormatKind::F32).unwrap(), t0);
        assert!(r.oldest_enqueue_in(OpKind::Divide, FormatKind::F64).unwrap() > t0);
        assert!(r.oldest_enqueue_in(OpKind::Divide, FormatKind::F32).is_none());
    }

    #[test]
    fn drain_more_than_queued() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Rsqrt));
        let got = r.drain(OpKind::Rsqrt, FormatKind::F32, 10);
        assert_eq!(got.len(), 1);
        assert!(r.is_empty());
    }
}
