//! Request router: fans incoming requests into per-op queues.
//!
//! The router is deliberately simple — op kind is the only routing key
//! the FPU needs — but it enforces the invariants the batcher relies
//! on: FIFO order within an op, and conservation (every request routed
//! exactly once, none dropped, none duplicated).

use std::collections::VecDeque;

use super::request::{OpKind, Request};

/// Per-op FIFO queues.
#[derive(Debug, Default)]
pub struct Router {
    divide: VecDeque<Request>,
    sqrt: VecDeque<Request>,
    rsqrt: VecDeque<Request>,
    routed: u64,
    drained: u64,
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Route one request to its op queue.
    pub fn route(&mut self, req: Request) {
        self.routed += 1;
        self.queue_mut(req.op).push_back(req);
    }

    /// Queue length for an op.
    pub fn len(&self, op: OpKind) -> usize {
        self.queue(op).len()
    }

    /// Total queued across ops.
    pub fn total_len(&self) -> usize {
        OpKind::ALL.iter().map(|&op| self.len(op)).sum()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Oldest enqueue time across all queues (drives age-based flush).
    pub fn oldest_enqueue(&self) -> Option<std::time::Instant> {
        OpKind::ALL
            .iter()
            .filter_map(|&op| self.queue(op).front().map(|r| r.enqueued_at))
            .min()
    }

    /// Pop up to `max` requests from one op queue, FIFO.
    pub fn drain(&mut self, op: OpKind, max: usize) -> Vec<Request> {
        let q = self.queue_mut(op);
        let take = max.min(q.len());
        let out: Vec<Request> = q.drain(..take).collect();
        self.drained += out.len() as u64;
        out
    }

    /// Lifetime counters: (routed, drained). Conservation invariant:
    /// `routed == drained + total_len()` at all times.
    pub fn counters(&self) -> (u64, u64) {
        (self.routed, self.drained)
    }

    fn queue(&self, op: OpKind) -> &VecDeque<Request> {
        match op {
            OpKind::Divide => &self.divide,
            OpKind::Sqrt => &self.sqrt,
            OpKind::Rsqrt => &self.rsqrt,
        }
    }

    fn queue_mut(&mut self, op: OpKind) -> &mut VecDeque<Request> {
        match op {
            OpKind::Divide => &mut self.divide,
            OpKind::Sqrt => &mut self.sqrt,
            OpKind::Rsqrt => &mut self.rsqrt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64, op: OpKind) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep rx alive by leaking in tests that don't need replies
        std::mem::forget(_rx);
        Request { id, op, a: 1.0, b: 1.0, enqueued_at: Instant::now(), reply: tx }
    }

    #[test]
    fn routes_by_op() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Divide));
        assert_eq!(r.len(OpKind::Divide), 2);
        assert_eq!(r.len(OpKind::Sqrt), 1);
        assert_eq!(r.len(OpKind::Rsqrt), 0);
        assert_eq!(r.total_len(), 3);
    }

    #[test]
    fn fifo_within_op() {
        let mut r = Router::new();
        for id in 0..10 {
            r.route(req(id, OpKind::Divide));
        }
        let got = r.drain(OpKind::Divide, 4);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let got = r.drain(OpKind::Divide, 100);
        assert_eq!(got.first().unwrap().id, 4);
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn conservation_property() {
        check::property("router conserves requests", |g| {
            let mut r = Router::new();
            let mut routed = 0u64;
            let mut drained = 0u64;
            for step in 0..g.usize_in(1, 60) {
                if g.chance(0.6) {
                    let op = *g.pick(&OpKind::ALL);
                    r.route(req(step as u64, op));
                    routed += 1;
                } else {
                    let op = *g.pick(&OpKind::ALL);
                    drained += r.drain(op, g.usize_in(0, 8) + 1).len() as u64;
                }
            }
            let (cr, cd) = r.counters();
            ensure(cr == routed && cd == drained, format!("{cr}/{routed} {cd}/{drained}"))?;
            ensure(
                routed == drained + r.total_len() as u64,
                format!("conservation: {routed} != {drained} + {}", r.total_len()),
            )
        });
    }

    #[test]
    fn oldest_enqueue_across_queues() {
        let mut r = Router::new();
        assert!(r.oldest_enqueue().is_none());
        let first = req(1, OpKind::Sqrt);
        let t0 = first.enqueued_at;
        r.route(first);
        std::thread::sleep(std::time::Duration::from_millis(1));
        r.route(req(2, OpKind::Divide));
        assert_eq!(r.oldest_enqueue().unwrap(), t0);
    }

    #[test]
    fn drain_more_than_queued() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Rsqrt));
        let got = r.drain(OpKind::Rsqrt, 10);
        assert_eq!(got.len(), 1);
        assert!(r.is_empty());
    }
}
