//! Request router: fans incoming work items into per-(op, format)
//! queues.
//!
//! The router is deliberately simple — (op kind, IEEE format) is the
//! full routing key the FPU needs — but it enforces the invariants the
//! batcher relies on: FIFO order within a queue, format purity (a
//! queue's items all share one format, so a batch's planes are
//! uniform), and lane conservation (every submitted lane drained
//! exactly once, none dropped, none duplicated).
//!
//! Quantities are counted in **lanes**, not items: a vectored
//! submission enters as one [`WorkItem`] carrying many lanes, and
//! [`Router::drain`] may split it at a batch boundary (the halves share
//! their operand planes and completion slot, so the split is free and
//! invisible to the client).
//!
//! In the sharded coordinator every shard owns a private `Router`: a
//! submit picks its shard from `hash(op, format, handle shard key)`,
//! so one (op, format) stream from one handle always lands in the same
//! shard's queues and the FIFO/purity/conservation invariants hold
//! per shard with no cross-shard locking.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::{TraceEvent, TraceKind, TracePlane};

use super::request::{op_format_slot as slot, FormatKind, OpKind, WorkItem, OP_FORMAT_SLOTS};

/// Per-(op, format) FIFO queues.
#[derive(Debug)]
pub struct Router {
    queues: [VecDeque<WorkItem>; OP_FORMAT_SLOTS],
    /// Queued lanes per slot (kept incrementally; `len` must be O(1)).
    lanes: [usize; OP_FORMAT_SLOTS],
    /// Earliest deadline per slot (drives deadline-triggered flushes).
    min_deadline: [Option<Instant>; OP_FORMAT_SLOTS],
    /// Queued deadline-carrying items per slot: when zero (the common,
    /// deadline-free case) `drain` skips the floor rescan entirely.
    deadline_items: [usize; OP_FORMAT_SLOTS],
    routed: u64,
    drained: u64,
    /// Trace sink for enqueue events on sampled items (None = no
    /// tracing; the route hot path pays one `Option` check).
    trace: Option<Arc<TracePlane>>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self {
            queues: std::array::from_fn(|_| VecDeque::new()),
            lanes: [0; OP_FORMAT_SLOTS],
            min_deadline: [None; OP_FORMAT_SLOTS],
            deadline_items: [0; OP_FORMAT_SLOTS],
            routed: 0,
            drained: 0,
            trace: None,
        }
    }

    /// Arm (or disarm) trace emission for sampled items.
    pub fn set_trace(&mut self, trace: Option<Arc<TracePlane>>) {
        self.trace = trace;
    }

    /// Route one item to its (op, format) queue.
    pub fn route(&mut self, item: WorkItem) {
        let s = slot(item.op, item.format());
        if item.sampled {
            if let Some(t) = &self.trace {
                t.emit(
                    TraceEvent::new(TraceKind::Enqueue, t.now_ns())
                        .req(item.id, item.op, item.format())
                        .with_lanes(item.lanes()),
                );
            }
        }
        self.lanes[s] += item.lanes();
        self.routed += item.lanes() as u64;
        if let Some(d) = item.deadline {
            self.deadline_items[s] += 1;
            self.min_deadline[s] = Some(self.min_deadline[s].map_or(d, |m| m.min(d)));
        }
        self.queues[s].push_back(item);
    }

    /// Queued lanes for an (op, format) pair.
    pub fn len(&self, op: OpKind, format: FormatKind) -> usize {
        self.lanes[slot(op, format)]
    }

    /// Total queued lanes across all queues.
    pub fn total_len(&self) -> usize {
        self.lanes.iter().sum()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Oldest enqueue time in one (op, format) queue (FIFO: its front).
    pub fn oldest_enqueue_in(&self, op: OpKind, format: FormatKind) -> Option<Instant> {
        self.queues[slot(op, format)].front().map(|r| r.enqueued_at)
    }

    /// Oldest enqueue time across all queues (drives idle wake-up).
    pub fn oldest_enqueue(&self) -> Option<Instant> {
        self.queues.iter().filter_map(|q| q.front().map(|r| r.enqueued_at)).min()
    }

    /// Earliest deadline among one queue's items (None when no queued
    /// item carries a deadline).
    pub fn earliest_deadline_in(&self, op: OpKind, format: FormatKind) -> Option<Instant> {
        self.min_deadline[slot(op, format)]
    }

    /// Pop up to `max_lanes` lanes from one (op, format) queue, FIFO. A
    /// group item straddling the boundary is split: its front window is
    /// returned and the remainder stays at the head of the queue.
    pub fn drain(&mut self, op: OpKind, format: FormatKind, max_lanes: usize) -> Vec<WorkItem> {
        let qi = slot(op, format);
        let mut out = Vec::new();
        let mut taken = 0usize;
        let mut drained_deadline = false;
        while taken < max_lanes {
            let Some(front) = self.queues[qi].front_mut() else { break };
            let lanes = front.lanes();
            if taken + lanes <= max_lanes {
                let item = self.queues[qi].pop_front().expect("front exists");
                if item.deadline.is_some() {
                    self.deadline_items[qi] -= 1;
                    drained_deadline = true;
                }
                taken += lanes;
                out.push(item);
            } else {
                // a split leaves the remainder (with the same deadline,
                // if any) at the head: the per-slot count and the floor
                // are both unchanged
                let part = front.split_off_front(max_lanes - taken);
                taken += part.lanes();
                out.push(part);
                break;
            }
        }
        self.lanes[qi] -= taken;
        self.drained += taken as u64;
        // deadline floor: unchanged unless a deadline-carrying item
        // actually left the queue; the rescan is paid only by deadline
        // traffic, never by a deadline-free (or deadline-behind) backlog
        if drained_deadline {
            self.min_deadline[qi] = if self.deadline_items[qi] == 0 {
                None
            } else {
                self.queues[qi].iter().filter_map(|r| r.deadline).min()
            };
        }
        out
    }

    /// Lifetime lane counters: (routed, drained). Conservation
    /// invariant: `routed == drained + total_len()` at all times.
    pub fn counters(&self) -> (u64, u64) {
        (self.routed, self.drained)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use crate::formats::Value;
    use std::time::{Duration, Instant};

    fn req_fmt(id: u64, op: OpKind, format: FormatKind) -> WorkItem {
        let (item, _ticket) =
            WorkItem::single(id, op, Value::one(format), Value::one(format), None);
        item
    }

    fn req(id: u64, op: OpKind) -> WorkItem {
        req_fmt(id, op, FormatKind::F32)
    }

    fn group(id: u64, op: OpKind, format: FormatKind, lanes: usize) -> WorkItem {
        let a: Vec<u64> = (0..lanes as u64).map(|i| i + 1).collect();
        let b = if op == OpKind::Divide { a.clone() } else { Vec::new() };
        let (item, _ticket) = WorkItem::group(id, op, format, &a, &b, None);
        item
    }

    #[test]
    fn routes_by_op() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Divide));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 2);
        assert_eq!(r.len(OpKind::Sqrt, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Rsqrt, FormatKind::F32), 0);
        assert_eq!(r.total_len(), 3);
    }

    #[test]
    fn routes_by_format_within_one_op() {
        let mut r = Router::new();
        r.route(req_fmt(1, OpKind::Divide, FormatKind::F32));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F64));
        r.route(req_fmt(3, OpKind::Divide, FormatKind::F16));
        r.route(req_fmt(4, OpKind::Divide, FormatKind::F64));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F64), 2);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F16), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::BF16), 0);
        // draining one format leaves the others untouched
        let got = r.drain(OpKind::Divide, FormatKind::F64, 10);
        assert_eq!(got.iter().map(|x| x.id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(got.iter().all(|x| x.format() == FormatKind::F64));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F16), 1);
    }

    #[test]
    fn fifo_within_op() {
        let mut r = Router::new();
        for id in 0..10 {
            r.route(req(id, OpKind::Divide));
        }
        let got = r.drain(OpKind::Divide, FormatKind::F32, 4);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let got = r.drain(OpKind::Divide, FormatKind::F32, 100);
        assert_eq!(got.first().unwrap().id, 4);
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn groups_count_lanes_and_split_at_drain_boundary() {
        let mut r = Router::new();
        r.route(group(1, OpKind::Divide, FormatKind::F32, 10));
        r.route(req(2, OpKind::Divide));
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 11);
        // drain 6 lanes: the group splits, its tail stays queued
        let got = r.drain(OpKind::Divide, FormatKind::F32, 6);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].lanes(), 6);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 5);
        // the tail (4 lanes) drains before the single behind it
        let got = r.drain(OpKind::Divide, FormatKind::F32, 100);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].lanes(), 4);
        assert_eq!(got[0].id, 1);
        assert_eq!(got[1].id, 2);
        assert!(r.is_empty());
        let (routed, drained) = r.counters();
        assert_eq!(routed, 11);
        assert_eq!(drained, 11);
    }

    #[test]
    fn conservation_property() {
        check::property("router conserves lanes", |g| {
            let mut r = Router::new();
            let mut routed = 0u64;
            let mut drained = 0u64;
            for step in 0..g.usize_in(1, 60) {
                let op = *g.pick(&OpKind::ALL);
                let fmt = *g.pick(&FormatKind::ALL);
                if g.chance(0.6) {
                    if g.chance(0.3) {
                        let lanes = g.usize_in(1, 12);
                        r.route(group(step as u64, op, fmt, lanes));
                        routed += lanes as u64;
                    } else {
                        r.route(req_fmt(step as u64, op, fmt));
                        routed += 1;
                    }
                } else {
                    let got = r.drain(op, fmt, g.usize_in(0, 8) + 1);
                    drained += got.iter().map(|x| x.lanes() as u64).sum::<u64>();
                }
            }
            let (cr, cd) = r.counters();
            ensure(cr == routed && cd == drained, format!("{cr}/{routed} {cd}/{drained}"))?;
            ensure(
                routed == drained + r.total_len() as u64,
                format!("conservation: {routed} != {drained} + {}", r.total_len()),
            )
        });
    }

    #[test]
    fn oldest_enqueue_across_queues() {
        let mut r = Router::new();
        assert!(r.oldest_enqueue().is_none());
        let first = req(1, OpKind::Sqrt);
        let t0 = first.enqueued_at;
        r.route(first);
        std::thread::sleep(Duration::from_millis(1));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F64));
        assert_eq!(r.oldest_enqueue().unwrap(), t0);
        assert_eq!(r.oldest_enqueue_in(OpKind::Sqrt, FormatKind::F32).unwrap(), t0);
        assert!(r.oldest_enqueue_in(OpKind::Divide, FormatKind::F64).unwrap() > t0);
        assert!(r.oldest_enqueue_in(OpKind::Divide, FormatKind::F32).is_none());
    }

    #[test]
    fn deadline_floor_tracked_and_recomputed() {
        let mut r = Router::new();
        assert!(r.earliest_deadline_in(OpKind::Divide, FormatKind::F32).is_none());
        let far = Instant::now() + Duration::from_secs(60);
        let near = Instant::now() + Duration::from_millis(5);
        let mut with_deadline = |id, d| {
            let (item, _t) = WorkItem::single(
                id,
                OpKind::Divide,
                Value::F32(1.0),
                Value::F32(1.0),
                Some(d),
            );
            item
        };
        r.route(with_deadline(1, far));
        assert_eq!(r.earliest_deadline_in(OpKind::Divide, FormatKind::F32), Some(far));
        r.route(with_deadline(2, near));
        assert_eq!(r.earliest_deadline_in(OpKind::Divide, FormatKind::F32), Some(near));
        // draining the near-deadline item restores the floor
        let got = r.drain(OpKind::Divide, FormatKind::F32, 2);
        assert_eq!(got.len(), 2);
        assert!(r.earliest_deadline_in(OpKind::Divide, FormatKind::F32).is_none());
        r.route(with_deadline(3, far));
        r.route(req(4, OpKind::Divide));
        let _ = r.drain(OpKind::Divide, FormatKind::F32, 1);
        assert_eq!(r.earliest_deadline_in(OpKind::Divide, FormatKind::F32), None);
    }

    #[test]
    fn sampled_items_emit_enqueue_events() {
        use crate::obs::{TraceConfig, TraceKind, TracePlane};
        let plane = Arc::new(TracePlane::new(TraceConfig { sample: 1, capacity: 64 }));
        let mut r = Router::new();
        r.set_trace(Some(plane.clone()));
        let mut item = group(7, OpKind::Divide, FormatKind::F32, 3);
        item.sampled = true;
        r.route(item);
        r.route(req(8, OpKind::Sqrt)); // unsampled: silent
        let events = plane.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, TraceKind::Enqueue);
        assert_eq!(events[0].id, 7);
        assert_eq!(events[0].lanes, 3);
    }

    #[test]
    fn drain_more_than_queued() {
        let mut r = Router::new();
        r.route(req(1, OpKind::Rsqrt));
        let got = r.drain(OpKind::Rsqrt, FormatKind::F32, 10);
        assert_eq!(got.len(), 1);
        assert!(r.is_empty());
    }
}
