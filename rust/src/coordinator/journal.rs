//! [`Journal`]: the append-only binary request journal behind the
//! durable submission API
//! ([`submit_batch_durable`](super::service::FpuService::submit_batch_durable)).
//!
//! A journal file is a fixed 8-byte header (`GSJL` magic + version)
//! followed by length-prefixed records, each CRC-guarded:
//!
//! ```text
//! header  := b"GSJL" | version: u32 LE
//! record  := len: u32 LE | crc32(payload): u32 LE | payload
//! payload := id: u64 | op: u8 | format: u8 | status: u8 | flags: u8
//!          | a_lanes: u32 | b_lanes: u32 | r_lanes: u32 | err_len: u32
//!          | a words (u64 LE) | b words | result words | error (utf8)
//! ```
//!
//! A job's lifecycle is append-only: one `Pending` record at submit,
//! then one `Done` (with result words) or `Failed` (with the error
//! text) record when its ticket resolves. On open, records are read
//! back until the first short, oversized, or CRC-mismatching record —
//! the *torn tail* a crash mid-append leaves — and the file is
//! truncated there, so the journal is always well-formed for the next
//! append. Replay coalesces by id (last status wins): ids whose latest
//! record is still `Pending` are re-submitted through the normal
//! request path by `FpuService::start`, exactly once.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::fault::{FaultPlan, FaultSite};
use crate::formats::FormatKind;

use super::request::OpKind;

/// Backend-filter name the journal's fault sites match against (a
/// journal has no backend; see `crate::fault` for the site table).
const FAULT_BACKEND: &str = "journal";

const MAGIC: [u8; 4] = *b"GSJL";
const VERSION: u32 = 1;
const HEADER_LEN: u64 = 8;
/// Fixed-size payload prefix before the variable-length planes.
const PREFIX_LEN: usize = 8 + 1 + 1 + 1 + 1 + 4 + 4 + 4 + 4;
/// Refuse to trust a length prefix beyond this (a torn length field
/// could otherwise ask for gigabytes).
const MAX_RECORD: u32 = 256 << 20;

/// A journalled job's lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted, not yet resolved — replayed on restart.
    Pending,
    /// Resolved with result words.
    Done,
    /// Resolved with a service error.
    Failed,
}

impl JobStatus {
    fn to_byte(self) -> u8 {
        match self {
            JobStatus::Pending => 0,
            JobStatus::Done => 1,
            JobStatus::Failed => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Self> {
        match b {
            0 => Ok(JobStatus::Pending),
            1 => Ok(JobStatus::Done),
            2 => Ok(JobStatus::Failed),
            other => bail!("bad journal status byte {other}"),
        }
    }
}

/// One journal record: a job id plus everything needed to re-submit it
/// (operands) or report it (result / error).
#[derive(Clone, Debug, PartialEq)]
pub struct JournalRecord {
    /// Stable job id (assigned at first submit, preserved by replay).
    pub id: u64,
    /// The operation.
    pub op: OpKind,
    /// The operand format.
    pub format: FormatKind,
    /// Lifecycle state this record asserts.
    pub status: JobStatus,
    /// Operand plane A, raw format words.
    pub a: Vec<u64>,
    /// Operand plane B (empty for unary ops).
    pub b: Vec<u64>,
    /// Result words (`Done` records only).
    pub result: Vec<u64>,
    /// Error text (`Failed` records only).
    pub error: String,
}

impl JournalRecord {
    /// A fresh `Pending` record for a submission.
    pub fn pending(id: u64, op: OpKind, format: FormatKind, a: Vec<u64>, b: Vec<u64>) -> Self {
        Self { id, op, format, status: JobStatus::Pending, a, b, result: Vec::new(), error: String::new() }
    }
}

pub(crate) fn op_to_byte(op: OpKind) -> u8 {
    match op {
        OpKind::Divide => 0,
        OpKind::Sqrt => 1,
        OpKind::Rsqrt => 2,
    }
}

pub(crate) fn op_from_byte(b: u8) -> Result<OpKind> {
    match b {
        0 => Ok(OpKind::Divide),
        1 => Ok(OpKind::Sqrt),
        2 => Ok(OpKind::Rsqrt),
        other => bail!("bad journal op byte {other}"),
    }
}

pub(crate) fn format_to_byte(format: FormatKind) -> u8 {
    match format {
        FormatKind::F16 => 0,
        FormatKind::BF16 => 1,
        FormatKind::F32 => 2,
        FormatKind::F64 => 3,
    }
}

pub(crate) fn format_from_byte(b: u8) -> Result<FormatKind> {
    match b {
        0 => Ok(FormatKind::F16),
        1 => Ok(FormatKind::BF16),
        2 => Ok(FormatKind::F32),
        3 => Ok(FormatKind::F64),
        other => bail!("bad journal format byte {other}"),
    }
}

/// CRC-32 (IEEE 802.3 polynomial, reflected), table-driven. Hand
/// rolled because the environment ships no crc crate; pinned by a
/// known-answer test below. Shared with the wire protocol
/// (`crate::net`), which reuses the journal's framing discipline.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };
    let mut crc = 0xFFFF_FFFFu32;
    for &byte in data {
        crc = TABLE[((crc ^ byte as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

fn encode_payload(rec: &JournalRecord) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        PREFIX_LEN + 8 * (rec.a.len() + rec.b.len() + rec.result.len()) + rec.error.len(),
    );
    out.extend_from_slice(&rec.id.to_le_bytes());
    out.push(op_to_byte(rec.op));
    out.push(format_to_byte(rec.format));
    out.push(rec.status.to_byte());
    out.push(0); // flags, reserved
    out.extend_from_slice(&(rec.a.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rec.b.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rec.result.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rec.error.len() as u32).to_le_bytes());
    for &w in &rec.a {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for &w in &rec.b {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for &w in &rec.result {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.extend_from_slice(rec.error.as_bytes());
    out
}

fn decode_payload(payload: &[u8]) -> Result<JournalRecord> {
    if payload.len() < PREFIX_LEN {
        bail!("journal payload shorter than prefix");
    }
    let id = u64::from_le_bytes(payload[0..8].try_into().unwrap());
    let op = op_from_byte(payload[8])?;
    let format = format_from_byte(payload[9])?;
    let status = JobStatus::from_byte(payload[10])?;
    let word32 = |off: usize| u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
    let (a_lanes, b_lanes, r_lanes, err_len) =
        (word32(12) as usize, word32(16) as usize, word32(20) as usize, word32(24) as usize);
    let expect = PREFIX_LEN + 8 * (a_lanes + b_lanes + r_lanes) + err_len;
    if payload.len() != expect {
        bail!("journal payload length {} != declared {}", payload.len(), expect);
    }
    let mut off = PREFIX_LEN;
    let mut words = |n: usize, off: &mut usize| -> Vec<u64> {
        let v = (0..n)
            .map(|i| u64::from_le_bytes(payload[*off + 8 * i..*off + 8 * i + 8].try_into().unwrap()))
            .collect();
        *off += 8 * n;
        v
    };
    let a = words(a_lanes, &mut off);
    let b = words(b_lanes, &mut off);
    let result = words(r_lanes, &mut off);
    let error = String::from_utf8(payload[off..].to_vec())
        .context("journal error text is not utf8")?;
    Ok(JournalRecord { id, op, format, status, a, b, result, error })
}

/// An open journal file positioned for appends. Construction via
/// [`Journal::open`] returns the replayable records alongside.
#[derive(Debug)]
pub struct Journal {
    file: File,
    /// Armed fault schedule; `append-fail` / `fsync-stall` sites are
    /// consulted per append with the `"journal"` backend filter.
    fault: Option<Arc<FaultPlan>>,
}

impl Journal {
    /// Open (or create) a journal at `path`, returning the journal
    /// positioned for appending plus every intact record in file
    /// order. A torn tail — the partial record a crash mid-append
    /// leaves — is detected by its length/CRC and truncated away; the
    /// records before it are unaffected.
    pub fn open(path: &Path) -> Result<(Journal, Vec<JournalRecord>)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        let end = file.seek(SeekFrom::End(0))?;
        if end == 0 {
            file.write_all(&MAGIC)?;
            file.write_all(&VERSION.to_le_bytes())?;
            file.flush()?;
            return Ok((Journal { file, fault: None }, Vec::new()));
        }
        file.seek(SeekFrom::Start(0))?;
        let mut bytes = Vec::with_capacity(end as usize);
        file.read_to_end(&mut bytes)?;
        if bytes.len() < HEADER_LEN as usize || bytes[..4] != MAGIC {
            bail!("{} is not a journal (bad magic)", path.display());
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("journal version {version} unsupported (expected {VERSION})");
        }
        let mut records = Vec::new();
        let mut pos = HEADER_LEN as usize;
        let good_end = loop {
            if pos == bytes.len() {
                break pos; // clean end
            }
            if pos + 8 > bytes.len() {
                break pos; // torn length/crc prefix
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
            let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            if len > MAX_RECORD || pos + 8 + len as usize > bytes.len() {
                break pos; // torn payload
            }
            let payload = &bytes[pos + 8..pos + 8 + len as usize];
            if crc32(payload) != crc {
                break pos; // corrupted record: stop trusting the tail
            }
            match decode_payload(payload) {
                Ok(rec) => records.push(rec),
                Err(_) => break pos,
            }
            pos += 8 + len as usize;
        };
        if good_end < bytes.len() {
            file.set_len(good_end as u64)?;
        }
        file.seek(SeekFrom::Start(good_end as u64))?;
        Ok((Journal { file, fault: None }, records))
    }

    /// Arm a fault schedule: subsequent appends consult the
    /// `append-fail` and `fsync-stall` sites (backend `"journal"`).
    pub fn set_fault(&mut self, plan: Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    /// Append one record (length + CRC + payload, flushed). The write
    /// is a single `write_all`, so a crash leaves at most one torn
    /// tail record for the next open to truncate. An injected
    /// `append-fail` errors *before* anything reaches the file, so the
    /// caller sees a typed failure for a record the journal does not
    /// hold — exactly the shape a full disk or yanked volume produces.
    pub fn append(&mut self, rec: &JournalRecord) -> Result<()> {
        if let Some(plan) = &self.fault {
            if plan.check(FaultSite::JournalAppendFail, FAULT_BACKEND).is_some() {
                bail!("injected fault: journal append failed (site append-fail)");
            }
        }
        let payload = encode_payload(rec);
        if payload.len() as u64 > MAX_RECORD as u64 {
            bail!("journal record too large ({} bytes)", payload.len());
        }
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        if let Some(plan) = &self.fault {
            if let Some(shot) = plan.check(FaultSite::JournalFsyncStall, FAULT_BACKEND) {
                std::thread::sleep(std::time::Duration::from_micros(shot.micros));
            }
        }
        self.file.flush()?;
        Ok(())
    }
}

/// Coalesce raw records by job id — the **last** record of an id wins
/// (a `Done`/`Failed` record supersedes the job's `Pending` record).
/// Returns the coalesced records ordered by id, so replay is
/// deterministic regardless of append interleaving.
pub fn coalesce(records: Vec<JournalRecord>) -> Vec<JournalRecord> {
    let mut by_id: std::collections::BTreeMap<u64, JournalRecord> =
        std::collections::BTreeMap::new();
    for rec in records {
        by_id.insert(rec.id, rec);
    }
    by_id.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "goldschmidt-journal-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&dir);
        dir
    }

    fn sample(id: u64, status: JobStatus) -> JournalRecord {
        JournalRecord {
            id,
            op: OpKind::Divide,
            format: FormatKind::F32,
            status,
            a: vec![0x4080_0000, 0x40A0_0000],
            b: vec![0x4000_0000, 0x4000_0000],
            result: if status == JobStatus::Done { vec![0x4000_0000, 0x4020_0000] } else { vec![] },
            error: if status == JobStatus::Failed { "kaput".into() } else { String::new() },
        }
    }

    #[test]
    fn crc32_known_answer() {
        // the IEEE 802.3 check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn round_trips_records() {
        let path = tmp("roundtrip");
        {
            let (mut j, recs) = Journal::open(&path).unwrap();
            assert!(recs.is_empty());
            j.append(&sample(1, JobStatus::Pending)).unwrap();
            j.append(&sample(2, JobStatus::Pending)).unwrap();
            j.append(&sample(1, JobStatus::Done)).unwrap();
            j.append(&sample(3, JobStatus::Failed)).unwrap();
        }
        let (_, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(recs[0], sample(1, JobStatus::Pending));
        assert_eq!(recs[2], sample(1, JobStatus::Done));
        assert_eq!(recs[3].error, "kaput");
        // ops and formats survive the byte round trip
        for op in OpKind::ALL {
            assert_eq!(op_from_byte(op_to_byte(op)).unwrap(), op);
        }
        for format in FormatKind::ALL {
            assert_eq!(format_from_byte(format_to_byte(format)).unwrap(), format);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn coalesce_keeps_last_record_per_id() {
        let recs = vec![
            sample(2, JobStatus::Pending),
            sample(1, JobStatus::Pending),
            sample(2, JobStatus::Done),
            sample(3, JobStatus::Pending),
        ];
        let merged = coalesce(recs);
        assert_eq!(merged.len(), 3);
        assert_eq!(
            merged.iter().map(|r| (r.id, r.status)).collect::<Vec<_>>(),
            vec![
                (1, JobStatus::Pending),
                (2, JobStatus::Done),
                (3, JobStatus::Pending)
            ],
            "ordered by id, last status wins"
        );
    }

    #[test]
    fn torn_tail_is_truncated_and_appendable() {
        let path = tmp("torn");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&sample(1, JobStatus::Pending)).unwrap();
            j.append(&sample(2, JobStatus::Pending)).unwrap();
        }
        // simulate a crash mid-append: a dangling half-record
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0xAB; 11]).unwrap();
        }
        let torn_len = std::fs::metadata(&path).unwrap().len();
        let (mut j, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.len(), 2, "intact records survive the torn tail");
        assert!(std::fs::metadata(&path).unwrap().len() < torn_len, "tail truncated");
        // appends continue where the good records end
        j.append(&sample(3, JobStatus::Pending)).unwrap();
        drop(j);
        let (_, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupted_record_stops_replay_at_the_corruption() {
        let path = tmp("corrupt");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&sample(1, JobStatus::Pending)).unwrap();
            let offset = j.file.stream_position().unwrap();
            j.append(&sample(2, JobStatus::Pending)).unwrap();
            j.append(&sample(3, JobStatus::Pending)).unwrap();
            // flip one payload byte of record 2: its CRC no longer
            // matches, so it and everything after is distrusted
            j.file.seek(SeekFrom::Start(offset + 8 + 3)).unwrap();
            j.file.write_all(&[0xFF]).unwrap();
        }
        let (_, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].id, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn injected_append_fail_is_typed_and_writes_nothing() {
        let path = tmp("appendfail");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.set_fault(Arc::new(
                FaultPlan::parse("append-fail@journal:after=1,count=1", 5).unwrap(),
            ));
            j.append(&sample(1, JobStatus::Pending)).unwrap();
            let len_before = std::fs::metadata(&path).unwrap().len();
            let err = j.append(&sample(2, JobStatus::Pending)).unwrap_err();
            assert!(err.to_string().contains("append-fail"), "{err:#}");
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                len_before,
                "a failed append must leave the file untouched"
            );
            // the window is spent: the next append lands normally
            j.append(&sample(3, JobStatus::Pending)).unwrap();
        }
        let (_, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn injected_fsync_stall_delays_but_lands_the_record() {
        let path = tmp("fsyncstall");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.set_fault(Arc::new(
                FaultPlan::parse("fsync-stall@journal:us=20000,count=1", 5).unwrap(),
            ));
            let t0 = std::time::Instant::now();
            j.append(&sample(1, JobStatus::Pending)).unwrap();
            assert!(
                t0.elapsed() >= std::time::Duration::from_millis(15),
                "stall not observed: {:?}",
                t0.elapsed()
            );
        }
        let (_, recs) = Journal::open(&path).unwrap();
        assert_eq!(recs.len(), 1, "a stalled flush still lands the record");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn non_journal_file_is_rejected() {
        let path = tmp("notjournal");
        std::fs::write(&path, b"#!/bin/sh\necho hello\n").unwrap();
        assert!(Journal::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
