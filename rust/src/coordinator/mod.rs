//! The FPU-service coordinator: the layer-3 serving stack that exposes
//! the Goldschmidt divider as a batched request service.
//!
//! Request path (all rust, no Python):
//!
//! ```text
//! clients ──submit()──> bounded queue ──> Router ──> per-(op, format)
//!                                              │      queues
//!                                       DynamicBatcher (size/age policy,
//!                                              │        ladder padding)
//!                                     worker pool: Executor::execute
//!                                              │  (format-dispatched
//!                                              │   batch kernels / PJRT)
//!                                        per-request responses
//! ```
//!
//! Every request carries a format-tagged [`Value`] pair; the
//! (op, IEEE format) pair is the routing key end to end — queues,
//! batches, executor dispatch and metrics are all sliced by it, so an
//! f16 inference workload and an f64 scientific workload batch
//! independently on the same service.
//!
//! * [`request`] — request/response types, op kinds, and the format
//!   tags re-exported from [`crate::formats`].
//! * [`router`] — fans requests out to per-(op, format) queues
//!   (conservation and format purity are property-tested).
//! * [`batcher`] — dynamic batching: flush on max-size or max-age,
//!   padding to the artifact batch ladder with the format's `1.0`.
//! * [`metrics`] — always-on counters + latency histograms, per
//!   (op, format) with per-op aggregates.
//! * [`service`] — the threaded service: lifecycle, backpressure,
//!   worker pool.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod service;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{Metrics, MetricsSnapshot, OpFormatSnapshot, OpSnapshot};
pub use request::{FormatKind, OpKind, Request, Response, Value};
pub use router::Router;
pub use service::{FpuService, ServiceConfig, ServiceHandle};
