//! The FPU-service coordinator: the layer-3 serving stack that exposes
//! the Goldschmidt divider as a batched request service, through the v2
//! ticketed request plane.
//!
//! Request path (all rust, no Python):
//!
//! ```text
//! clients ──submit / submit_batch──> shard pick: hash(op, format,
//!            │ (Ticket / BatchTicket:             handle shard key)
//!            │  shared completion slots,      lock-free SubmitRing
//!            │  no channel per request)       (one CAS + one publish;
//!            │                                 EventCount parking)
//!            │                            shard dispatcher ──> per-
//!            │                                       (op, format)
//!            │                                       queues
//!            │                                DynamicBatcher
//!            │                                (per-(op, format)
//!            │                                 size/age policy,
//!            │                                 deadline shedding,
//!            │                                 capability-ladder
//!            │                                 padding)
//!            │                 ready queue ──(peer steal on imbalance:
//!            │                               whole batches only)
//!            │                        DispatchPlane (crate::dispatch):
//!            │                          per-batch backend selection —
//!            │                          static or latency policy,
//!            │                          circuit breakers, probes,
//!            │                          rider-invisible failover
//!            │                              per-shard × backend worker
//!            │                              pools: Executor::execute_into
//!            │                                (caller-owned output
//!            │                                plane; batch kernels,
//!            │                                u128 baseline, scalar
//!            │                                reference or PJRT)
//!            └───── tickets resolve: Response | typed ServiceError
//! ```
//!
//! The coordinator runs as N independent shards
//! ([`ServiceConfig::shards`](service::ServiceConfig)): each owns its
//! submit ring, batcher, plane pool, metrics slice, and worker set, so
//! submitting threads on different shards share no queue state at all.
//! A handle clone carries a fresh shard key, spreading connections and
//! client threads across shards; [`ServiceMetrics`] merges the
//! per-shard slices back into one [`MetricsSnapshot`] for reporting.
//!
//! Every request carries a format-tagged [`Value`] pair (or, vectored,
//! a whole plane of raw format words); the (op, IEEE format) pair is
//! the routing key end to end — queues, batches, executor dispatch and
//! metrics are all sliced by it, so an f16 inference workload and an
//! f64 scientific workload batch independently on the same service,
//! under independently tunable batching budgets.
//!
//! What v2 of the request plane guarantees:
//!
//! * **Ticketed completion** — `submit` returns a [`Ticket`] backed by
//!   a shared slot; `submit_batch` returns one [`BatchTicket`] for a
//!   whole operand plane, which travels the router as a pre-formed
//!   group (batch locality preserved, split only at executable-ladder
//!   boundaries). No `mpsc::channel` per request.
//! * **Typed failure surface** — every outcome is a
//!   [`ServiceError`]: `Rejected` at submit time (validation and
//!   capability misses), `Overloaded` from the non-blocking submit
//!   family, `ExecFailed` carrying the backend's own message,
//!   `Deadline` for shed work, `Shutdown` for teardown. Nothing is
//!   signalled by dropping a sender.
//! * **Deadlines** — `submit_value_deadline` / `submit_batch_deadline`
//!   attach a completion deadline, gated by **admission control**: a
//!   budget the slot's queue-delay estimate already exceeds fails at
//!   submit time with `ServiceError::Deadline` (counted as
//!   `admission_rejected`), before any queueing. Admitted work whose
//!   deadline expires in the queue is shed by the dispatcher (counted
//!   in [`Metrics`] as `shed`), not executed.
//! * **Width-true planes** — operand and result planes are
//!   [`PlaneBuf`](crate::formats::PlaneBuf)s at the format's native
//!   word (u32 for f16/bf16, u64 for f32/f64), recycled per width
//!   through the [`PlanePool`], halving half-precision flush traffic.
//! * **Capability negotiation** — every backend's
//!   [`BackendCaps`](crate::runtime::BackendCaps) table (per-(op,
//!   format) support + batch ladders + plane widths) is read once at
//!   startup; a routed service
//!   ([`FpuService::start_routed`](service::FpuService::start_routed))
//!   merges them into a [`RoutingTable`](crate::dispatch::RoutingTable)
//!   whose union drives submit-time rejection while each batch is
//!   padded and plane-shaped for the backend that actually serves it.
//! * **Multi-backend dispatch** — batches route per (op, format) to
//!   health-tracked per-backend worker pools (static preference or
//!   measured-latency policy); a failed batch re-routes down the
//!   candidate chain before any rider sees an error, and an open
//!   circuit breaker is probed back to life (see [`crate::dispatch`]).
//!
//! # Example
//!
//! ```
//! use goldschmidt::coordinator::{FormatKind, FpuService, OpKind, ServiceConfig};
//! use goldschmidt::runtime::NativeExecutor;
//!
//! let svc = FpuService::start(ServiceConfig::default(), || {
//!     Ok(Box::new(NativeExecutor::with_defaults()) as _)
//! })
//! .unwrap();
//! let h = svc.handle();
//!
//! // one request: a ticket backed by a shared completion slot
//! let ticket = h.submit(OpKind::Divide, 10.0, 4.0).unwrap();
//! assert_eq!(ticket.wait().unwrap().value.f32(), 2.5);
//!
//! // vectored submission: one ticket for a whole operand plane
//! let xs: Vec<u64> = [9.0f32, 16.0, 25.0].iter().map(|v| v.to_bits() as u64).collect();
//! let batch = h.submit_batch(OpKind::Sqrt, FormatKind::F32, &xs, &[]).unwrap();
//! let roots: Vec<f32> = batch.wait().unwrap().values().map(|v| v.f32()).collect();
//! assert_eq!(roots, vec![3.0, 4.0, 5.0]);
//!
//! svc.shutdown();
//! ```
//!
//! * [`request`] — op kinds, [`ServiceError`], [`Response`], and the
//!   [`WorkItem`] unit (one request or a group window) the queues move;
//!   format tags re-exported from [`crate::formats`].
//! * [`ticket`] — [`Ticket`] / [`BatchTicket`] and their shared
//!   completion slots.
//! * [`router`] — fans work items out to per-(op, format) queues
//!   (lane conservation and format purity are property-tested).
//! * [`ring`] — the bounded lock-free MPSC [`SubmitRing`](ring::SubmitRing)
//!   each shard consumes from, plus the [`EventCount`](ring::EventCount)
//!   the shard dispatcher parks on when its ring runs dry.
//! * [`batcher`] — dynamic batching: flush on size, age, or deadline
//!   arrival, per-(op, format) policy overrides, padding to the
//!   backend's capability ladder with the format's `1.0`, operand-plane
//!   recycling through the [`PlanePool`].
//! * [`metrics`] — always-on counters + latency histograms, per
//!   (op, format) with per-op aggregates; errors and deadline sheds
//!   counted separately.
//! * [`journal`] — the append-only CRC-guarded request journal behind
//!   `submit_batch_durable` / `poll_job`: a `Pending` record per
//!   durable submission, a `Done`/`Failed` record per outcome, and
//!   torn-tail truncation on open so a crash mid-append never poisons
//!   the file. `FpuService::start*` replays still-`Pending` records
//!   through the normal submit path, exactly once.
//! * [`service`] — the threaded service: fail-fast startup, lifecycle,
//!   backpressure, supervised worker pools (a panicking worker's batch
//!   fails over; the supervisor respawns the dead worker with capped
//!   backoff and marks the pool degraded when respawn keeps failing),
//!   deterministic fault-injection hooks ([`crate::fault`]).

pub mod batcher;
pub mod journal;
pub mod metrics;
pub mod request;
pub mod ring;
pub mod router;
pub mod service;
pub mod ticket;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher, PlanePool, PolicyOverride};
pub use journal::{coalesce, JobStatus, Journal, JournalRecord};
pub use metrics::{Metrics, MetricsSnapshot, OpFormatSnapshot, OpSnapshot};
pub use request::{FormatKind, OpKind, Response, ServiceError, Value, WorkItem};
pub use router::Router;
pub use service::{
    FpuService, JobPoll, NetPlaneStats, ServiceConfig, ServiceHandle, ServiceMetrics, ShardStat,
};
pub use ticket::{BatchResponse, BatchTicket, Ticket};
