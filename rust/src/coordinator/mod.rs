//! The FPU-service coordinator: the layer-3 serving stack that exposes
//! the Goldschmidt divider as a batched request service.
//!
//! Request path (all rust, no Python):
//!
//! ```text
//! clients ──submit()──> bounded queue ──> Router ──> per-op queues
//!                                              │
//!                                       DynamicBatcher (size/age policy,
//!                                              │        ladder padding)
//!                                     worker pool: Executor::execute
//!                                              │  (PJRT AOT executables)
//!                                        per-request responses
//! ```
//!
//! * [`request`] — request/response types and op kinds.
//! * [`router`] — fans requests out to per-op queues (conservation is
//!   property-tested).
//! * [`batcher`] — dynamic batching: flush on max-size or max-age,
//!   padding to the artifact batch ladder.
//! * [`metrics`] — always-on counters + latency histograms.
//! * [`service`] — the threaded service: lifecycle, backpressure,
//!   worker pool.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod service;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{OpKind, Request, Response};
pub use router::Router;
pub use service::{FpuService, ServiceConfig, ServiceHandle};
