//! Tickets: the client-side completion handles of the v2 request plane.
//!
//! A [`Ticket`] (one lane) or [`BatchTicket`] (a vectored submission's
//! worth of lanes) is backed by one shared [`TicketCore`] — a
//! mutex/condvar completion slot allocated **once per submit call**, not
//! once per lane, and written in place by the executing worker. This
//! replaces the v1 per-request `mpsc::channel`: no channel allocation on
//! the hot path, and failures arrive as typed
//! [`ServiceError`](super::request::ServiceError)s instead of a dropped
//! sender.
//!
//! A batch submission's lanes may be executed across several executor
//! batches (the dynamic batcher splits oversized groups at ladder
//! boundaries); each completed range fills its slice of the slot and the
//! final range wakes the waiter.
//!
//! Tickets are shard-agnostic: completion is a write into the shared
//! slot plus a condvar wake, so it does not matter whether the batch
//! retired on its home shard's workers or was stolen by a peer — the
//! waiter sees the same bits either way.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::formats::{FormatKind, Value};

use super::request::{Response, ServiceError};

/// Result lanes: a single word inline, or a plane for batch tickets
/// (no `Vec` for the single-request fast path).
#[derive(Debug)]
enum LaneStore {
    One(u64),
    Many(Vec<u64>),
}

#[derive(Debug)]
struct CoreState {
    store: LaneStore,
    /// Lanes resolved so far (completed or failed).
    filled: usize,
    /// First failure, if any — the whole ticket then errors.
    err: Option<ServiceError>,
    /// Worst end-to-end latency over the ticket's lanes (ns).
    latency_ns: u64,
    /// Largest padded executor batch any lane rode in.
    batch_size: usize,
}

/// The shared completion slot behind [`Ticket`] / [`BatchTicket`]: one
/// allocation per submit call, holding every result lane.
#[derive(Debug)]
pub(crate) struct TicketCore {
    lanes: usize,
    state: Mutex<CoreState>,
    cv: Condvar,
}

impl TicketCore {
    /// New slot expecting `lanes >= 1` result lanes.
    pub(crate) fn new(lanes: usize) -> Arc<Self> {
        assert!(lanes >= 1, "a ticket needs at least one lane");
        let store =
            if lanes == 1 { LaneStore::One(0) } else { LaneStore::Many(vec![0; lanes]) };
        Arc::new(Self {
            lanes,
            state: Mutex::new(CoreState {
                store,
                filled: 0,
                err: None,
                latency_ns: 0,
                batch_size: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Fill result lanes `[base, base + values.len())`; wakes the waiter
    /// once every lane of the ticket is resolved.
    pub(crate) fn complete_range(
        &self,
        base: usize,
        values: &[u64],
        latency_ns: u64,
        batch_size: usize,
    ) {
        let mut s = self.state.lock().expect("ticket lock poisoned");
        match &mut s.store {
            LaneStore::One(slot) => *slot = values[0],
            LaneStore::Many(v) => v[base..base + values.len()].copy_from_slice(values),
        }
        s.filled += values.len();
        if latency_ns > s.latency_ns {
            s.latency_ns = latency_ns;
        }
        if batch_size > s.batch_size {
            s.batch_size = batch_size;
        }
        if s.filled >= self.lanes {
            self.cv.notify_all();
        }
    }

    /// Resolve `lanes` lanes as failed. The first recorded error wins
    /// (a ticket either yields every value or one typed error).
    pub(crate) fn fail_range(&self, lanes: usize, err: ServiceError) {
        let mut s = self.state.lock().expect("ticket lock poisoned");
        s.filled += lanes;
        if s.err.is_none() {
            s.err = Some(err);
        }
        if s.filled >= self.lanes {
            self.cv.notify_all();
        }
    }

    fn wait_done(&self) -> MutexGuard<'_, CoreState> {
        let mut s = self.state.lock().expect("ticket lock poisoned");
        while s.filled < self.lanes {
            s = self.cv.wait(s).expect("ticket lock poisoned");
        }
        s
    }

    fn poll_done(&self) -> Option<MutexGuard<'_, CoreState>> {
        let s = self.state.lock().expect("ticket lock poisoned");
        if s.filled < self.lanes {
            None
        } else {
            Some(s)
        }
    }
}

/// Completion handle for one submitted request.
#[derive(Debug)]
pub struct Ticket {
    core: Arc<TicketCore>,
    id: u64,
    format: FormatKind,
}

impl Ticket {
    pub(crate) fn new(core: Arc<TicketCore>, id: u64, format: FormatKind) -> Self {
        Self { core, id, format }
    }

    /// The request id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The format the response will be tagged with.
    pub fn format(&self) -> FormatKind {
        self.format
    }

    fn resolve(s: &CoreState, id: u64, format: FormatKind) -> Result<Response, ServiceError> {
        if let Some(e) = &s.err {
            return Err(e.clone());
        }
        let bits = match &s.store {
            LaneStore::One(v) => *v,
            LaneStore::Many(v) => v[0],
        };
        Ok(Response {
            id,
            value: Value::from_bits(format, bits),
            latency_ns: s.latency_ns,
            batch_size: s.batch_size,
        })
    }

    /// Block until the request resolves: the [`Response`] on success, a
    /// typed [`ServiceError`] otherwise.
    pub fn wait(self) -> Result<Response, ServiceError> {
        let s = self.core.wait_done();
        Self::resolve(&s, self.id, self.format)
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<Response, ServiceError>> {
        self.core.poll_done().map(|s| Self::resolve(&s, self.id, self.format))
    }
}

/// Completion handle for one vectored submission
/// ([`ServiceHandle::submit_batch`](super::service::ServiceHandle::submit_batch)).
#[derive(Debug)]
pub struct BatchTicket {
    core: Arc<TicketCore>,
    id: u64,
    format: FormatKind,
    lanes: usize,
}

impl BatchTicket {
    pub(crate) fn new(core: Arc<TicketCore>, id: u64, format: FormatKind, lanes: usize) -> Self {
        Self { core, id, format, lanes }
    }

    /// The group id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Number of result lanes the ticket will yield.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The format every result lane is tagged with.
    pub fn format(&self) -> FormatKind {
        self.format
    }

    /// Non-blocking poll: `true` once every lane is resolved.
    pub fn is_done(&self) -> bool {
        self.core.poll_done().is_some()
    }

    /// Block until every lane resolves. Lanes keep submission order; a
    /// failure of any lane fails the whole ticket with the first error.
    pub fn wait(self) -> Result<BatchResponse, ServiceError> {
        let mut s = self.core.wait_done();
        if let Some(e) = &s.err {
            return Err(e.clone());
        }
        let bits = match &mut s.store {
            LaneStore::One(v) => vec![*v],
            LaneStore::Many(v) => std::mem::take(v),
        };
        Ok(BatchResponse {
            id: self.id,
            format: self.format,
            bits,
            latency_ns: s.latency_ns,
            batch_size: s.batch_size,
        })
    }
}

/// Results of a vectored submission, in submission order.
#[derive(Clone, Debug)]
pub struct BatchResponse {
    /// Echoes the group id.
    pub id: u64,
    /// Format every lane is encoded in.
    pub format: FormatKind,
    /// Raw result words, one per submitted lane.
    pub bits: Vec<u64>,
    /// Worst end-to-end latency across the group's lanes (ns).
    pub latency_ns: u64,
    /// Largest padded executor batch any lane rode in.
    pub batch_size: usize,
}

impl BatchResponse {
    /// Number of result lanes.
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    /// True when the response carries no lanes (cannot happen for a
    /// successfully submitted batch; provided for completeness).
    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// One lane as a format-tagged [`Value`].
    pub fn value(&self, lane: usize) -> Value {
        Value::from_bits(self.format, self.bits[lane])
    }

    /// All lanes as format-tagged [`Value`]s, in submission order.
    pub fn values(&self) -> impl Iterator<Item = Value> + '_ {
        let format = self.format;
        self.bits.iter().map(move |&w| Value::from_bits(format, w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_lane_ticket_round_trip() {
        let core = TicketCore::new(1);
        let ticket = Ticket::new(core.clone(), 7, FormatKind::F32);
        assert!(ticket.try_wait().is_none());
        core.complete_range(0, &[2.5f32.to_bits() as u64], 1234, 64);
        let resp = ticket.try_wait().expect("done").expect("ok");
        assert_eq!(resp.id, 7);
        assert_eq!(resp.value.f32(), 2.5);
        assert_eq!(resp.latency_ns, 1234);
        assert_eq!(resp.batch_size, 64);
    }

    #[test]
    fn batch_ticket_fills_across_ranges() {
        let core = TicketCore::new(4);
        let ticket = BatchTicket::new(core.clone(), 9, FormatKind::F64, 4);
        assert!(!ticket.is_done());
        core.complete_range(0, &[1, 2], 100, 64);
        assert!(!ticket.is_done());
        core.complete_range(2, &[3, 4], 300, 256);
        assert!(ticket.is_done());
        let resp = ticket.wait().expect("ok");
        assert_eq!(resp.bits, vec![1, 2, 3, 4]);
        assert_eq!(resp.latency_ns, 300); // worst range wins
        assert_eq!(resp.batch_size, 256);
        assert_eq!(resp.len(), 4);
    }

    #[test]
    fn failure_of_any_range_fails_the_ticket() {
        let core = TicketCore::new(3);
        let ticket = BatchTicket::new(core.clone(), 1, FormatKind::F32, 3);
        core.complete_range(0, &[11], 10, 64);
        core.fail_range(2, ServiceError::ExecFailed { backend: "boom".into() });
        match ticket.wait() {
            Err(ServiceError::ExecFailed { backend }) => assert_eq!(backend, "boom"),
            other => panic!("expected ExecFailed, got {other:?}"),
        }
    }

    #[test]
    fn wait_blocks_until_completion_from_another_thread() {
        let core = TicketCore::new(1);
        let ticket = Ticket::new(core.clone(), 0, FormatKind::F32);
        let filler = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            core.complete_range(0, &[1.0f32.to_bits() as u64], 42, 1);
        });
        assert_eq!(ticket.wait().expect("ok").value.f32(), 1.0);
        filler.join().unwrap();
    }

    #[test]
    fn first_error_wins() {
        let core = TicketCore::new(2);
        let ticket = BatchTicket::new(core.clone(), 0, FormatKind::F16, 2);
        core.fail_range(1, ServiceError::Deadline);
        core.fail_range(1, ServiceError::Shutdown);
        assert_eq!(ticket.wait().unwrap_err(), ServiceError::Deadline);
    }
}
