//! Dynamic batcher: decides *when* to flush a per-(op, format) queue
//! into one executor batch and *how big* that batch is.
//!
//! Policy (the standard serving trade-off):
//! * flush a queue when it holds `max_batch` requests, or
//! * when its oldest request has waited `max_wait`, or
//! * when `flush_all` is requested (drain/shutdown).
//!
//! The formed batch is padded (with the neutral operand `1.0` *in the
//! batch's format*) up to the executor's batch ladder — AOT graphs have
//! fixed shapes, so a 70-request flush rides the 256-wide executable.
//! Operands travel as raw `u64` plane words (format-uniform per batch,
//! guaranteed by the router's per-(op, format) queues). Padding waste
//! is tracked in metrics; the ladder itself comes from the artifact
//! manifest, per (op, format).

use std::time::{Duration, Instant};

use super::request::{FormatKind, op_format_slot, OP_FORMAT_SLOTS, OpKind, Request};
use super::router::Router;

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush threshold: batch is formed at this many queued requests.
    pub max_batch: usize,
    /// Age threshold: flush whatever is queued once the oldest request
    /// has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 1024, max_wait: Duration::from_micros(200) }
    }
}

/// A formed batch, ready for an executor.
#[derive(Debug)]
pub struct Batch {
    /// Operation.
    pub op: OpKind,
    /// IEEE format of every lane (the router guarantees purity).
    pub format: FormatKind,
    /// The requests riding this batch (in FIFO order).
    pub requests: Vec<Request>,
    /// Padded operand plane as raw format words (`b` only meaningful
    /// for divide).
    pub a: Vec<u64>,
    /// Second operand plane (padded), divide only.
    pub b: Vec<u64>,
    /// Padded (executable) size; `requests.len() <= padded`.
    pub padded: usize,
}

impl Batch {
    /// Live (non-padding) size.
    pub fn live(&self) -> usize {
        self.requests.len()
    }

    /// Padding fraction (0 = perfectly full; an empty batch wastes
    /// nothing rather than dividing by zero).
    pub fn waste(&self) -> f64 {
        if self.padded == 0 {
            0.0
        } else {
            1.0 - self.live() as f64 / self.padded as f64
        }
    }
}

/// The dynamic batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    config: BatcherConfig,
    /// Per-(op, format) ladder of available executable batch sizes
    /// (ascending), indexed by the shared routing-slot layout.
    ladders: [Vec<usize>; OP_FORMAT_SLOTS],
}

impl DynamicBatcher {
    /// New batcher over the given per-(op, format) batch ladders.
    pub fn new(
        config: BatcherConfig,
        ladder_of: impl Fn(OpKind, FormatKind) -> Vec<usize>,
    ) -> Self {
        let mut ladders: [Vec<usize>; OP_FORMAT_SLOTS] = std::array::from_fn(|_| Vec::new());
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                ladders[op_format_slot(op, format)] = ladder_of(op, format);
            }
        }
        Self { config, ladders }
    }

    /// The config in force.
    pub fn config(&self) -> &BatcherConfig {
        &self.config
    }

    fn ladder(&self, op: OpKind, format: FormatKind) -> &[usize] {
        &self.ladders[op_format_slot(op, format)]
    }

    /// Largest executable size for an (op, format) pair (the flush cap).
    fn cap(&self, op: OpKind, format: FormatKind) -> usize {
        self.ladder(op, format)
            .last()
            .copied()
            .unwrap_or(self.config.max_batch)
            .min(self.config.max_batch)
    }

    /// Smallest ladder size >= n (or the cap when n exceeds it).
    fn pad_to(&self, op: OpKind, format: FormatKind, n: usize) -> usize {
        let ladder = self.ladder(op, format);
        ladder.iter().copied().find(|&b| b >= n).or(ladder.last().copied()).unwrap_or(n)
    }

    /// Decide whether an (op, format) queue should flush now.
    pub fn should_flush(
        &self,
        router: &Router,
        op: OpKind,
        format: FormatKind,
        now: Instant,
    ) -> bool {
        let len = router.len(op, format);
        if len == 0 {
            return false;
        }
        if len >= self.cap(op, format) {
            return true;
        }
        match router.oldest_enqueue_in(op, format) {
            Some(oldest) => now.duration_since(oldest) >= self.config.max_wait,
            None => false,
        }
    }

    /// Form one batch from an (op, format) queue (up to the cap),
    /// padding operand planes to the ladder with the format's `1.0`.
    /// Returns `None` when the queue is empty.
    pub fn form_batch(
        &self,
        router: &mut Router,
        op: OpKind,
        format: FormatKind,
    ) -> Option<Batch> {
        let cap = self.cap(op, format);
        let requests = router.drain(op, format, cap);
        if requests.is_empty() {
            return None;
        }
        let padded = self.pad_to(op, format, requests.len());
        let mut a = Vec::with_capacity(padded);
        let mut b = Vec::with_capacity(padded);
        for r in &requests {
            a.push(r.a.bits());
            b.push(r.b.bits());
        }
        // pad with neutral operands: 1.0 / 1.0 stays in-domain for every op
        let one = format.one_bits();
        a.resize(padded, one);
        b.resize(padded, one);
        Some(Batch { op, format, requests, a, b, padded })
    }

    /// Form batches for every (op, format) queue that should flush at
    /// `now`.
    pub fn ready_batches(&self, router: &mut Router, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                while self.should_flush(router, op, format, now) {
                    match self.form_batch(router, op, format) {
                        Some(b) => out.push(b),
                        None => break,
                    }
                }
            }
        }
        out
    }

    /// Unconditionally drain everything (shutdown path). Queues that
    /// are already empty form no batch.
    pub fn flush_all(&self, router: &mut Router) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                if router.len(op, format) == 0 {
                    continue; // skip forming empty batches
                }
                while let Some(b) = self.form_batch(router, op, format) {
                    out.push(b);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use crate::formats::Value;
    use std::sync::mpsc;

    fn req_at(id: u64, op: OpKind, format: FormatKind, enqueued_at: Instant) -> Request {
        let (tx, rx) = mpsc::channel();
        std::mem::forget(rx);
        Request {
            id,
            op,
            a: Value::from_f64(format, id as f64 + 2.0),
            b: Value::from_f64(format, 2.0),
            enqueued_at,
            reply: tx,
        }
    }

    fn req_fmt(id: u64, op: OpKind, format: FormatKind) -> Request {
        req_at(id, op, format, Instant::now())
    }

    fn req(id: u64, op: OpKind) -> Request {
        req_fmt(id, op, FormatKind::F32)
    }

    fn batcher(max_batch: usize, max_wait_us: u64) -> DynamicBatcher {
        DynamicBatcher::new(
            BatcherConfig { max_batch, max_wait: Duration::from_micros(max_wait_us) },
            |_, _| vec![64, 256, 1024],
        )
    }

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn no_flush_when_empty() {
        let b = batcher(256, 100);
        let r = Router::new();
        assert!(!b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
    }

    #[test]
    fn flushes_at_cap() {
        let b = batcher(256, 1_000_000); // effectively no age flush
        let mut r = Router::new();
        for i in 0..255 {
            r.route(req(i, OpKind::Divide));
        }
        assert!(!b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
        r.route(req(255, OpKind::Divide));
        assert!(b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
    }

    #[test]
    fn flushes_on_age() {
        let b = batcher(1024, 0); // zero wait: always stale
        let mut r = Router::new();
        r.route(req(1, OpKind::Sqrt));
        assert!(b.should_flush(&r, OpKind::Sqrt, F32, Instant::now()));
    }

    #[test]
    fn age_flush_is_per_queue() {
        // a stale f64 queue must not force the fresh f32 queue to flush
        let b = batcher(1024, 500);
        let mut r = Router::new();
        let stale = Instant::now() - Duration::from_millis(10);
        r.route(req_at(1, OpKind::Divide, FormatKind::F64, stale));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F32));
        let now = Instant::now();
        assert!(b.should_flush(&r, OpKind::Divide, FormatKind::F64, now));
        assert!(!b.should_flush(&r, OpKind::Divide, FormatKind::F32, now));
        let ready = b.ready_batches(&mut r, now);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].format, FormatKind::F64);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
    }

    #[test]
    fn max_wait_flush_preserves_fifo_order() {
        // two age-triggered flushes from one queue: the older requests
        // must ride the earlier batch, in submission order
        let b = batcher(4, 0);
        let mut r = Router::new();
        for i in 0..6 {
            r.route(req(i, OpKind::Divide));
        }
        let batches = b.ready_batches(&mut r, Instant::now());
        assert_eq!(batches.len(), 2);
        assert_eq!(
            batches[0].requests.iter().map(|x| x.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(batches[1].requests.iter().map(|x| x.id).collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn pads_to_ladder() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..70 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = b.form_batch(&mut r, OpKind::Divide, F32).unwrap();
        assert_eq!(batch.live(), 70);
        assert_eq!(batch.padded, 256);
        assert_eq!(batch.a.len(), 256);
        assert_eq!(batch.b.len(), 256);
        // padding is the neutral operand in the batch format
        assert!(batch.a[70..].iter().all(|&x| x == F32.one_bits()));
        assert!((batch.waste() - (1.0 - 70.0 / 256.0)).abs() < 1e-12);
    }

    #[test]
    fn pads_with_format_specific_one() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..3 {
            r.route(req_fmt(i, OpKind::Divide, FormatKind::F16));
        }
        let batch = b.form_batch(&mut r, OpKind::Divide, FormatKind::F16).unwrap();
        assert_eq!(batch.format, FormatKind::F16);
        assert_eq!(batch.padded, 64);
        assert!(batch.a[3..].iter().all(|&x| x == 0x3C00)); // f16 1.0
        assert!(batch.b[3..].iter().all(|&x| x == 0x3C00));
    }

    #[test]
    fn empty_batch_wastes_nothing() {
        // padded == 0 must not divide by zero (guard, not NaN)
        let batch = Batch {
            op: OpKind::Divide,
            format: F32,
            requests: Vec::new(),
            a: Vec::new(),
            b: Vec::new(),
            padded: 0,
        };
        assert_eq!(batch.waste(), 0.0);
    }

    #[test]
    fn batch_preserves_fifo_and_operands() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..5 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = b.form_batch(&mut r, OpKind::Divide, F32).unwrap();
        for (i, rq) in batch.requests.iter().enumerate() {
            assert_eq!(rq.id, i as u64);
            assert_eq!(batch.a[i], (i as f32 + 2.0).to_bits() as u64);
        }
    }

    #[test]
    fn oversized_queue_splits_into_multiple_batches() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..2500 {
            r.route(req(i, OpKind::Divide));
        }
        let batches = b.ready_batches(&mut r, Instant::now());
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].live(), 1024);
        assert_eq!(batches[1].live(), 1024);
        assert_eq!(batches[2].live(), 452);
        assert!(r.is_empty());
    }

    #[test]
    fn formats_batch_independently() {
        // the same op in two formats never shares a batch
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..10 {
            let fmt = if i % 2 == 0 { FormatKind::F32 } else { FormatKind::F64 };
            r.route(req_fmt(i, OpKind::Divide, fmt));
        }
        let batches = b.ready_batches(&mut r, Instant::now());
        assert_eq!(batches.len(), 2);
        for batch in &batches {
            assert_eq!(batch.live(), 5);
            assert!(batch.requests.iter().all(|x| x.format() == batch.format));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn never_exceeds_cap_property() {
        check::property("batch size <= cap, conservation", |g| {
            let cap = [64usize, 256, 1024][g.usize_in(0, 3)];
            let b = batcher(cap, 0);
            let mut r = Router::new();
            let n = g.usize_in(0, 3000);
            for i in 0..n {
                let fmt = *g.pick(&FormatKind::ALL);
                r.route(req_fmt(i as u64, OpKind::Divide, fmt));
            }
            let batches = b.flush_all(&mut r);
            let total: usize = batches.iter().map(|x| x.live()).sum();
            ensure(total == n, format!("lost requests: {total} != {n}"))?;
            for batch in &batches {
                if batch.live() == 0 {
                    return Err("flush_all formed an empty batch".into());
                }
                if batch.live() > cap {
                    return Err(format!("batch {} > cap {cap}", batch.live()));
                }
                if batch.padded < batch.live() {
                    return Err("padded < live".into());
                }
                if batch.requests.iter().any(|x| x.format() != batch.format) {
                    return Err("mixed formats in one batch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flush_all_drains_every_op_and_format() {
        let b = batcher(256, 1_000_000);
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Rsqrt));
        r.route(req_fmt(4, OpKind::Divide, FormatKind::BF16));
        let batches = b.flush_all(&mut r);
        assert_eq!(batches.len(), 4);
        assert!(batches.iter().all(|x| x.live() > 0));
        assert!(r.is_empty());
    }
}
