//! Dynamic batcher: decides *when* to flush a per-op queue into one
//! executor batch and *how big* that batch is.
//!
//! Policy (the standard serving trade-off):
//! * flush an op queue when it holds `max_batch` requests, or
//! * when its oldest request has waited `max_wait`, or
//! * when `flush_all` is requested (drain/shutdown).
//!
//! The formed batch is padded (with the neutral operand 1.0) up to the
//! executor's batch ladder — AOT graphs have fixed shapes, so a
//! 70-request flush rides the 256-wide executable. Padding waste is
//! tracked in metrics; the ladder itself comes from the artifact
//! manifest.

use std::time::{Duration, Instant};

use super::request::{OpKind, Request};
use super::router::Router;

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush threshold: batch is formed at this many queued requests.
    pub max_batch: usize,
    /// Age threshold: flush whatever is queued once the oldest request
    /// has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 1024, max_wait: Duration::from_micros(200) }
    }
}

/// A formed batch, ready for an executor.
#[derive(Debug)]
pub struct Batch {
    /// Operation.
    pub op: OpKind,
    /// The requests riding this batch (in FIFO order).
    pub requests: Vec<Request>,
    /// Padded operand arrays (`b` only meaningful for divide).
    pub a: Vec<f32>,
    /// Second operand array (padded), divide only.
    pub b: Vec<f32>,
    /// Padded (executable) size; `requests.len() <= padded`.
    pub padded: usize,
}

impl Batch {
    /// Live (non-padding) size.
    pub fn live(&self) -> usize {
        self.requests.len()
    }

    /// Padding fraction (0 = perfectly full).
    pub fn waste(&self) -> f64 {
        1.0 - self.live() as f64 / self.padded as f64
    }
}

/// The dynamic batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    config: BatcherConfig,
    /// Per-op ladder of available executable batch sizes (ascending).
    ladders: [(OpKind, Vec<usize>); 3],
}

impl DynamicBatcher {
    /// New batcher over the given per-op batch ladders.
    pub fn new(config: BatcherConfig, ladder_of: impl Fn(OpKind) -> Vec<usize>) -> Self {
        let ladders = [
            (OpKind::Divide, ladder_of(OpKind::Divide)),
            (OpKind::Sqrt, ladder_of(OpKind::Sqrt)),
            (OpKind::Rsqrt, ladder_of(OpKind::Rsqrt)),
        ];
        Self { config, ladders }
    }

    /// The config in force.
    pub fn config(&self) -> &BatcherConfig {
        &self.config
    }

    fn ladder(&self, op: OpKind) -> &[usize] {
        &self.ladders.iter().find(|(o, _)| *o == op).expect("all ops present").1
    }

    /// Largest executable size for an op (the flush cap).
    fn cap(&self, op: OpKind) -> usize {
        self.ladder(op).last().copied().unwrap_or(self.config.max_batch).min(self.config.max_batch)
    }

    /// Smallest ladder size >= n (or the cap when n exceeds it).
    fn pad_to(&self, op: OpKind, n: usize) -> usize {
        let ladder = self.ladder(op);
        ladder.iter().copied().find(|&b| b >= n).or(ladder.last().copied()).unwrap_or(n)
    }

    /// Decide whether an op queue should flush now.
    pub fn should_flush(&self, router: &Router, op: OpKind, now: Instant) -> bool {
        let len = router.len(op);
        if len == 0 {
            return false;
        }
        if len >= self.cap(op) {
            return true;
        }
        match router.oldest_enqueue() {
            Some(oldest) => now.duration_since(oldest) >= self.config.max_wait,
            None => false,
        }
    }

    /// Form one batch from an op queue (up to the cap), padding operands
    /// to the ladder. Returns `None` when the queue is empty.
    pub fn form_batch(&self, router: &mut Router, op: OpKind) -> Option<Batch> {
        let cap = self.cap(op);
        let requests = router.drain(op, cap);
        if requests.is_empty() {
            return None;
        }
        let padded = self.pad_to(op, requests.len());
        let mut a = Vec::with_capacity(padded);
        let mut b = Vec::with_capacity(padded);
        for r in &requests {
            a.push(r.a);
            b.push(r.b);
        }
        // pad with neutral operands: 1.0 / 1.0 stays in-domain for every op
        a.resize(padded, 1.0);
        b.resize(padded, 1.0);
        Some(Batch { op, requests, a, b, padded })
    }

    /// Form batches for every op that should flush at `now`.
    pub fn ready_batches(&self, router: &mut Router, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            while self.should_flush(router, op, now) {
                match self.form_batch(router, op) {
                    Some(b) => out.push(b),
                    None => break,
                }
            }
        }
        out
    }

    /// Unconditionally drain everything (shutdown path).
    pub fn flush_all(&self, router: &mut Router) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            while let Some(b) = self.form_batch(router, op) {
                out.push(b);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use std::sync::mpsc;

    fn req(id: u64, op: OpKind) -> Request {
        let (tx, rx) = mpsc::channel();
        std::mem::forget(rx);
        Request { id, op, a: id as f32 + 2.0, b: 2.0, enqueued_at: Instant::now(), reply: tx }
    }

    fn batcher(max_batch: usize, max_wait_us: u64) -> DynamicBatcher {
        DynamicBatcher::new(
            BatcherConfig { max_batch, max_wait: Duration::from_micros(max_wait_us) },
            |_| vec![64, 256, 1024],
        )
    }

    #[test]
    fn no_flush_when_empty() {
        let b = batcher(256, 100);
        let r = Router::new();
        assert!(!b.should_flush(&r, OpKind::Divide, Instant::now()));
    }

    #[test]
    fn flushes_at_cap() {
        let b = batcher(256, 1_000_000); // effectively no age flush
        let mut r = Router::new();
        for i in 0..255 {
            r.route(req(i, OpKind::Divide));
        }
        assert!(!b.should_flush(&r, OpKind::Divide, Instant::now()));
        r.route(req(255, OpKind::Divide));
        assert!(b.should_flush(&r, OpKind::Divide, Instant::now()));
    }

    #[test]
    fn flushes_on_age() {
        let b = batcher(1024, 0); // zero wait: always stale
        let mut r = Router::new();
        r.route(req(1, OpKind::Sqrt));
        assert!(b.should_flush(&r, OpKind::Sqrt, Instant::now()));
    }

    #[test]
    fn pads_to_ladder() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..70 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = b.form_batch(&mut r, OpKind::Divide).unwrap();
        assert_eq!(batch.live(), 70);
        assert_eq!(batch.padded, 256);
        assert_eq!(batch.a.len(), 256);
        assert_eq!(batch.b.len(), 256);
        // padding is the neutral operand
        assert!(batch.a[70..].iter().all(|&x| x == 1.0));
        assert!((batch.waste() - (1.0 - 70.0 / 256.0)).abs() < 1e-12);
    }

    #[test]
    fn batch_preserves_fifo_and_operands() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..5 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = b.form_batch(&mut r, OpKind::Divide).unwrap();
        for (i, rq) in batch.requests.iter().enumerate() {
            assert_eq!(rq.id, i as u64);
            assert_eq!(batch.a[i], i as f32 + 2.0);
        }
    }

    #[test]
    fn oversized_queue_splits_into_multiple_batches() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..2500 {
            r.route(req(i, OpKind::Divide));
        }
        let batches = b.ready_batches(&mut r, Instant::now());
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].live(), 1024);
        assert_eq!(batches[1].live(), 1024);
        assert_eq!(batches[2].live(), 452);
        assert!(r.is_empty());
    }

    #[test]
    fn never_exceeds_cap_property() {
        check::property("batch size <= cap, conservation", |g| {
            let cap = [64usize, 256, 1024][g.usize_in(0, 3)];
            let b = batcher(cap, 0);
            let mut r = Router::new();
            let n = g.usize_in(0, 3000);
            for i in 0..n {
                r.route(req(i as u64, OpKind::Divide));
            }
            let batches = b.flush_all(&mut r);
            let total: usize = batches.iter().map(|x| x.live()).sum();
            ensure(total == n, format!("lost requests: {total} != {n}"))?;
            for batch in &batches {
                if batch.live() > cap {
                    return Err(format!("batch {} > cap {cap}", batch.live()));
                }
                if batch.padded < batch.live() {
                    return Err("padded < live".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flush_all_drains_every_op() {
        let b = batcher(256, 1_000_000);
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Rsqrt));
        let batches = b.flush_all(&mut r);
        assert_eq!(batches.len(), 3);
        assert!(r.is_empty());
    }
}
