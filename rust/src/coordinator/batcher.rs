//! Dynamic batcher: decides *when* to flush a per-(op, format) queue
//! into one executor batch and *how big* that batch is.
//!
//! Policy (the standard serving trade-off), resolvable per (op, format)
//! — half-precision inference traffic tolerates less queueing latency
//! than f64 batch jobs, so [`BatcherConfig`] carries per-slot overrides
//! on top of the global knobs:
//! * flush a queue when it holds `max_batch` lanes, or
//! * when its oldest item has waited `max_wait`, or
//! * when a queued item's deadline has arrived (so deadline shedding is
//!   timely, not deferred to the next natural flush), or
//! * when `flush_all` is requested (drain/shutdown).
//!
//! The formed batch is padded (with the neutral operand `1.0` *in the
//! batch's format*) up to the backend's capability ladder — AOT graphs
//! have fixed shapes, so a 70-lane flush rides the 256-wide executable.
//! Operand planes are recycled through a [`PlanePool`] (workers return
//! them after execution), so steady-state batch formation performs no
//! plane allocation. Items whose deadline expired are shed here —
//! failed with [`ServiceError::Deadline`] and counted in metrics — and
//! never reach an executor.
//!
//! Each coordinator shard owns its own `DynamicBatcher` (and
//! `PlanePool`): batches form from one shard's queues only, which is
//! what lets a peer shard steal a *formed* batch wholesale without
//! ever touching individual lanes.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::formats::{PlaneBuf, PlaneWidth};
use crate::obs::{TraceEvent, TraceKind, TracePlane};
use crate::runtime::caps::BackendCaps;

use super::metrics::Metrics;
use super::request::{
    op_format_slot, FormatKind, OpKind, ServiceError, WorkItem, OP_FORMAT_SLOTS,
};
use super::router::Router;

/// Per-(op, format) overrides of the batching policy; `None` fields
/// fall back to the global [`BatcherConfig`] values.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyOverride {
    /// Flush threshold override (lanes).
    pub max_batch: Option<usize>,
    /// Age threshold override.
    pub max_wait: Option<Duration>,
}

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Global flush threshold: a queue flushes at this many lanes.
    pub max_batch: usize,
    /// Global age threshold: flush whatever is queued once the oldest
    /// item has waited this long.
    pub max_wait: Duration,
    overrides: [PolicyOverride; OP_FORMAT_SLOTS],
}

impl Default for BatcherConfig {
    /// 1024-lane / 200 microsecond policy, with the half-precision
    /// queues (f16, bf16) on a 4x tighter latency budget by default.
    fn default() -> Self {
        Self::new(1024, Duration::from_micros(200)).tight_half_precision()
    }
}

impl BatcherConfig {
    /// Uniform policy: the same thresholds for every (op, format).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self { max_batch, max_wait, overrides: [PolicyOverride::default(); OP_FORMAT_SLOTS] }
    }

    /// Set a full override for one (op, format) slot.
    pub fn with_policy(mut self, op: OpKind, format: FormatKind, policy: PolicyOverride) -> Self {
        self.overrides[op_format_slot(op, format)] = policy;
        self
    }

    /// Override the age threshold for every op of one format.
    pub fn with_format_max_wait(mut self, format: FormatKind, max_wait: Duration) -> Self {
        for &op in &OpKind::ALL {
            self.overrides[op_format_slot(op, format)].max_wait = Some(max_wait);
        }
        self
    }

    /// Override the flush threshold for every op of one format.
    pub fn with_format_max_batch(mut self, format: FormatKind, max_batch: usize) -> Self {
        for &op in &OpKind::ALL {
            self.overrides[op_format_slot(op, format)].max_batch = Some(max_batch);
        }
        self
    }

    /// The default half-precision posture: f16/bf16 queues flush at a
    /// quarter of the global age budget (inference traffic pays for
    /// latency; f64 batch jobs pay for occupancy).
    pub fn tight_half_precision(self) -> Self {
        let wait = self.max_wait / 4;
        self.with_format_max_wait(FormatKind::F16, wait)
            .with_format_max_wait(FormatKind::BF16, wait)
    }

    /// Resolved flush threshold for one (op, format) queue.
    pub fn max_batch_for(&self, op: OpKind, format: FormatKind) -> usize {
        self.overrides[op_format_slot(op, format)].max_batch.unwrap_or(self.max_batch)
    }

    /// Resolved age threshold for one (op, format) queue.
    pub fn max_wait_for(&self, op: OpKind, format: FormatKind) -> Duration {
        self.overrides[op_format_slot(op, format)].max_wait.unwrap_or(self.max_wait)
    }
}

/// Recycler for batch operand planes: workers return a batch's `a`/`b`
/// planes here after execution, and `form_batch` reuses them, so the
/// steady-state request path allocates no planes. Planes are parked
/// **per width** — a recycled u32 half-precision plane never widens
/// into a u64 one — and each width's free list is bounded so a burst
/// cannot pin memory forever.
#[derive(Clone, Debug, Default)]
pub struct PlanePool {
    free: Arc<Mutex<PoolLists>>,
}

#[derive(Debug, Default)]
struct PoolLists {
    w32: Vec<Vec<u32>>,
    w64: Vec<Vec<u64>>,
}

/// Retained planes cap per width: beyond this, returned planes are
/// dropped instead of parked.
const POOL_MAX_PLANES: usize = 64;

impl PlanePool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a cleared plane of the given width (capacity retained from
    /// earlier batches of that width).
    pub fn take(&self, width: PlaneWidth) -> PlaneBuf {
        let mut free = self.free.lock().expect("plane pool poisoned");
        match width {
            PlaneWidth::W32 => PlaneBuf::W32(free.w32.pop().unwrap_or_default()),
            PlaneWidth::W64 => PlaneBuf::W64(free.w64.pop().unwrap_or_default()),
        }
    }

    /// Return a plane for reuse (capacity-less planes — e.g. the empty
    /// `b` of a unary batch — are dropped, not parked).
    pub fn give(&self, mut plane: PlaneBuf) {
        if plane.capacity() == 0 {
            return;
        }
        plane.clear();
        let mut free = self.free.lock().expect("plane pool poisoned");
        match plane {
            PlaneBuf::W32(v) => {
                if free.w32.len() < POOL_MAX_PLANES {
                    free.w32.push(v);
                }
            }
            PlaneBuf::W64(v) => {
                if free.w64.len() < POOL_MAX_PLANES {
                    free.w64.push(v);
                }
            }
        }
    }

    /// Planes currently parked in the pool, both widths
    /// (diagnostics/tests).
    pub fn parked(&self) -> usize {
        let free = self.free.lock().expect("plane pool poisoned");
        free.w32.len() + free.w64.len()
    }

    /// Planes currently parked at one width (diagnostics/tests).
    pub fn parked_at(&self, width: PlaneWidth) -> usize {
        let free = self.free.lock().expect("plane pool poisoned");
        match width {
            PlaneWidth::W32 => free.w32.len(),
            PlaneWidth::W64 => free.w64.len(),
        }
    }
}

/// A formed batch, ready for an executor.
#[derive(Debug)]
pub struct Batch {
    /// Operation.
    pub op: OpKind,
    /// IEEE format of every lane (the router guarantees purity).
    pub format: FormatKind,
    /// The work items riding this batch (FIFO order; lane offsets
    /// within the planes follow item order).
    pub items: Vec<WorkItem>,
    /// Padded operand plane as raw format words at the serving
    /// backend's negotiated plane width (`u32` lanes for width-true
    /// half-precision batches).
    pub a: PlaneBuf,
    /// Second operand plane (padded), divide only — empty for unary
    /// ops, whose executors never read it.
    pub b: PlaneBuf,
    /// Padded (executable) size; `live() <= padded`.
    pub padded: usize,
    /// Index of the backend (worker pool) this batch was formed for:
    /// its planes are at that backend's width, padded to its ladder.
    pub backend: usize,
    /// Bitmask of backend indices that have already attempted this
    /// batch — the dispatch plane's retry chain never re-offers a batch
    /// to a backend that failed it.
    pub tried: u8,
    /// When this batch was formed (the boundary between a rider's
    /// queue-wait and batch stages in the trace decomposition).
    pub formed_at: Instant,
    /// Whether any rider in this batch is trace-sampled — the worker
    /// emits per-request stage spans only for sampled riders.
    pub sampled: bool,
    /// Nanoseconds burned on failed execution attempts before the
    /// successful one (accumulated across failover hops; the trace's
    /// failover stage).
    pub failover_ns: u64,
}

impl Batch {
    /// Live (non-padding) lane count.
    pub fn live(&self) -> usize {
        self.items.iter().map(|i| i.lanes()).sum()
    }

    /// Padding fraction (0 = perfectly full; an empty batch wastes
    /// nothing rather than dividing by zero).
    pub fn waste(&self) -> f64 {
        if self.padded == 0 {
            0.0
        } else {
            1.0 - self.live() as f64 / self.padded as f64
        }
    }
}

/// One backend's batching shape: its capability ladders and negotiated
/// plane widths (a routed service keeps one per registered backend).
#[derive(Debug)]
struct BackendShape {
    /// Per-(op, format) ladder of available executable batch sizes
    /// (ascending), from the backend's negotiated capabilities.
    ladders: [Vec<usize>; OP_FORMAT_SLOTS],
    /// Per-format plane width the backend consumes (width-true unless
    /// the backend negotiated otherwise); batch planes are drawn from
    /// the pool at this width.
    widths: [PlaneWidth; FormatKind::ALL.len()],
}

impl BackendShape {
    fn from_caps(caps: &BackendCaps) -> Self {
        let mut ladders: [Vec<usize>; OP_FORMAT_SLOTS] = std::array::from_fn(|_| Vec::new());
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                ladders[op_format_slot(op, format)] = caps.ladder(op, format).to_vec();
            }
        }
        let widths = std::array::from_fn(|i| caps.plane_width(FormatKind::ALL[i]));
        Self { ladders, widths }
    }
}

/// The dynamic batcher. A routed service holds one shape table per
/// registered backend and forms each batch *for* the backend the
/// dispatch plane selected (`*_for` methods); the plain methods are the
/// single-backend view (backend 0), which is what direct
/// [`FpuService::start`](super::service::FpuService::start) services
/// and the batcher's own tests use.
#[derive(Debug)]
pub struct DynamicBatcher {
    config: BatcherConfig,
    backends: Vec<BackendShape>,
    trace: Option<Arc<TracePlane>>,
}

impl DynamicBatcher {
    /// New single-backend batcher over one capability table.
    pub fn new(config: BatcherConfig, caps: &BackendCaps) -> Self {
        Self::routed(config, std::slice::from_ref(caps))
    }

    /// New multi-backend batcher: one shape table per backend, index
    /// order matching the dispatch plane's routing table.
    pub fn routed(config: BatcherConfig, caps: &[BackendCaps]) -> Self {
        assert!(!caps.is_empty(), "batcher needs at least one backend");
        Self { config, backends: caps.iter().map(BackendShape::from_caps).collect(), trace: None }
    }

    /// Attach a trace plane: batch formation then emits batch-formed
    /// events for sampled batches and error-class shed events.
    pub fn with_trace(mut self, trace: Option<Arc<TracePlane>>) -> Self {
        self.trace = trace;
        self
    }

    /// The config in force.
    pub fn config(&self) -> &BatcherConfig {
        &self.config
    }

    fn ladder_for(&self, backend: usize, op: OpKind, format: FormatKind) -> &[usize] {
        &self.backends[backend].ladders[op_format_slot(op, format)]
    }

    /// Largest executable size for a backend's (op, format) pair (the
    /// flush cap).
    fn cap_for(&self, backend: usize, op: OpKind, format: FormatKind) -> usize {
        let max_batch = self.config.max_batch_for(op, format);
        self.ladder_for(backend, op, format)
            .last()
            .copied()
            .unwrap_or(max_batch)
            .min(max_batch)
            .max(1)
    }

    /// Smallest ladder size >= n for a backend (or the cap when n
    /// exceeds it).
    pub fn padded_for(&self, backend: usize, op: OpKind, format: FormatKind, n: usize) -> usize {
        let ladder = self.ladder_for(backend, op, format);
        ladder.iter().copied().find(|&b| b >= n).or(ladder.last().copied()).unwrap_or(n)
    }

    /// The plane width a backend's batches of `format` ride.
    pub fn plane_width_for(&self, backend: usize, format: FormatKind) -> PlaneWidth {
        self.backends[backend].widths[format.index()]
    }

    /// Decide whether an (op, format) queue should flush now (single-
    /// backend view).
    pub fn should_flush(
        &self,
        router: &Router,
        op: OpKind,
        format: FormatKind,
        now: Instant,
    ) -> bool {
        self.should_flush_for(0, router, op, format, now)
    }

    /// Decide whether an (op, format) queue should flush now, into a
    /// batch shaped for `backend`.
    pub fn should_flush_for(
        &self,
        backend: usize,
        router: &Router,
        op: OpKind,
        format: FormatKind,
        now: Instant,
    ) -> bool {
        let len = router.len(op, format);
        if len == 0 {
            return false;
        }
        if len >= self.cap_for(backend, op, format) {
            return true;
        }
        if router.earliest_deadline_in(op, format).is_some_and(|d| now >= d) {
            return true; // a queued deadline arrived: shed it promptly
        }
        match router.oldest_enqueue_in(op, format) {
            Some(oldest) => now.duration_since(oldest) >= self.config.max_wait_for(op, format),
            None => false,
        }
    }

    /// [`Self::form_batch_for`] on the single-backend view (backend 0).
    pub fn form_batch(
        &self,
        router: &mut Router,
        op: OpKind,
        format: FormatKind,
        now: Instant,
        pool: &PlanePool,
        metrics: &Metrics,
    ) -> Option<Batch> {
        self.form_batch_for(0, router, op, format, now, pool, metrics)
    }

    /// Form one batch from an (op, format) queue (up to the backend's
    /// cap), shedding expired items and padding operand planes to the
    /// backend's ladder with the format's `1.0`, at the backend's
    /// negotiated plane width. Every drained lane (shed included) is
    /// discounted from the metrics queue-depth gauge. Returns `None`
    /// when the drain yields no live items (empty queue, or everything
    /// drained was expired — the queue has still shrunk, so callers
    /// loop on queue length).
    pub fn form_batch_for(
        &self,
        backend: usize,
        router: &mut Router,
        op: OpKind,
        format: FormatKind,
        now: Instant,
        pool: &PlanePool,
        metrics: &Metrics,
    ) -> Option<Batch> {
        let cap = self.cap_for(backend, op, format);
        let drained = router.drain(op, format, cap);
        if drained.is_empty() {
            return None;
        }
        let taken: usize = drained.iter().map(|i| i.lanes()).sum();
        metrics.record_dequeued(op, format, taken as u64);
        let mut items = Vec::with_capacity(drained.len());
        let mut shed = 0usize;
        for item in drained {
            if item.expired(now) {
                shed += item.lanes();
                if let Some(trace) = &self.trace {
                    // sheds are error-class: captured at 100%
                    trace.emit(
                        TraceEvent::new(TraceKind::Shed, trace.ns_of(now))
                            .req(item.id, op, format)
                            .with_lanes(item.lanes()),
                    );
                }
                item.fail(ServiceError::Deadline);
            } else {
                items.push(item);
            }
        }
        if shed > 0 {
            metrics.record_shed(op, format, shed as u64);
        }
        if items.is_empty() {
            return None;
        }
        let live: usize = items.iter().map(|i| i.lanes()).sum();
        let padded = self.padded_for(backend, op, format, live);
        // pad with neutral operands: 1.0 / 1.0 stays in-domain for every
        // op; unary batches build no divisor plane at all. Planes come
        // from the pool at the backend's negotiated width (u32 for
        // half-precision batches on a width-true backend: half the
        // flush traffic).
        let divide = op == OpKind::Divide;
        let one = format.one_bits();
        let width = self.plane_width_for(backend, format);
        let mut a = pool.take(width);
        let mut b = if divide { pool.take(width) } else { PlaneBuf::new(width) };
        a.reserve(padded);
        if divide {
            b.reserve(padded);
        }
        for item in &items {
            item.push_operands(&mut a, if divide { Some(&mut b) } else { None }, one);
        }
        a.resize(padded, one);
        if divide {
            b.resize(padded, one);
        }
        let sampled = items.iter().any(|i| i.sampled);
        if sampled {
            if let Some(trace) = &self.trace {
                trace.emit(
                    TraceEvent::new(TraceKind::BatchFormed, trace.ns_of(now))
                        .req(items[0].id, op, format)
                        .on_backend(backend)
                        .with_lanes(live)
                        .with_arg(padded as u64),
                );
            }
        }
        Some(Batch {
            op,
            format,
            items,
            a,
            b,
            padded,
            backend,
            tried: 0,
            formed_at: now,
            sampled,
            failover_ns: 0,
        })
    }

    /// Form batches for every (op, format) queue that should flush at
    /// `now` (single-backend view; the routed dispatcher drives
    /// [`Self::form_batch_for`] per selected backend instead).
    pub fn ready_batches(
        &self,
        router: &mut Router,
        now: Instant,
        pool: &PlanePool,
        metrics: &Metrics,
    ) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                while self.should_flush(router, op, format, now) {
                    match self.form_batch(router, op, format, now, pool, metrics) {
                        Some(b) => out.push(b),
                        None => {
                            if router.len(op, format) == 0 {
                                break; // everything drained was shed
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Unconditionally drain everything (shutdown path). Expired items
    /// are still shed, not executed; queues that are already empty form
    /// no batch. Single-backend view, like [`Self::ready_batches`].
    pub fn flush_all(
        &self,
        router: &mut Router,
        now: Instant,
        pool: &PlanePool,
        metrics: &Metrics,
    ) -> Vec<Batch> {
        let mut out = Vec::new();
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                while router.len(op, format) > 0 {
                    if let Some(b) = self.form_batch(router, op, format, now, pool, metrics) {
                        out.push(b);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};
    use crate::formats::Value;

    fn req_at(id: u64, op: OpKind, format: FormatKind, enqueued_at: Instant) -> WorkItem {
        let (mut item, _ticket) = WorkItem::single(
            id,
            op,
            Value::from_f64(format, id as f64 + 2.0),
            Value::from_f64(format, 2.0),
            None,
        );
        item.enqueued_at = enqueued_at;
        item
    }

    fn req_fmt(id: u64, op: OpKind, format: FormatKind) -> WorkItem {
        req_at(id, op, format, Instant::now())
    }

    fn req(id: u64, op: OpKind) -> WorkItem {
        req_fmt(id, op, FormatKind::F32)
    }

    fn batcher(max_batch: usize, max_wait_us: u64) -> DynamicBatcher {
        DynamicBatcher::new(
            BatcherConfig::new(max_batch, Duration::from_micros(max_wait_us)),
            &BackendCaps::uniform("test", &[64, 256, 1024]),
        )
    }

    fn form(b: &DynamicBatcher, r: &mut Router, op: OpKind, format: FormatKind) -> Option<Batch> {
        b.form_batch(r, op, format, Instant::now(), &PlanePool::new(), &Metrics::new())
    }

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn no_flush_when_empty() {
        let b = batcher(256, 100);
        let r = Router::new();
        assert!(!b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
    }

    #[test]
    fn flushes_at_cap() {
        let b = batcher(256, 1_000_000); // effectively no age flush
        let mut r = Router::new();
        for i in 0..255 {
            r.route(req(i, OpKind::Divide));
        }
        assert!(!b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
        r.route(req(255, OpKind::Divide));
        assert!(b.should_flush(&r, OpKind::Divide, F32, Instant::now()));
    }

    #[test]
    fn flushes_on_age() {
        let b = batcher(1024, 0); // zero wait: always stale
        let mut r = Router::new();
        r.route(req(1, OpKind::Sqrt));
        assert!(b.should_flush(&r, OpKind::Sqrt, F32, Instant::now()));
    }

    #[test]
    fn age_flush_is_per_queue() {
        // a stale f64 queue must not force the fresh f32 queue to flush
        let b = batcher(1024, 500);
        let mut r = Router::new();
        let stale = Instant::now() - Duration::from_millis(10);
        r.route(req_at(1, OpKind::Divide, FormatKind::F64, stale));
        r.route(req_fmt(2, OpKind::Divide, FormatKind::F32));
        let now = Instant::now();
        assert!(b.should_flush(&r, OpKind::Divide, FormatKind::F64, now));
        assert!(!b.should_flush(&r, OpKind::Divide, FormatKind::F32, now));
        let ready = b.ready_batches(&mut r, now, &PlanePool::new(), &Metrics::new());
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].format, FormatKind::F64);
        assert_eq!(r.len(OpKind::Divide, FormatKind::F32), 1);
    }

    #[test]
    fn per_format_policy_overrides_resolve() {
        let cfg = BatcherConfig::new(1024, Duration::from_micros(400))
            .with_format_max_wait(FormatKind::F16, Duration::from_micros(25))
            .with_format_max_batch(FormatKind::F16, 128)
            .with_policy(
                OpKind::Sqrt,
                FormatKind::F64,
                PolicyOverride {
                    max_batch: Some(2048),
                    max_wait: Some(Duration::from_millis(2)),
                },
            );
        assert_eq!(cfg.max_batch_for(OpKind::Divide, FormatKind::F16), 128);
        assert_eq!(cfg.max_wait_for(OpKind::Rsqrt, FormatKind::F16), Duration::from_micros(25));
        assert_eq!(cfg.max_batch_for(OpKind::Divide, FormatKind::F32), 1024);
        assert_eq!(cfg.max_wait_for(OpKind::Divide, FormatKind::F32), Duration::from_micros(400));
        assert_eq!(cfg.max_batch_for(OpKind::Sqrt, FormatKind::F64), 2048);
        assert_eq!(cfg.max_wait_for(OpKind::Sqrt, FormatKind::F64), Duration::from_millis(2));
        // default posture: half-precision waits a quarter of the budget
        let d = BatcherConfig::default();
        assert_eq!(d.max_wait_for(OpKind::Divide, FormatKind::F16), d.max_wait / 4);
        assert_eq!(d.max_wait_for(OpKind::Divide, FormatKind::BF16), d.max_wait / 4);
        assert_eq!(d.max_wait_for(OpKind::Divide, FormatKind::F64), d.max_wait);
    }

    #[test]
    fn format_override_drives_flush_decision() {
        // same age, different formats: only the tight-budget queue is stale
        let cfg = BatcherConfig::new(1024, Duration::from_secs(1))
            .with_format_max_wait(FormatKind::F16, Duration::from_micros(1));
        let b =
            DynamicBatcher::new(cfg, &BackendCaps::uniform("test", &[64, 256, 1024]));
        let mut r = Router::new();
        let t = Instant::now() - Duration::from_millis(1);
        r.route(req_at(1, OpKind::Divide, FormatKind::F16, t));
        r.route(req_at(2, OpKind::Divide, FormatKind::F32, t));
        let now = Instant::now();
        assert!(b.should_flush(&r, OpKind::Divide, FormatKind::F16, now));
        assert!(!b.should_flush(&r, OpKind::Divide, FormatKind::F32, now));
    }

    #[test]
    fn max_wait_flush_preserves_fifo_order() {
        // two age-triggered flushes from one queue: the older requests
        // must ride the earlier batch, in submission order
        let b = batcher(4, 0);
        let mut r = Router::new();
        for i in 0..6 {
            r.route(req(i, OpKind::Divide));
        }
        let batches = b.ready_batches(&mut r, Instant::now(), &PlanePool::new(), &Metrics::new());
        assert_eq!(batches.len(), 2);
        assert_eq!(
            batches[0].items.iter().map(|x| x.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(batches[1].items.iter().map(|x| x.id).collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn pads_to_ladder() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..70 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = form(&b, &mut r, OpKind::Divide, F32).unwrap();
        assert_eq!(batch.live(), 70);
        assert_eq!(batch.padded, 256);
        assert_eq!(batch.a.len(), 256);
        assert_eq!(batch.b.len(), 256);
        // padding is the neutral operand in the batch format
        assert!((70..256).all(|i| batch.a.get(i) == F32.one_bits()));
        assert!((batch.waste() - (1.0 - 70.0 / 256.0)).abs() < 1e-12);
    }

    #[test]
    fn pads_with_format_specific_one() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..3 {
            r.route(req_fmt(i, OpKind::Divide, FormatKind::F16));
        }
        let batch = form(&b, &mut r, OpKind::Divide, FormatKind::F16).unwrap();
        assert_eq!(batch.format, FormatKind::F16);
        assert_eq!(batch.padded, 64);
        // half-precision batches ride width-true u32 planes
        assert_eq!(batch.a.width(), PlaneWidth::W32);
        assert_eq!(batch.b.width(), PlaneWidth::W32);
        assert!((3..64).all(|i| batch.a.get(i) == 0x3C00)); // f16 1.0
        assert!((3..64).all(|i| batch.b.get(i) == 0x3C00));
    }

    #[test]
    fn empty_batch_wastes_nothing() {
        // padded == 0 must not divide by zero (guard, not NaN)
        let batch = Batch {
            op: OpKind::Divide,
            format: F32,
            items: Vec::new(),
            a: PlaneBuf::default(),
            b: PlaneBuf::default(),
            padded: 0,
            backend: 0,
            tried: 0,
            formed_at: Instant::now(),
            sampled: false,
            failover_ns: 0,
        };
        assert_eq!(batch.waste(), 0.0);
    }

    #[test]
    fn per_backend_shapes_drive_width_ladder_and_cap() {
        // backend 0: width-true, fine ladder; backend 1: a u64-planes
        // divide backend on a coarser ladder — the same queue forms
        // differently depending on who serves the batch
        let caps0 = BackendCaps::uniform("native", &[64, 256, 1024]);
        let caps1 = {
            let mut c = BackendCaps::new("u64-only");
            for &format in &FormatKind::ALL {
                c = c
                    .with(OpKind::Divide, format, &[128])
                    .with_plane_width(format, PlaneWidth::W64);
            }
            c
        };
        let b = DynamicBatcher::routed(
            BatcherConfig::new(1024, Duration::from_micros(1_000_000)),
            &[caps0, caps1],
        );
        assert_eq!(b.plane_width_for(0, FormatKind::F16), PlaneWidth::W32);
        assert_eq!(b.plane_width_for(1, FormatKind::F16), PlaneWidth::W64);
        assert_eq!(b.padded_for(0, OpKind::Divide, FormatKind::F16, 70), 256);
        assert_eq!(b.padded_for(1, OpKind::Divide, FormatKind::F16, 70), 128);
        let pool = PlanePool::new();
        let metrics = Metrics::new();
        let mut r = Router::new();
        for i in 0..70 {
            r.route(req_fmt(i, OpKind::Divide, FormatKind::F16));
        }
        let now = Instant::now();
        let batch = b
            .form_batch_for(1, &mut r, OpKind::Divide, FormatKind::F16, now, &pool, &metrics)
            .unwrap();
        assert_eq!(batch.backend, 1);
        assert_eq!(batch.tried, 0);
        assert_eq!(batch.padded, 128);
        assert_eq!(batch.a.width(), PlaneWidth::W64, "backend 1 negotiated u64 planes");
        assert!((70..128).all(|i| batch.a.get(i) == FormatKind::F16.one_bits()));
    }

    #[test]
    fn form_batch_discounts_queue_depth_gauge() {
        let b = batcher(1024, 0);
        let metrics = Metrics::new();
        let pool = PlanePool::new();
        let mut r = Router::new();
        for i in 0..30 {
            r.route(req(i, OpKind::Divide));
        }
        // the service handle normally feeds the gauge at submit time
        metrics.record_enqueued(OpKind::Divide, F32, 30);
        assert_eq!(metrics.queued_lanes(OpKind::Divide, F32), 30);
        let batch = b
            .form_batch(&mut r, OpKind::Divide, F32, Instant::now(), &pool, &metrics)
            .unwrap();
        assert_eq!(batch.live(), 30);
        assert_eq!(metrics.queued_lanes(OpKind::Divide, F32), 0, "drained lanes discounted");
    }

    #[test]
    fn batch_preserves_fifo_and_operands() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..5 {
            r.route(req(i, OpKind::Divide));
        }
        let batch = form(&b, &mut r, OpKind::Divide, F32).unwrap();
        for (i, item) in batch.items.iter().enumerate() {
            assert_eq!(item.id, i as u64);
            assert_eq!(batch.a.get(i), (i as f32 + 2.0).to_bits() as u64);
        }
    }

    #[test]
    fn oversized_queue_splits_into_multiple_batches() {
        let b = batcher(1024, 0);
        let mut r = Router::new();
        for i in 0..2500 {
            r.route(req(i, OpKind::Divide));
        }
        let batches = b.ready_batches(&mut r, Instant::now(), &PlanePool::new(), &Metrics::new());
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].live(), 1024);
        assert_eq!(batches[1].live(), 1024);
        assert_eq!(batches[2].live(), 452);
        assert!(r.is_empty());
    }

    #[test]
    fn vectored_group_keeps_locality_and_splits_on_ladder() {
        // a 300-lane group: one batch of 256 (split) + the 44-lane tail
        let b = batcher(256, 0);
        let mut r = Router::new();
        let a: Vec<u64> = (0..300).map(|i| (i as f32 + 1.0).to_bits() as u64).collect();
        let (item, _ticket) =
            WorkItem::group(9, OpKind::Sqrt, F32, &a, &[], None);
        r.route(item);
        let batches = b.ready_batches(&mut r, Instant::now(), &PlanePool::new(), &Metrics::new());
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].live(), 256);
        assert_eq!(batches[0].padded, 256);
        assert_eq!(batches[1].live(), 44);
        // lanes arrive pre-formed, in order, without re-discovery
        assert!((0..256).all(|i| batches[0].a.get(i) == a[i]));
        assert!((0..44).all(|i| batches[1].a.get(i) == a[256 + i]));
        // unary batch: no divisor plane is built at all
        assert!(batches[0].b.is_empty());
        assert!(batches[1].b.is_empty());
    }

    #[test]
    fn expired_items_are_shed_not_executed() {
        let b = batcher(1024, 0);
        let metrics = Metrics::new();
        let pool = PlanePool::new();
        let mut r = Router::new();
        let past = Instant::now() - Duration::from_millis(1);
        let (expired, _t1) = {
            let (mut item, t) = WorkItem::single(
                1,
                OpKind::Divide,
                Value::F32(6.0),
                Value::F32(2.0),
                Some(past),
            );
            item.enqueued_at = past;
            (item, t)
        };
        r.route(expired);
        r.route(req(2, OpKind::Divide));
        let batch = b
            .form_batch(&mut r, OpKind::Divide, F32, Instant::now(), &pool, &metrics)
            .unwrap();
        assert_eq!(batch.live(), 1);
        assert_eq!(batch.items[0].id, 2);
        let snap = metrics.snapshot();
        assert_eq!(snap.op_format(OpKind::Divide, F32).shed, 1);
        // the shed client observes a typed Deadline error
        assert_eq!(_t1.wait().unwrap_err(), ServiceError::Deadline);
    }

    #[test]
    fn all_expired_drain_still_empties_queue() {
        let b = batcher(1024, 1_000_000);
        let metrics = Metrics::new();
        let pool = PlanePool::new();
        let mut r = Router::new();
        let past = Instant::now() - Duration::from_millis(1);
        for i in 0..5 {
            let (mut item, _t) = WorkItem::single(
                i,
                OpKind::Sqrt,
                Value::F32(4.0),
                Value::F32(1.0),
                Some(past),
            );
            item.enqueued_at = past;
            r.route(item);
        }
        // deadline arrival makes the queue flush-eligible immediately
        assert!(b.should_flush(&r, OpKind::Sqrt, F32, Instant::now()));
        let batches = b.flush_all(&mut r, Instant::now(), &pool, &metrics);
        assert!(batches.is_empty());
        assert!(r.is_empty());
        assert_eq!(metrics.snapshot().op_format(OpKind::Sqrt, F32).shed, 5);
    }

    #[test]
    fn batch_formation_traces_sheds_and_sampled_batches() {
        use crate::obs::{TraceConfig, TraceKind, TracePlane};
        let trace = Arc::new(TracePlane::new(TraceConfig { sample: 1, capacity: 64 }));
        let b = batcher(1024, 0).with_trace(Some(trace.clone()));
        let metrics = Metrics::new();
        let pool = PlanePool::new();
        let mut r = Router::new();
        let past = Instant::now() - Duration::from_millis(1);
        let (expired, _t) = {
            let (mut item, t) = WorkItem::single(
                7,
                OpKind::Divide,
                Value::F32(6.0),
                Value::F32(2.0),
                Some(past),
            );
            item.enqueued_at = past;
            (item, t)
        };
        r.route(expired);
        let mut live = req(8, OpKind::Divide);
        live.sampled = true;
        r.route(live);
        let now = Instant::now();
        let batch =
            b.form_batch(&mut r, OpKind::Divide, F32, now, &pool, &metrics).unwrap();
        assert!(batch.sampled, "a sampled rider marks the whole batch");
        assert_eq!(batch.formed_at, now);
        assert_eq!(batch.failover_ns, 0);
        let evs = trace.events();
        let shed: Vec<_> = evs.iter().filter(|e| e.kind == TraceKind::Shed).collect();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 7, "the expired rider is the shed event");
        let formed: Vec<_> =
            evs.iter().filter(|e| e.kind == TraceKind::BatchFormed).collect();
        assert_eq!(formed.len(), 1);
        assert_eq!(formed[0].id, 8, "batch-formed carries the first live rider's id");
        assert_eq!(formed[0].lanes, 1);
        // an unsampled batch forms silently
        r.route(req(9, OpKind::Divide));
        let batch = form(&b, &mut r, OpKind::Divide, F32).unwrap();
        assert!(!batch.sampled);
        let evs = trace.events();
        assert_eq!(
            evs.iter().filter(|e| e.kind == TraceKind::BatchFormed).count(),
            1,
            "no batch-formed event for an unsampled batch"
        );
    }

    #[test]
    fn plane_pool_recycles_capacity() {
        // capacity must actually be retained across give/take cycles,
        // independently per width
        let pool = PlanePool::new();
        for width in [PlaneWidth::W32, PlaneWidth::W64] {
            let mut v = pool.take(width);
            assert_eq!(v.capacity(), 0);
            v.resize(1024, 7);
            pool.give(v);
            assert_eq!(pool.parked_at(width), 1);
            let v = pool.take(width);
            assert!(v.is_empty());
            assert_eq!(v.width(), width);
            assert!(v.capacity() >= 1024, "{width:?} capacity lost in the pool");
            assert_eq!(pool.parked_at(width), 0);
            pool.give(v);
        }
        assert_eq!(pool.parked(), 2);
    }

    #[test]
    fn plane_pool_never_crosses_widths() {
        // a parked u32 plane must not come back as (or displace) a u64
        // plane
        let pool = PlanePool::new();
        let mut v = pool.take(PlaneWidth::W32);
        v.resize(512, 1);
        pool.give(v);
        let w64 = pool.take(PlaneWidth::W64);
        assert_eq!(w64.width(), PlaneWidth::W64);
        assert_eq!(w64.capacity(), 0, "must not hand the u32 plane across widths");
        assert_eq!(pool.parked_at(PlaneWidth::W32), 1);
    }

    #[test]
    fn plane_pool_cap_drops_excess_planes() {
        // the retained-planes cap bounds each width's free list: a
        // burst of returns beyond the cap is dropped, not accumulated
        let pool = PlanePool::new();
        for width in [PlaneWidth::W32, PlaneWidth::W64] {
            for _ in 0..200 {
                let mut v = PlaneBuf::new(width);
                v.resize(64, 0);
                pool.give(v);
            }
            assert_eq!(pool.parked_at(width), POOL_MAX_PLANES, "{width:?} free list not capped");
        }
        // capacity-less planes (unary b planes) are never parked
        pool.give(PlaneBuf::new(PlaneWidth::W64));
        assert_eq!(pool.parked_at(PlaneWidth::W64), POOL_MAX_PLANES);
    }

    #[test]
    fn never_exceeds_cap_property() {
        check::property("batch lanes <= cap, conservation", |g| {
            let cap = [64usize, 256, 1024][g.usize_in(0, 3)];
            let b = batcher(cap, 0);
            let metrics = Metrics::new();
            let pool = PlanePool::new();
            let mut r = Router::new();
            let mut n = 0usize;
            for i in 0..g.usize_in(0, 200) {
                let fmt = *g.pick(&FormatKind::ALL);
                if g.chance(0.2) {
                    let lanes = g.usize_in(1, 90);
                    let a: Vec<u64> = vec![fmt.one_bits(); lanes];
                    let (item, _t) =
                        WorkItem::group(i as u64, OpKind::Divide, fmt, &a, &a, None);
                    r.route(item);
                    n += lanes;
                } else {
                    r.route(req_fmt(i as u64, OpKind::Divide, fmt));
                    n += 1;
                }
            }
            let batches = b.flush_all(&mut r, Instant::now(), &pool, &metrics);
            let total: usize = batches.iter().map(|x| x.live()).sum();
            ensure(total == n, format!("lost lanes: {total} != {n}"))?;
            for batch in &batches {
                if batch.live() == 0 {
                    return Err("flush_all formed an empty batch".into());
                }
                if batch.live() > cap {
                    return Err(format!("batch {} > cap {cap}", batch.live()));
                }
                if batch.padded < batch.live() {
                    return Err("padded < live".into());
                }
                if batch.a.len() != batch.padded || batch.b.len() != batch.padded {
                    return Err("plane length != padded".into());
                }
                if batch.items.iter().any(|x| x.format() != batch.format) {
                    return Err("mixed formats in one batch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flush_all_drains_every_op_and_format() {
        let b = batcher(256, 1_000_000);
        let mut r = Router::new();
        r.route(req(1, OpKind::Divide));
        r.route(req(2, OpKind::Sqrt));
        r.route(req(3, OpKind::Rsqrt));
        r.route(req_fmt(4, OpKind::Divide, FormatKind::BF16));
        let batches = b.flush_all(&mut r, Instant::now(), &PlanePool::new(), &Metrics::new());
        assert_eq!(batches.len(), 4);
        assert!(batches.iter().all(|x| x.live() > 0));
        assert!(r.is_empty());
    }
}
