//! Workload generation for the service benchmarks: operand
//! distributions and arrival processes ([`generator`]), and the
//! scenario-scale open-loop load harness that drives them at the wire
//! front end ([`scenario`], the engine behind `goldschmidt loadgen`).

pub mod generator;
pub mod scenario;

pub use generator::{ArrivalProcess, OperandDist, WorkloadGen, WorkloadSpec};
pub use scenario::{
    derive_seed, run_scenario, sweep_max_qps, RampSpec, ScenarioReport, ScenarioSpec, SweepProbe,
    SweepReport, SCENARIOS,
};
