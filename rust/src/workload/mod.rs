//! Workload generation for the service benchmarks: operand
//! distributions and arrival processes.

pub mod generator;

pub use generator::{ArrivalProcess, OperandDist, WorkloadGen, WorkloadSpec};
