//! Scenario-scale open-loop load harness for the net plane.
//!
//! [`super::generator`] produces one stream of requests; this module
//! drives **many connections** of them at a wire server
//! ([`crate::net::NetServer`]) under a declarative [`ScenarioSpec`]:
//! Poisson arrivals, burst trains, diurnal ramps, per-format/op mixes,
//! reconnect storms and slow-loris readers — all on the same seeded-RNG
//! discipline (every connection derives its stream from the scenario
//! seed, so a run is replayable bit-for-bit from `(scenario, seed)`).
//!
//! The harness is **open-loop**: each connection paces submissions from
//! a precomputed arrival schedule and never waits for a completion
//! before sending the next frame, so offered load stays fixed while the
//! service degrades — the shape that finds the max-sustained-qps knee
//! the `net_loopback` bench section reports. Completions are drained by
//! a separate receiver thread per connection; per-frame latency is
//! submit-to-COMPLETE wall time.
//!
//! `goldschmidt loadgen --scenario <name>` is the CLI face of this
//! module; [`run_scenario`] is the library face the bench uses.

use std::collections::HashMap;
use std::net::ToSocketAddrs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::request::{FormatKind, OpKind, Value};
use crate::net::{result_of, Event, NetClient, SubmitOpts, FLAG_DURABLE};
use crate::util::rng::{SplitMix64, Xoshiro256};

use super::generator::{ArrivalProcess, OperandDist};

/// Linear offered-rate ramp (the "diurnal" shape compressed into a
/// bench-sized window): inter-arrival gaps are divided by a scale that
/// interpolates `start_scale -> end_scale` over `span_s`, then holds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RampSpec {
    /// Rate multiplier at t=0 (1.0 = the spec's base rate).
    pub start_scale: f64,
    /// Rate multiplier at `span_s` and beyond.
    pub end_scale: f64,
    /// Seconds over which the scale interpolates.
    pub span_s: f64,
}

impl RampSpec {
    fn scale_at(&self, t_s: f64) -> f64 {
        let frac = if self.span_s <= 0.0 { 1.0 } else { (t_s / self.span_s).clamp(0.0, 1.0) };
        (self.start_scale + (self.end_scale - self.start_scale) * frac).max(1e-9)
    }
}

/// Declarative description of one load scenario.
///
/// `arrivals` is the **per-connection** process; total offered rate is
/// `connections x` the per-connection rate. [`ScenarioSpec::preset`]
/// builds the named shapes the CLI exposes.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Concurrent client connections.
    pub connections: usize,
    /// Total SUBMIT frames across all connections.
    pub requests: usize,
    /// Lanes per SUBMIT frame (vectored batch width on the wire).
    pub lanes: usize,
    /// Per-connection arrival process.
    pub arrivals: ArrivalProcess,
    /// Optional rate ramp layered over `arrivals`.
    pub ramp: Option<RampSpec>,
    /// Operand value distribution.
    pub dist: OperandDist,
    /// Probability a frame is a divide (remainder split sqrt/rsqrt).
    pub divide_frac: f64,
    /// Formats drawn uniformly per frame (empty = f32 only).
    pub formats: Vec<FormatKind>,
    /// Per-frame deadline carried on the wire (0 = none).
    pub deadline_us: u32,
    /// Submit durably (requires the server to run with a journal).
    pub durable: bool,
    /// Tear down and re-dial each connection after this many frames
    /// (0 = never): the reconnect-storm shape.
    pub reconnect_every: usize,
    /// Of the `connections`, this many read completions slowly
    /// (slow-loris): each sleeps `read_delay_us` before every read.
    pub slow_conns: usize,
    /// Per-read stall for slow-loris connections, microseconds.
    pub read_delay_us: u64,
    /// Scenario seed; connection `i` streams from `derive_seed(seed, i)`.
    pub seed: u64,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        Self {
            connections: 4,
            requests: 10_000,
            lanes: 8,
            arrivals: ArrivalProcess::Poisson { rate: 2_000.0 },
            ramp: None,
            dist: OperandDist::LogNormal { mu: 0.0, sigma: 2.0 },
            divide_frac: 1.0,
            formats: vec![FormatKind::F32],
            deadline_us: 0,
            durable: false,
            reconnect_every: 0,
            slow_conns: 0,
            read_delay_us: 0,
            seed: 0xFEED,
        }
    }
}

/// Names accepted by [`ScenarioSpec::preset`] / `loadgen --scenario`.
pub const SCENARIOS: [&str; 6] = ["steady", "burst", "ramp", "mixed", "reconnect", "slowloris"];

impl ScenarioSpec {
    /// A named preset shape. `rate` is the **total** offered rate in
    /// frames/s across all connections; `requests` the total frame
    /// count. Returns `None` for an unknown name.
    pub fn preset(name: &str, requests: usize, rate: f64, seed: u64) -> Option<ScenarioSpec> {
        let base = ScenarioSpec { requests, seed, ..Default::default() };
        let per_conn = |conns: usize| rate / conns as f64;
        Some(match name {
            // steady Poisson plateau: the SLO-sweep workhorse
            "steady" => ScenarioSpec {
                arrivals: ArrivalProcess::Poisson { rate: per_conn(4) },
                ..base
            },
            // burst trains: 20 ms ON at 4x the mean rate, 60 ms OFF
            "burst" => ScenarioSpec {
                arrivals: ArrivalProcess::Bursty {
                    burst_rate: 4.0 * per_conn(4),
                    on_s: 0.020,
                    off_s: 0.060,
                },
                ..base
            },
            // diurnal ramp: half rate up to double rate over the run
            "ramp" => ScenarioSpec {
                arrivals: ArrivalProcess::Uniform { rate: per_conn(4) },
                ramp: Some(RampSpec { start_scale: 0.5, end_scale: 2.0, span_s: 2.0 }),
                ..base
            },
            // every format, 60/20/20 op mix
            "mixed" => ScenarioSpec {
                arrivals: ArrivalProcess::Poisson { rate: per_conn(4) },
                divide_frac: 0.6,
                formats: FormatKind::ALL.to_vec(),
                ..base
            },
            // eight dialers re-dialing every 64 frames
            "reconnect" => ScenarioSpec {
                connections: 8,
                arrivals: ArrivalProcess::Poisson { rate: per_conn(8) },
                reconnect_every: 64,
                ..base
            },
            // one of four readers stalls 2 ms per read; the server must
            // shed it without hurting the other three
            "slowloris" => ScenarioSpec {
                arrivals: ArrivalProcess::Poisson { rate: per_conn(4) },
                slow_conns: 1,
                read_delay_us: 2_000,
                ..base
            },
            _ => return None,
        })
    }

    /// Frames connection `idx` owns (total split as evenly as possible).
    pub fn frames_for_conn(&self, idx: usize) -> usize {
        let conns = self.connections.max(1);
        self.requests / conns + usize::from(idx < self.requests % conns)
    }

    /// The same scenario re-paced to a new **total** offered rate
    /// (frames/s across all connections). Burst trains keep their
    /// on/off duty cycle and 4x peak-to-mean ratio; a closed-loop spec
    /// becomes Poisson so the sweep stays open-loop.
    pub fn with_total_rate(&self, total_qps: f64) -> ScenarioSpec {
        let per_conn = total_qps.max(1e-9) / self.connections.max(1) as f64;
        let arrivals = match self.arrivals {
            ArrivalProcess::Closed => ArrivalProcess::Poisson { rate: per_conn },
            ArrivalProcess::Uniform { .. } => ArrivalProcess::Uniform { rate: per_conn },
            ArrivalProcess::Poisson { .. } => ArrivalProcess::Poisson { rate: per_conn },
            ArrivalProcess::Bursty { on_s, off_s, .. } => {
                ArrivalProcess::Bursty { burst_rate: 4.0 * per_conn, on_s, off_s }
            }
        };
        ScenarioSpec { arrivals, ..self.clone() }
    }
}

/// Stable per-connection seed derivation: mixes the scenario seed with
/// the connection index through SplitMix64 so streams are independent
/// but the whole run replays from one seed.
pub fn derive_seed(seed: u64, conn: usize) -> u64 {
    let mut sm = SplitMix64::new(seed ^ (conn as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sm.next_u64()
}

/// Aggregate outcome of one scenario run.
#[derive(Clone, Debug, Default)]
pub struct ScenarioReport {
    /// SUBMIT frames written.
    pub submitted: u64,
    /// COMPLETE frames with status OK.
    pub ok: u64,
    /// COMPLETE frames carrying a typed service error (shed, overload).
    pub service_errors: u64,
    /// Frames whose completion was lost to a dropped/failed connection.
    pub transport_errors: u64,
    /// Re-dials performed (reconnect storms count here).
    pub reconnects: u64,
    /// Wall-clock for the whole scenario, seconds.
    pub elapsed_s: f64,
    /// Per-frame submit-to-complete latency, sorted ascending, ns.
    pub latencies_ns: Vec<u64>,
}

impl ScenarioReport {
    /// Completed-OK frames per second of wall clock.
    pub fn qps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ok as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Latency percentile in ns (`q` in `[0, 1]`); 0 when empty.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        if self.latencies_ns.is_empty() {
            return 0;
        }
        let idx = ((self.latencies_ns.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        self.latencies_ns[idx]
    }

    /// Median latency, ns.
    pub fn p50_ns(&self) -> u64 {
        self.percentile_ns(0.50)
    }

    /// Tail latency, ns.
    pub fn p99_ns(&self) -> u64 {
        self.percentile_ns(0.99)
    }

    /// True when every submitted frame completed OK.
    pub fn all_ok(&self) -> bool {
        self.ok == self.submitted
    }
}

/// Arrival pacing with the optional ramp layered in. Mirrors
/// `WorkloadGen::advance_clock` but scales each gap by the ramp's
/// instantaneous rate multiplier.
struct ArrivalClock {
    process: ArrivalProcess,
    ramp: Option<RampSpec>,
    clock_s: f64,
    burst_elapsed: f64,
}

impl ArrivalClock {
    fn new(process: ArrivalProcess, ramp: Option<RampSpec>) -> Self {
        Self { process, ramp, clock_s: 0.0, burst_elapsed: 0.0 }
    }

    /// Absolute send time (seconds from stream start) of the next frame.
    fn next_at(&mut self, rng: &mut Xoshiro256) -> f64 {
        let gap = match self.process {
            ArrivalProcess::Closed => 0.0,
            ArrivalProcess::Uniform { rate } => 1.0 / rate,
            ArrivalProcess::Poisson { rate } => rng.exponential(rate),
            ArrivalProcess::Bursty { burst_rate, on_s, off_s } => {
                let mut gap = rng.exponential(burst_rate);
                self.burst_elapsed += gap;
                if self.burst_elapsed >= on_s {
                    gap += off_s;
                    self.burst_elapsed = 0.0;
                }
                gap
            }
        };
        let scale = self.ramp.map_or(1.0, |r| r.scale_at(self.clock_s));
        self.clock_s += gap / scale;
        self.clock_s
    }
}

/// One frame's worth of sampled work: a single (op, format) and `lanes`
/// operand pairs, encoded into the format's container bits.
struct FramePlan {
    op: OpKind,
    format: FormatKind,
    a: Vec<u64>,
    b: Vec<u64>,
}

fn sample_frame(spec: &ScenarioSpec, rng: &mut Xoshiro256) -> FramePlan {
    let op = if rng.chance(spec.divide_frac) {
        OpKind::Divide
    } else if rng.chance(0.5) {
        OpKind::Sqrt
    } else {
        OpKind::Rsqrt
    };
    let format = if spec.formats.is_empty() {
        FormatKind::F32
    } else {
        spec.formats[rng.next_below(spec.formats.len() as u64) as usize]
    };
    let lanes = spec.lanes.max(1);
    let mut a = Vec::with_capacity(lanes);
    let mut b = Vec::with_capacity(if op == OpKind::Divide { lanes } else { 0 });
    for _ in 0..lanes {
        let mut x = spec.dist.sample(rng);
        if op != OpKind::Divide {
            // sqrt family needs positive operands
            x = x.abs().max(f32::MIN_POSITIVE);
        }
        a.push(Value::from_f64(format, x as f64).bits());
        if op == OpKind::Divide {
            let mut y = spec.dist.sample(rng);
            if y.abs() < 1e-30 {
                y = 1.0;
            }
            b.push(Value::from_f64(format, y as f64).bits());
        }
    }
    FramePlan { op, format, a, b }
}

/// Per-connection tallies folded into the [`ScenarioReport`].
#[derive(Default)]
struct ConnTally {
    submitted: u64,
    ok: u64,
    service_errors: u64,
    transport_errors: u64,
    reconnects: u64,
    latencies_ns: Vec<u64>,
}

/// Drive one whole scenario against a listening server; blocks until
/// every connection finishes its share of frames (or dies trying —
/// transport losses are tallied, not fatal, so slow-loris and
/// chaos-fault scenarios report rather than abort).
pub fn run_scenario<A>(addr: A, spec: &ScenarioSpec) -> Result<ScenarioReport>
where
    A: ToSocketAddrs + Clone + Send + 'static,
{
    if spec.requests == 0 {
        bail!("scenario has no requests");
    }
    let start = Instant::now();
    let conns = spec.connections.max(1);
    let mut handles = Vec::with_capacity(conns);
    for idx in 0..conns {
        let spec = spec.clone();
        let addr = addr.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("loadgen-{idx}"))
                .spawn(move || run_connection(addr, &spec, idx, start))
                .context("spawning loadgen connection thread")?,
        );
    }
    let mut report = ScenarioReport::default();
    for h in handles {
        let tally = match h.join() {
            Ok(t) => t,
            Err(_) => bail!("loadgen connection thread panicked"),
        };
        report.submitted += tally.submitted;
        report.ok += tally.ok;
        report.service_errors += tally.service_errors;
        report.transport_errors += tally.transport_errors;
        report.reconnects += tally.reconnects;
        report.latencies_ns.extend(tally.latencies_ns);
    }
    report.elapsed_s = start.elapsed().as_secs_f64();
    report.latencies_ns.sort_unstable();
    Ok(report)
}

/// One probed offered rate in a [`sweep_max_qps`] run.
#[derive(Clone, Debug)]
pub struct SweepProbe {
    /// Total offered rate for this probe, frames/s.
    pub offered_qps: f64,
    /// Completed-OK throughput actually achieved, frames/s.
    pub achieved_qps: f64,
    /// Submit-to-complete p99 at this rate, ns.
    pub p99_ns: u64,
    /// Every submitted frame completed OK.
    pub all_ok: bool,
    /// `all_ok` and p99 within the SLO: this rate is sustained.
    pub sustained: bool,
}

/// Outcome of a max-sustained-qps sweep: every probe in the order it
/// ran, plus the knee.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// All probes, in execution order (climb phase then refinement).
    pub probes: Vec<SweepProbe>,
    /// Highest offered rate that met the SLO; 0 when even the starting
    /// rate missed it and refinement found no sustainable rate.
    pub max_sustained_qps: f64,
}

/// Doubling climb + binary refinement over a probe function. Split out
/// from the networked sweep so the search itself is unit-testable: the
/// probe returns `(achieved_qps, p99_ns, all_ok)` for an offered rate.
fn sweep_core<F>(start_qps: f64, slo_p99_ns: u64, mut run_probe: F) -> Result<SweepReport>
where
    F: FnMut(f64) -> Result<(f64, u64, bool)>,
{
    const CLIMB_STEPS: usize = 8;
    const REFINE_STEPS: usize = 5;
    let mut probes = Vec::new();
    let mut probe = |qps: f64, probes: &mut Vec<SweepProbe>| -> Result<bool> {
        let (achieved_qps, p99_ns, all_ok) = run_probe(qps)?;
        let sustained = all_ok && p99_ns <= slo_p99_ns;
        probes.push(SweepProbe { offered_qps: qps, achieved_qps, p99_ns, all_ok, sustained });
        Ok(sustained)
    };
    // geometric climb: double until the SLO breaks (or the climb budget
    // runs out, in which case the last sustained rate is the answer)
    let mut lo = 0.0f64; // highest sustained offered rate so far
    let mut hi = 0.0f64; // lowest unsustained offered rate so far
    let mut rate = start_qps.max(1.0);
    for _ in 0..CLIMB_STEPS {
        if probe(rate, &mut probes)? {
            lo = rate;
            rate *= 2.0;
        } else {
            hi = rate;
            break;
        }
    }
    // binary refinement between the last good and first bad rate
    if hi > 0.0 {
        for _ in 0..REFINE_STEPS {
            if lo > 0.0 && hi / lo < 1.1 {
                break;
            }
            let mid = if lo > 0.0 { (lo * hi).sqrt() } else { hi / 2.0 };
            if mid < 1.0 {
                break;
            }
            if probe(mid, &mut probes)? {
                lo = mid;
            } else {
                hi = mid;
            }
        }
    }
    Ok(SweepReport { probes, max_sustained_qps: lo })
}

/// Find the highest total offered rate the server sustains within a p99
/// SLO: each probe re-paces `template` (same mix, lanes, deadline,
/// connection count) to a candidate rate and runs it open-loop via
/// [`run_scenario`]; a rate is *sustained* when every frame completes
/// OK and the submit-to-complete p99 stays within `slo_p99`. Doubling
/// climb from `start_qps`, then geometric binary refinement to ~10%.
/// This is the engine behind `goldschmidt loadgen --sweep`.
pub fn sweep_max_qps<A>(
    addr: A,
    template: &ScenarioSpec,
    start_qps: f64,
    slo_p99: Duration,
) -> Result<SweepReport>
where
    A: ToSocketAddrs + Clone + Send + 'static,
{
    sweep_core(start_qps, slo_p99.as_nanos() as u64, |qps| {
        let report = run_scenario(addr.clone(), &template.with_total_rate(qps))?;
        Ok((report.qps(), report.p99_ns(), report.all_ok()))
    })
}

/// One connection's life: dial, pace its frame share open-loop, drain
/// completions on a side thread, re-dial on schedule or on error.
fn run_connection<A: ToSocketAddrs>(
    addr: A,
    spec: &ScenarioSpec,
    idx: usize,
    start: Instant,
) -> ConnTally {
    let mut tally = ConnTally::default();
    let mut rng = Xoshiro256::new(derive_seed(spec.seed, idx));
    let mut clock = ArrivalClock::new(spec.arrivals, spec.ramp);
    let slow = idx < spec.slow_conns;
    let read_delay =
        if slow { Some(Duration::from_micros(spec.read_delay_us.max(1))) } else { None };
    let mut remaining = spec.frames_for_conn(idx);
    let mut dialed = false;
    while remaining > 0 {
        let client = match NetClient::connect_with_flags(
            &addr,
            if spec.durable { FLAG_DURABLE } else { 0 },
        ) {
            Ok(c) => c,
            Err(_) => {
                // server gone: everything left on this connection is a
                // transport loss, not a hang
                tally.transport_errors += remaining as u64;
                return tally;
            }
        };
        if dialed {
            tally.reconnects += 1;
        }
        dialed = true;
        let durable = spec.durable && client.granted_flags() & FLAG_DURABLE != 0;
        let segment = if spec.reconnect_every > 0 {
            remaining.min(spec.reconnect_every)
        } else {
            remaining
        };
        let sent =
            run_segment(client, spec, segment, durable, read_delay, start, &mut rng, &mut clock,
                &mut tally);
        // a segment that died mid-stream (slow-loris shed, injected
        // conn-drop) still consumed `sent` frames of the share; a
        // segment that died before its first submit consumes one frame
        // as a transport loss so a dead server cannot loop us forever
        if sent == 0 {
            tally.transport_errors += 1;
        }
        remaining -= sent.max(1).min(remaining);
    }
    tally
}

/// Pace one connection segment; returns how many frames were submitted.
#[allow(clippy::too_many_arguments)]
fn run_segment(
    client: NetClient,
    spec: &ScenarioSpec,
    frames: usize,
    durable: bool,
    read_delay: Option<Duration>,
    start: Instant,
    rng: &mut Xoshiro256,
    clock: &mut ArrivalClock,
    tally: &mut ConnTally,
) -> usize {
    let (mut sender, mut receiver) = client.split();
    let in_flight: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let expected = Arc::new(AtomicU64::new(u64::MAX));
    // receiver thread: drain TICKET/COMPLETE frames until the segment's
    // completion count is reached or the connection dies under us
    let drain = {
        let in_flight = Arc::clone(&in_flight);
        let expected = Arc::clone(&expected);
        thread::spawn(move || {
            let mut tally = ConnTally::default();
            let mut done = 0u64;
            loop {
                if done >= expected.load(Ordering::Acquire) {
                    break;
                }
                if let Some(d) = read_delay {
                    thread::sleep(d);
                }
                match receiver.recv() {
                    Ok(Some(Event::Ticket { .. })) => {}
                    // scenario segments never poll stats; a stray reply
                    // (shared harness, stale poll) is not a completion
                    Ok(Some(Event::Stats(_))) => {}
                    Ok(Some(Event::Complete(c))) => {
                        done += 1;
                        let sent_at = in_flight.lock().unwrap().remove(&c.id);
                        match result_of(&c) {
                            Ok(_) => {
                                tally.ok += 1;
                                if let Some(t) = sent_at {
                                    tally.latencies_ns.push(t.elapsed().as_nanos() as u64);
                                }
                            }
                            Err(_) => tally.service_errors += 1,
                        }
                    }
                    // clean close or torn connection: whatever is still
                    // in flight is lost
                    Ok(None) | Err(_) => break,
                }
            }
            tally
        })
    };
    let mut sent = 0usize;
    for _ in 0..frames {
        let at_s = clock.next_at(rng);
        let due = start + Duration::from_secs_f64(at_s);
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        let plan = sample_frame(spec, rng);
        let opts = SubmitOpts { deadline_us: spec.deadline_us, durable };
        let sent_at = Instant::now();
        match sender.submit(plan.op, plan.format, &plan.a, &plan.b, opts) {
            Ok(id) => {
                in_flight.lock().unwrap().insert(id, sent_at);
                sent += 1;
                tally.submitted += 1;
            }
            // write failure = server dropped us; stop this segment
            Err(_) => break,
        }
    }
    expected.store(sent as u64, Ordering::Release);
    // FIN the write half: the server flushes outstanding completions
    // and closes, so the receiver sees them all then EOF — no window
    // where it blocks on a quiet socket after the last COMPLETE
    sender.finish();
    match drain.join() {
        Ok(t) => {
            let lost = (sent as u64).saturating_sub(t.ok + t.service_errors);
            tally.ok += t.ok;
            tally.service_errors += t.service_errors;
            tally.transport_errors += lost;
            tally.latencies_ns.extend(t.latencies_ns);
        }
        Err(_) => tally.transport_errors += sent as u64,
    }
    sent
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_all_resolve_and_split_requests() {
        for name in SCENARIOS {
            let spec = ScenarioSpec::preset(name, 1003, 5_000.0, 7).unwrap();
            assert_eq!(spec.requests, 1003, "{name}");
            let total: usize = (0..spec.connections).map(|i| spec.frames_for_conn(i)).sum();
            assert_eq!(total, 1003, "{name}");
        }
        assert!(ScenarioSpec::preset("nope", 10, 1.0, 0).is_none());
    }

    #[test]
    fn derived_seeds_differ_per_connection() {
        let seeds: Vec<u64> = (0..8).map(|i| derive_seed(42, i)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j]);
            }
        }
        // and the derivation is stable across runs
        assert_eq!(derive_seed(42, 3), derive_seed(42, 3));
    }

    #[test]
    fn ramp_scales_arrival_gaps() {
        let mut rng = Xoshiro256::new(1);
        let mut flat = ArrivalClock::new(ArrivalProcess::Uniform { rate: 100.0 }, None);
        let mut ramped = ArrivalClock::new(
            ArrivalProcess::Uniform { rate: 100.0 },
            Some(RampSpec { start_scale: 2.0, end_scale: 2.0, span_s: 1.0 }),
        );
        let (mut flat_t, mut ramp_t) = (0.0, 0.0);
        for _ in 0..50 {
            flat_t = flat.next_at(&mut rng);
        }
        for _ in 0..50 {
            ramp_t = ramped.next_at(&mut rng);
        }
        // constant 2x scale halves every gap
        assert!((ramp_t - flat_t / 2.0).abs() < 1e-9, "{ramp_t} vs {flat_t}");
    }

    #[test]
    fn ramp_scale_interpolates_then_holds() {
        let r = RampSpec { start_scale: 0.5, end_scale: 2.0, span_s: 2.0 };
        assert!((r.scale_at(0.0) - 0.5).abs() < 1e-12);
        assert!((r.scale_at(1.0) - 1.25).abs() < 1e-12);
        assert!((r.scale_at(2.0) - 2.0).abs() < 1e-12);
        assert!((r.scale_at(50.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sampled_frames_respect_spec_shape() {
        let spec = ScenarioSpec {
            lanes: 5,
            divide_frac: 1.0,
            formats: vec![FormatKind::F16],
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(9);
        for _ in 0..20 {
            let f = sample_frame(&spec, &mut rng);
            assert_eq!(f.op, OpKind::Divide);
            assert_eq!(f.format, FormatKind::F16);
            assert_eq!(f.a.len(), 5);
            assert_eq!(f.b.len(), 5);
            // f16 container: bits fit the 16-bit word
            assert!(f.a.iter().all(|w| *w <= u64::from(u16::MAX)));
        }
        let unary = ScenarioSpec { divide_frac: 0.0, ..spec };
        let f = sample_frame(&unary, &mut rng);
        assert!(f.b.is_empty());
    }

    #[test]
    fn with_total_rate_repaces_every_arrival_shape() {
        let base = ScenarioSpec { connections: 4, ..Default::default() };
        match base.with_total_rate(8_000.0).arrivals {
            ArrivalProcess::Poisson { rate } => assert!((rate - 2_000.0).abs() < 1e-9),
            other => panic!("expected Poisson, got {other:?}"),
        }
        let bursty = ScenarioSpec {
            connections: 2,
            arrivals: ArrivalProcess::Bursty { burst_rate: 1.0, on_s: 0.020, off_s: 0.060 },
            ..Default::default()
        };
        match bursty.with_total_rate(1_000.0).arrivals {
            ArrivalProcess::Bursty { burst_rate, on_s, off_s } => {
                // duty cycle preserved, peak re-derived from the new mean
                assert!((burst_rate - 2_000.0).abs() < 1e-9);
                assert!((on_s - 0.020).abs() < 1e-12);
                assert!((off_s - 0.060).abs() < 1e-12);
            }
            other => panic!("expected Bursty, got {other:?}"),
        }
        // closed-loop becomes open-loop Poisson so a sweep can pace it
        let closed = ScenarioSpec { arrivals: ArrivalProcess::Closed, ..Default::default() };
        assert!(matches!(
            closed.with_total_rate(100.0).arrivals,
            ArrivalProcess::Poisson { .. }
        ));
    }

    #[test]
    fn sweep_core_finds_the_capacity_knee() {
        // synthetic server: sustains anything at or below 10_000 qps
        let capacity = 10_000.0;
        let report = sweep_core(1_000.0, 5_000_000, |qps| {
            let ok = qps <= capacity;
            Ok((qps.min(capacity), if ok { 1_000_000 } else { 50_000_000 }, ok))
        })
        .unwrap();
        // climbs 1k,2k,4k,8k,16k then refines between 8k and 16k
        assert!(report.probes.len() >= 5, "only {} probes", report.probes.len());
        assert!(
            report.max_sustained_qps >= 8_000.0 && report.max_sustained_qps <= capacity,
            "knee {} outside (8000, {capacity}]",
            report.max_sustained_qps
        );
        // the refinement converged to within ~10% of the true knee
        assert!(report.max_sustained_qps >= capacity / 1.2);
        // every recorded probe carries a coherent verdict
        for p in &report.probes {
            assert_eq!(p.sustained, p.all_ok && p.p99_ns <= 5_000_000);
        }
    }

    #[test]
    fn sweep_core_reports_zero_when_even_the_floor_fails() {
        let report =
            sweep_core(1_000.0, 1_000, |_| Ok((0.0, 1_000_000, false))).unwrap();
        assert_eq!(report.max_sustained_qps, 0.0);
        assert!(!report.probes.is_empty());
        assert!(report.probes.iter().all(|p| !p.sustained));
    }

    #[test]
    fn report_percentiles_and_qps() {
        let report = ScenarioReport {
            submitted: 4,
            ok: 4,
            elapsed_s: 2.0,
            latencies_ns: vec![10, 20, 30, 40],
            ..Default::default()
        };
        assert!(report.all_ok());
        assert!((report.qps() - 2.0).abs() < 1e-12);
        assert_eq!(report.p50_ns(), 20);
        assert_eq!(report.percentile_ns(1.0), 40);
        assert_eq!(ScenarioReport::default().p99_ns(), 0);
    }
}
