//! Request workload generator: operand values and arrival times for the
//! FPU-service experiments (E2E throughput/latency bench and the
//! `fpu_service` example).

use crate::coordinator::request::{FormatKind, OpKind, Value};
use crate::util::rng::Xoshiro256;

/// Operand value distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OperandDist {
    /// Uniform in `[lo, hi)`.
    Uniform { lo: f32, hi: f32 },
    /// Log-normal with log-space mu/sigma (heavy-tailed magnitudes, the
    /// realistic FPU feed).
    LogNormal { mu: f64, sigma: f64 },
    /// Uniform mantissas in `[1, 2)` (datapath-native).
    Mantissa,
}

impl OperandDist {
    /// Draw one operand.
    pub fn sample(&self, rng: &mut Xoshiro256) -> f32 {
        match self {
            OperandDist::Uniform { lo, hi } => rng.range_f32(*lo, *hi),
            OperandDist::LogNormal { mu, sigma } => rng.lognormal(*mu, *sigma) as f32,
            OperandDist::Mantissa => rng.range_f32(1.0, 2.0),
        }
    }
}

/// Request inter-arrival process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// Fixed spacing at `rate` requests/second.
    Uniform { rate: f64 },
    /// ON/OFF bursts: Poisson at `burst_rate` for `on_s`, silent `off_s`.
    Bursty { burst_rate: f64, on_s: f64, off_s: f64 },
    /// Everything at t=0 (closed-loop saturation).
    Closed,
}

/// A generated request, before entering the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct GenRequest {
    /// Operation kind.
    pub op: OpKind,
    /// IEEE format the request is served in.
    pub format: FormatKind,
    /// First operand (sampled at f32 precision; encode into the request
    /// format with [`GenRequest::value_a`]).
    pub a: f32,
    /// Second operand (1.0 for unary ops).
    pub b: f32,
    /// Arrival offset from stream start, seconds.
    pub at_s: f64,
}

impl GenRequest {
    /// First operand encoded into the request format (RNE).
    pub fn value_a(&self) -> Value {
        Value::from_f64(self.format, self.a as f64)
    }

    /// Second operand encoded into the request format (RNE).
    pub fn value_b(&self) -> Value {
        Value::from_f64(self.format, self.b as f64)
    }
}

/// Full workload specification.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// Number of requests.
    pub count: usize,
    /// Operand distribution.
    pub dist: OperandDist,
    /// Arrival process.
    pub arrivals: ArrivalProcess,
    /// Mix: probability of divide (remainder split evenly sqrt/rsqrt).
    pub divide_frac: f64,
    /// IEEE format every request is tagged with.
    pub format: FormatKind,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            count: 10_000,
            dist: OperandDist::LogNormal { mu: 0.0, sigma: 2.0 },
            arrivals: ArrivalProcess::Closed,
            divide_frac: 1.0,
            format: FormatKind::F32,
            seed: 0xFEED,
        }
    }
}

/// Iterator-style generator over a [`WorkloadSpec`].
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Xoshiro256,
    emitted: usize,
    clock_s: f64,
    burst_elapsed: f64,
}

impl WorkloadGen {
    /// New generator.
    pub fn new(spec: WorkloadSpec) -> Self {
        Self { spec, rng: Xoshiro256::new(spec.seed), emitted: 0, clock_s: 0.0, burst_elapsed: 0.0 }
    }

    /// Generate the whole workload eagerly.
    pub fn generate(spec: WorkloadSpec) -> Vec<GenRequest> {
        let mut g = Self::new(spec);
        let mut out = Vec::with_capacity(spec.count);
        while let Some(r) = g.next_request() {
            out.push(r);
        }
        out
    }

    /// Next request, or `None` when the spec count is exhausted.
    pub fn next_request(&mut self) -> Option<GenRequest> {
        if self.emitted >= self.spec.count {
            return None;
        }
        self.emitted += 1;
        let op = self.pick_op();
        let a = self.spec.dist.sample(&mut self.rng);
        let b = match op {
            OpKind::Divide => {
                // keep divisors away from zero
                let mut b = self.spec.dist.sample(&mut self.rng);
                if b.abs() < 1e-30 {
                    b = 1.0;
                }
                b
            }
            _ => 1.0,
        };
        let a = match op {
            OpKind::Divide => a,
            // sqrt family needs nonnegative operands
            _ => a.abs().max(f32::MIN_POSITIVE),
        };
        self.advance_clock();
        Some(GenRequest { op, format: self.spec.format, a, b, at_s: self.clock_s })
    }

    fn pick_op(&mut self) -> OpKind {
        if self.rng.chance(self.spec.divide_frac) {
            OpKind::Divide
        } else if self.rng.chance(0.5) {
            OpKind::Sqrt
        } else {
            OpKind::Rsqrt
        }
    }

    fn advance_clock(&mut self) {
        match self.spec.arrivals {
            ArrivalProcess::Closed => {}
            ArrivalProcess::Uniform { rate } => {
                self.clock_s += 1.0 / rate;
            }
            ArrivalProcess::Poisson { rate } => {
                self.clock_s += self.rng.exponential(rate);
            }
            ArrivalProcess::Bursty { burst_rate, on_s, off_s } => {
                let gap = self.rng.exponential(burst_rate);
                self.clock_s += gap;
                self.burst_elapsed += gap;
                if self.burst_elapsed >= on_s {
                    self.clock_s += off_s;
                    self.burst_elapsed = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_exact_count() {
        let spec = WorkloadSpec { count: 137, ..Default::default() };
        assert_eq!(WorkloadGen::generate(spec).len(), 137);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec { count: 50, seed: 99, ..Default::default() };
        let a = WorkloadGen::generate(spec);
        let b = WorkloadGen::generate(spec);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.b, y.b);
            assert_eq!(x.at_s, y.at_s);
        }
    }

    #[test]
    fn divide_only_mix() {
        let spec = WorkloadSpec { count: 200, divide_frac: 1.0, ..Default::default() };
        assert!(WorkloadGen::generate(spec).iter().all(|r| r.op == OpKind::Divide));
    }

    #[test]
    fn mixed_ops_cover_all_kinds() {
        let spec = WorkloadSpec { count: 500, divide_frac: 0.5, ..Default::default() };
        let reqs = WorkloadGen::generate(spec);
        let div = reqs.iter().filter(|r| r.op == OpKind::Divide).count();
        let sqrt = reqs.iter().filter(|r| r.op == OpKind::Sqrt).count();
        let rsqrt = reqs.iter().filter(|r| r.op == OpKind::Rsqrt).count();
        assert!(div > 150 && sqrt > 50 && rsqrt > 50, "{div}/{sqrt}/{rsqrt}");
    }

    #[test]
    fn sqrt_operands_nonnegative() {
        let spec = WorkloadSpec {
            count: 500,
            divide_frac: 0.0,
            dist: OperandDist::Uniform { lo: -10.0, hi: 10.0 },
            ..Default::default()
        };
        assert!(WorkloadGen::generate(spec).iter().all(|r| r.a > 0.0));
    }

    #[test]
    fn poisson_arrivals_monotone_with_correct_mean() {
        let spec = WorkloadSpec {
            count: 5000,
            arrivals: ArrivalProcess::Poisson { rate: 1000.0 },
            ..Default::default()
        };
        let reqs = WorkloadGen::generate(spec);
        for w in reqs.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
        let span = reqs.last().unwrap().at_s;
        let expect = 5000.0 / 1000.0;
        assert!((span - expect).abs() / expect < 0.15, "span {span} vs {expect}");
    }

    #[test]
    fn closed_arrivals_all_at_zero() {
        let spec =
            WorkloadSpec { count: 10, arrivals: ArrivalProcess::Closed, ..Default::default() };
        assert!(WorkloadGen::generate(spec).iter().all(|r| r.at_s == 0.0));
    }

    #[test]
    fn mantissa_dist_in_range() {
        let spec = WorkloadSpec {
            count: 300,
            dist: OperandDist::Mantissa,
            ..Default::default()
        };
        for r in WorkloadGen::generate(spec) {
            assert!((1.0..2.0).contains(&r.a));
        }
    }

    #[test]
    fn format_tags_and_values_follow_spec() {
        let spec = WorkloadSpec { count: 50, format: FormatKind::F16, ..Default::default() };
        for r in WorkloadGen::generate(spec) {
            assert_eq!(r.format, FormatKind::F16);
            assert_eq!(r.value_a().format(), FormatKind::F16);
            // the encoded operand is the format's rounding of the sample
            assert_eq!(r.value_a(), Value::from_f64(FormatKind::F16, r.a as f64));
        }
        // default stays f32 so existing workloads are unchanged
        assert_eq!(WorkloadSpec::default().format, FormatKind::F32);
    }
}
