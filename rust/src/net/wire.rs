//! The wire codec: a compact length-prefixed binary protocol over TCP.
//!
//! Framing reuses the journal's discipline (`crate::coordinator::journal`):
//! every frame is
//!
//! ```text
//! len: u32 LE | crc32(payload): u32 LE | payload
//! ```
//!
//! with the same IEEE CRC-32 and the same torn-frame stance — a length
//! or checksum that doesn't add up is a protocol error, never a panic
//! or a silent truncation. `payload[0]` is the frame kind:
//!
//! | kind | frame | body |
//! |---|---|---|
//! | 1 | `HELLO` | `version u32, flags u32` |
//! | 2 | `SUBMIT` | `id u64, op u8, format u8, flags u8, deadline_us u32, n_a u32, n_b u32, a[n_a] u64, b[n_b] u64` |
//! | 3 | `TICKET` | `id u64` |
//! | 4 | `COMPLETE` | `id u64, status u8, n u32, results[n] u64, msg_len u32, msg bytes` |
//!
//! All integers little-endian. Operand/result lanes travel as raw
//! format words widened to `u64`, exactly the
//! [`ServiceHandle::submit_batch`](crate::coordinator::ServiceHandle::submit_batch)
//! contract — a `SUBMIT` frame maps 1:1 onto one vectored submission.
//! Op and format bytes are the journal's own encodings
//! (divide=0/sqrt=1/rsqrt=2; f16=0/bf16=1/f32=2/f64=3), so a wire
//! capture and a journal dump read the same.
//!
//! # Handshake
//!
//! The client speaks first: one `HELLO{version, flags}`. The server
//! answers with its own `HELLO{version, flags & supported}` — the
//! version it will speak (currently there is exactly one) and the
//! subset of requested flags it honours; a client asking for
//! [`FLAG_DURABLE`] on a journal-less service sees the bit cleared in
//! the reply and knows durable submits would be rejected. A version the
//! server does not speak ends the connection after the reply.
//!
//! # Status codes
//!
//! `COMPLETE.status` is the typed [`ServiceError`] surface flattened
//! onto the wire ([`status_of`] / [`error_from_status`] are inverse up
//! to the carried message): 0 ok, 1 rejected, 2 overloaded, 3
//! exec-failed, 4 deadline, 5 shutdown.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::journal::{
    crc32, format_from_byte, format_to_byte, op_from_byte, op_to_byte,
};
use crate::coordinator::{FormatKind, OpKind, ServiceError};

/// The one protocol version this build speaks.
pub const WIRE_VERSION: u32 = 1;

/// HELLO flag: the client intends to use durable (journalled)
/// submissions. The server clears it in its reply when the service has
/// no journal.
pub const FLAG_DURABLE: u32 = 1;

/// SUBMIT flag bit: journal this batch (`submit_batch_durable` path).
pub const SUBMIT_DURABLE: u8 = 1;

/// Frame size guard, mirroring the journal's `MAX_RECORD` stance: a
/// corrupt length prefix must not become a giant allocation. 16 MiB
/// bounds a submit at ~1M lanes — far beyond any batch ladder.
pub const MAX_FRAME: usize = 16 << 20;

/// Completion status codes (the [`ServiceError`] surface on the wire).
pub const STATUS_OK: u8 = 0;
pub const STATUS_REJECTED: u8 = 1;
pub const STATUS_OVERLOADED: u8 = 2;
pub const STATUS_EXEC_FAILED: u8 = 3;
pub const STATUS_DEADLINE: u8 = 4;
pub const STATUS_SHUTDOWN: u8 = 5;

const KIND_HELLO: u8 = 1;
const KIND_SUBMIT: u8 = 2;
const KIND_TICKET: u8 = 3;
const KIND_COMPLETE: u8 = 4;

/// A `SUBMIT` body: one vectored batch, client-assigned id.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitFrame {
    /// Client-assigned request id; completions echo it, and the trace
    /// plane samples/groups the request's spans under it.
    pub id: u64,
    pub op: OpKind,
    pub format: FormatKind,
    /// Bit 0 ([`SUBMIT_DURABLE`]): journal before queueing.
    pub flags: u8,
    /// Completion deadline in microseconds; 0 = none.
    pub deadline_us: u32,
    /// Operand plane A, raw format words widened to u64.
    pub a: Vec<u64>,
    /// Operand plane B (divisors; empty for unary ops).
    pub b: Vec<u64>,
}

/// A `COMPLETE` body: the outcome of one submit, out-of-order by id.
#[derive(Clone, Debug, PartialEq)]
pub struct CompleteFrame {
    pub id: u64,
    /// One of the `STATUS_*` codes.
    pub status: u8,
    /// Result plane, lane order preserved (empty unless `STATUS_OK`).
    pub results: Vec<u64>,
    /// Human-readable error detail (empty on `STATUS_OK`).
    pub error: String,
}

/// One decoded wire frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Handshake, both directions.
    Hello { version: u32, flags: u32 },
    /// Client → server: one vectored batch.
    Submit(SubmitFrame),
    /// Server → client: the submit with this id was accepted and queued.
    Ticket { id: u64 },
    /// Server → client: terminal outcome for this id.
    Complete(CompleteFrame),
}

/// Map a typed service error to its wire status code.
pub fn status_of(err: &ServiceError) -> u8 {
    match err {
        ServiceError::Rejected { .. } => STATUS_REJECTED,
        ServiceError::Overloaded => STATUS_OVERLOADED,
        ServiceError::ExecFailed { .. } => STATUS_EXEC_FAILED,
        ServiceError::Deadline => STATUS_DEADLINE,
        ServiceError::Shutdown => STATUS_SHUTDOWN,
    }
}

/// Reconstruct a typed service error from a wire status + message (the
/// client-side inverse of [`status_of`]; unknown codes land on
/// `Rejected` with the code in the reason).
pub fn error_from_status(status: u8, msg: &str) -> ServiceError {
    match status {
        STATUS_REJECTED => ServiceError::Rejected { reason: msg.to_string() },
        STATUS_OVERLOADED => ServiceError::Overloaded,
        STATUS_EXEC_FAILED => ServiceError::ExecFailed { backend: msg.to_string() },
        STATUS_DEADLINE => ServiceError::Deadline,
        STATUS_SHUTDOWN => ServiceError::Shutdown,
        other => ServiceError::Rejected { reason: format!("unknown wire status {other}: {msg}") },
    }
}

fn put_words(out: &mut Vec<u8>, words: &[u64]) {
    for &w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Encode a frame's payload (kind byte + body, no len/crc prefix).
fn encode_payload(frame: &Frame) -> Vec<u8> {
    match frame {
        Frame::Hello { version, flags } => {
            let mut out = Vec::with_capacity(9);
            out.push(KIND_HELLO);
            out.extend_from_slice(&version.to_le_bytes());
            out.extend_from_slice(&flags.to_le_bytes());
            out
        }
        Frame::Submit(s) => {
            let mut out = Vec::with_capacity(27 + 8 * (s.a.len() + s.b.len()));
            out.push(KIND_SUBMIT);
            out.extend_from_slice(&s.id.to_le_bytes());
            out.push(op_to_byte(s.op));
            out.push(format_to_byte(s.format));
            out.push(s.flags);
            out.extend_from_slice(&s.deadline_us.to_le_bytes());
            out.extend_from_slice(&(s.a.len() as u32).to_le_bytes());
            out.extend_from_slice(&(s.b.len() as u32).to_le_bytes());
            put_words(&mut out, &s.a);
            put_words(&mut out, &s.b);
            out
        }
        Frame::Ticket { id } => {
            let mut out = Vec::with_capacity(9);
            out.push(KIND_TICKET);
            out.extend_from_slice(&id.to_le_bytes());
            out
        }
        Frame::Complete(c) => {
            let mut out = Vec::with_capacity(18 + 8 * c.results.len() + c.error.len());
            out.push(KIND_COMPLETE);
            out.extend_from_slice(&c.id.to_le_bytes());
            out.push(c.status);
            out.extend_from_slice(&(c.results.len() as u32).to_le_bytes());
            put_words(&mut out, &c.results);
            out.extend_from_slice(&(c.error.len() as u32).to_le_bytes());
            out.extend_from_slice(c.error.as_bytes());
            out
        }
    }
}

/// A zero-copy cursor over a payload; every read is bounds-checked into
/// a typed protocol error.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.at < n {
            bail!(
                "truncated frame body: wanted {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn words(&mut self, n: usize) -> Result<Vec<u64>> {
        let bytes = self.take(8 * n)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after frame body", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Decode one payload (kind byte + body) back into a [`Frame`].
fn decode_payload(payload: &[u8]) -> Result<Frame> {
    let mut c = Cursor { buf: payload, at: 0 };
    let frame = match c.u8().context("empty frame payload")? {
        KIND_HELLO => Frame::Hello { version: c.u32()?, flags: c.u32()? },
        KIND_SUBMIT => {
            let id = c.u64()?;
            let op = op_from_byte(c.u8()?)?;
            let format = format_from_byte(c.u8()?)?;
            let flags = c.u8()?;
            let deadline_us = c.u32()?;
            let n_a = c.u32()? as usize;
            let n_b = c.u32()? as usize;
            // the plane counts were inside the CRC-checked payload, but
            // still bound them against the frame we actually hold
            // before allocating
            if 8 * (n_a + n_b) > payload.len() {
                bail!("submit lane counts ({n_a}+{n_b}) exceed the frame");
            }
            let a = c.words(n_a)?;
            let b = c.words(n_b)?;
            Frame::Submit(SubmitFrame { id, op, format, flags, deadline_us, a, b })
        }
        KIND_TICKET => Frame::Ticket { id: c.u64()? },
        KIND_COMPLETE => {
            let id = c.u64()?;
            let status = c.u8()?;
            let n = c.u32()? as usize;
            if 8 * n > payload.len() {
                bail!("complete lane count {n} exceeds the frame");
            }
            let results = c.words(n)?;
            let msg_len = c.u32()? as usize;
            let error = String::from_utf8(c.take(msg_len)?.to_vec())
                .context("complete error message is not UTF-8")?;
            Frame::Complete(CompleteFrame { id, status, results, error })
        }
        other => bail!("unknown frame kind {other}"),
    };
    c.done()?;
    Ok(frame)
}

/// Encode a frame to its full wire bytes (`len | crc | payload`).
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let payload = encode_payload(frame);
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Write one frame (a single `write_all`, as the journal appends).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    w.write_all(&encode_frame(frame)).context("writing wire frame")
}

/// Blocking-read one frame: length prefix, CRC check, decode. An EOF
/// **before any prefix byte** is a clean close (`Ok(None)`); anywhere
/// else it is a torn frame and an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut prefix = [0u8; 8];
    // distinguish clean close from mid-prefix EOF by hand
    let mut got = 0;
    while got < prefix.len() {
        let n = r.read(&mut prefix[got..]).context("reading frame prefix")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-prefix ({got}/8 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(prefix[0..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(prefix[4..8].try_into().unwrap());
    if len == 0 || len > MAX_FRAME {
        bail!("bad frame length {len} (max {MAX_FRAME})");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let actual = crc32(&payload);
    if actual != crc {
        bail!("frame CRC mismatch: stored {crc:#010x}, computed {actual:#010x}");
    }
    decode_payload(&payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(frame: Frame) {
        let bytes = encode_frame(&frame);
        let mut r = &bytes[..];
        let back = read_frame(&mut r).unwrap().expect("a frame, not EOF");
        assert_eq!(back, frame);
        // and the stream is exactly consumed
        assert!(r.is_empty());
    }

    #[test]
    fn frames_round_trip() {
        round_trip(Frame::Hello { version: WIRE_VERSION, flags: FLAG_DURABLE });
        round_trip(Frame::Ticket { id: 0xDEAD_BEEF_0042 });
        round_trip(Frame::Submit(SubmitFrame {
            id: 7,
            op: OpKind::Divide,
            format: FormatKind::F16,
            flags: SUBMIT_DURABLE,
            deadline_us: 1500,
            a: vec![0x3C00, 0x4200, 0x7BFF],
            b: vec![0x3800, 0x4000, 0x3C00],
        }));
        round_trip(Frame::Submit(SubmitFrame {
            id: u64::MAX,
            op: OpKind::Rsqrt,
            format: FormatKind::F64,
            flags: 0,
            deadline_us: 0,
            a: vec![0x4000_0000_0000_0000],
            b: vec![],
        }));
        round_trip(Frame::Complete(CompleteFrame {
            id: 7,
            status: STATUS_OK,
            results: vec![1, 2, 3],
            error: String::new(),
        }));
        round_trip(Frame::Complete(CompleteFrame {
            id: 9,
            status: STATUS_EXEC_FAILED,
            results: vec![],
            error: "backend execution failed: scalar-reference".into(),
        }));
    }

    #[test]
    fn several_frames_stream_back_to_back() {
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION, flags: 0 },
            Frame::Ticket { id: 1 },
            Frame::Ticket { id: 2 },
        ];
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&encode_frame(f));
        }
        let mut r = &bytes[..];
        for f in &frames {
            assert_eq!(read_frame(&mut r).unwrap().as_ref(), Some(f));
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "then a clean EOF");
    }

    #[test]
    fn corrupt_frames_are_typed_errors() {
        let good = encode_frame(&Frame::Ticket { id: 42 });

        // flipped payload bit -> CRC mismatch
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x40;
        let err = read_frame(&mut &bad[..]).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");

        // truncated payload (torn tail) -> read error, not a hang/panic
        let torn = &good[..good.len() - 3];
        assert!(read_frame(&mut &torn[..]).is_err());

        // mid-prefix EOF is distinguished from a clean close
        let stub = &good[..5];
        let err = read_frame(&mut &stub[..]).unwrap_err().to_string();
        assert!(err.contains("mid-prefix"), "{err}");

        // an oversized length prefix is rejected before allocating
        let mut huge = good.clone();
        huge[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut &huge[..]).unwrap_err().to_string();
        assert!(err.contains("bad frame length"), "{err}");

        // unknown kind byte survives the CRC but fails decode
        let payload = [99u8];
        let mut frame = Vec::new();
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&crate::coordinator::journal::crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let err = read_frame(&mut &frame[..]).unwrap_err().to_string();
        assert!(err.contains("unknown frame kind"), "{err}");
    }

    #[test]
    fn status_codes_round_trip_the_error_surface() {
        let errors = [
            ServiceError::Rejected { reason: "empty batch".into() },
            ServiceError::Overloaded,
            ServiceError::ExecFailed { backend: "native-fixed-point".into() },
            ServiceError::Deadline,
            ServiceError::Shutdown,
        ];
        for err in errors {
            let status = status_of(&err);
            assert_ne!(status, STATUS_OK);
            let back = error_from_status(status, &format!("{err}"));
            assert_eq!(status_of(&back), status, "status stable through a round trip");
        }
        assert!(matches!(error_from_status(200, "?"), ServiceError::Rejected { .. }));
    }
}
