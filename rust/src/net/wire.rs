//! The wire codec: a compact length-prefixed binary protocol over TCP.
//!
//! Framing reuses the journal's discipline (`crate::coordinator::journal`):
//! every frame is
//!
//! ```text
//! len: u32 LE | crc32(payload): u32 LE | payload
//! ```
//!
//! with the same IEEE CRC-32 and the same torn-frame stance — a length
//! or checksum that doesn't add up is a protocol error, never a panic
//! or a silent truncation. `payload[0]` is the frame kind:
//!
//! | kind | frame | body |
//! |---|---|---|
//! | 1 | `HELLO` | `version u32, flags u32` |
//! | 2 | `SUBMIT` | `id u64, op u8, format u8, flags u8, deadline_us u32, n_a u32, n_b u32, a[n_a] u64, b[n_b] u64` |
//! | 3 | `TICKET` | `id u64` |
//! | 4 | `COMPLETE` | `id u64, status u8, n u32, results[n] u64, msg_len u32, msg bytes` |
//! | 5 | `STATS_REQUEST` | (empty) |
//! | 6 | `STATS` | `version u32, server_ns u64, respawns u64, trace_drops u64, trace_errors u64, n_slots u32, slots[], n_shards u32, shards[], n_backends u32, backends[], net[8] u64` — see [`StatsFrame`] |
//!
//! All integers little-endian. Operand/result lanes travel as raw
//! format words widened to `u64`, exactly the
//! [`ServiceHandle::submit_batch`](crate::coordinator::ServiceHandle::submit_batch)
//! contract — a `SUBMIT` frame maps 1:1 onto one vectored submission.
//! Op and format bytes are the journal's own encodings
//! (divide=0/sqrt=1/rsqrt=2; f16=0/bf16=1/f32=2/f64=3), so a wire
//! capture and a journal dump read the same.
//!
//! # Handshake
//!
//! The client speaks first: one `HELLO{version, flags}`. The server
//! answers with its own `HELLO{version, flags & supported}` — the
//! version it will speak (currently there is exactly one) and the
//! subset of requested flags it honours; a client asking for
//! [`FLAG_DURABLE`] on a journal-less service sees the bit cleared in
//! the reply and knows durable submits would be rejected. A version the
//! server does not speak ends the connection after the reply.
//!
//! # Status codes
//!
//! `COMPLETE.status` is the typed [`ServiceError`] surface flattened
//! onto the wire ([`status_of`] / [`error_from_status`] are inverse up
//! to the carried message): 0 ok, 1 rejected, 2 overloaded, 3
//! exec-failed, 4 deadline, 5 shutdown.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::journal::{
    crc32, format_from_byte, format_to_byte, op_from_byte, op_to_byte,
};
use crate::coordinator::{FormatKind, OpKind, ServiceError};

/// The one protocol version this build speaks.
pub const WIRE_VERSION: u32 = 1;

/// HELLO flag: the client intends to use durable (journalled)
/// submissions. The server clears it in its reply when the service has
/// no journal.
pub const FLAG_DURABLE: u32 = 1;

/// SUBMIT flag bit: journal this batch (`submit_batch_durable` path).
pub const SUBMIT_DURABLE: u8 = 1;

/// Frame size guard, mirroring the journal's `MAX_RECORD` stance: a
/// corrupt length prefix must not become a giant allocation. 16 MiB
/// bounds a submit at ~1M lanes — far beyond any batch ladder.
pub const MAX_FRAME: usize = 16 << 20;

/// Completion status codes (the [`ServiceError`] surface on the wire).
pub const STATUS_OK: u8 = 0;
pub const STATUS_REJECTED: u8 = 1;
pub const STATUS_OVERLOADED: u8 = 2;
pub const STATUS_EXEC_FAILED: u8 = 3;
pub const STATUS_DEADLINE: u8 = 4;
pub const STATUS_SHUTDOWN: u8 = 5;

const KIND_HELLO: u8 = 1;
const KIND_SUBMIT: u8 = 2;
const KIND_TICKET: u8 = 3;
const KIND_COMPLETE: u8 = 4;
const KIND_STATS_REQUEST: u8 = 5;
const KIND_STATS: u8 = 6;

/// Version of the `STATS` snapshot body. Bumped whenever a field is
/// added or its meaning changes; clients check it before interpreting.
pub const STATS_VERSION: u32 = 1;

/// A `SUBMIT` body: one vectored batch, client-assigned id.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitFrame {
    /// Client-assigned request id; completions echo it, and the trace
    /// plane samples/groups the request's spans under it.
    pub id: u64,
    pub op: OpKind,
    pub format: FormatKind,
    /// Bit 0 ([`SUBMIT_DURABLE`]): journal before queueing.
    pub flags: u8,
    /// Completion deadline in microseconds; 0 = none.
    pub deadline_us: u32,
    /// Operand plane A, raw format words widened to u64.
    pub a: Vec<u64>,
    /// Operand plane B (divisors; empty for unary ops).
    pub b: Vec<u64>,
}

/// A `COMPLETE` body: the outcome of one submit, out-of-order by id.
#[derive(Clone, Debug, PartialEq)]
pub struct CompleteFrame {
    pub id: u64,
    /// One of the `STATUS_*` codes.
    pub status: u8,
    /// Result plane, lane order preserved (empty unless `STATUS_OK`).
    pub results: Vec<u64>,
    /// Human-readable error detail (empty on `STATUS_OK`).
    pub error: String,
}

/// One per-(op, format) slot in a `STATS` snapshot (raw counters —
/// clients compute rates from successive snapshots and `server_ns`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SlotStats {
    pub op: OpKind,
    pub format: FormatKind,
    /// Lanes completed.
    pub requests: u64,
    pub errors: u64,
    pub shed: u64,
    pub admission_rejected: u64,
    pub p50_latency_ns: u64,
    pub p99_latency_ns: u64,
    /// Lanes currently queued on this slot (gauge).
    pub queued_lanes: u64,
}

/// One coordinator shard's row in a `STATS` snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct ShardStats {
    /// Approximate submit-ring occupancy (gauge).
    pub ring_depth: u32,
    pub ring_capacity: u32,
    /// Lanes queued across this shard's (op, format) slots (gauge).
    pub queued_lanes: u64,
    /// Formed batches waiting in the ready queue (gauge).
    pub ready_batches: u32,
    /// Age of the oldest ready batch in microseconds (gauge; 0 when
    /// the queue is empty).
    pub oldest_ready_us: u64,
    /// Batches this shard stole from peers.
    pub steals_in: u64,
    /// Batches peers stole from this shard.
    pub steals_out: u64,
    /// Submissions rejected because this shard's ring was full.
    pub ring_full_rejects: u64,
}

/// One backend's health row in a `STATS` snapshot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendStats {
    pub name: String,
    pub breaker_open: bool,
    pub degraded: bool,
    pub ok_batches: u64,
    pub failed_batches: u64,
    pub rerouted: u64,
    pub respawns: u64,
}

/// Net-plane counters in a `STATS` snapshot (zeroed when the snapshot
/// is built without a wire front end, e.g. in-process callers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct NetCounters {
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Connections currently open (gauge).
    pub active_connections: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub submits: u64,
    pub completes: u64,
    pub slow_client_drops: u64,
    pub protocol_errors: u64,
}

/// A `STATS` body: a versioned snapshot of the serving plane. All
/// counters are raw totals plus the server's monotonic `server_ns`, so
/// a polling client (`loadgen --stats-poll`) differences successive
/// snapshots for rates without trusting its own clock.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsFrame {
    /// [`STATS_VERSION`] of the sender.
    pub version: u32,
    /// Server monotonic nanoseconds (since service start).
    pub server_ns: u64,
    /// Workers respawned by the supervisor.
    pub respawns: u64,
    /// Trace-plane ring drops (sampled lifecycle events lost).
    pub trace_drops: u64,
    /// Trace-plane error-class events captured.
    pub trace_errors: u64,
    /// Per-(op, format) rows.
    pub slots: Vec<SlotStats>,
    /// Per-coordinator-shard rows.
    pub shards: Vec<ShardStats>,
    /// Per-backend health rows.
    pub backends: Vec<BackendStats>,
    /// Wire front-end counters.
    pub net: NetCounters,
}

/// One decoded wire frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Handshake, both directions.
    Hello { version: u32, flags: u32 },
    /// Client → server: one vectored batch.
    Submit(SubmitFrame),
    /// Server → client: the submit with this id was accepted and queued.
    Ticket { id: u64 },
    /// Server → client: terminal outcome for this id.
    Complete(CompleteFrame),
    /// Client → server: snapshot request (empty body).
    StatsRequest,
    /// Server → client: the versioned snapshot.
    Stats(StatsFrame),
}

/// Map a typed service error to its wire status code.
pub fn status_of(err: &ServiceError) -> u8 {
    match err {
        ServiceError::Rejected { .. } => STATUS_REJECTED,
        ServiceError::Overloaded => STATUS_OVERLOADED,
        ServiceError::ExecFailed { .. } => STATUS_EXEC_FAILED,
        ServiceError::Deadline => STATUS_DEADLINE,
        ServiceError::Shutdown => STATUS_SHUTDOWN,
    }
}

/// Reconstruct a typed service error from a wire status + message (the
/// client-side inverse of [`status_of`]; unknown codes land on
/// `Rejected` with the code in the reason).
pub fn error_from_status(status: u8, msg: &str) -> ServiceError {
    match status {
        STATUS_REJECTED => ServiceError::Rejected { reason: msg.to_string() },
        STATUS_OVERLOADED => ServiceError::Overloaded,
        STATUS_EXEC_FAILED => ServiceError::ExecFailed { backend: msg.to_string() },
        STATUS_DEADLINE => ServiceError::Deadline,
        STATUS_SHUTDOWN => ServiceError::Shutdown,
        other => ServiceError::Rejected { reason: format!("unknown wire status {other}: {msg}") },
    }
}

fn put_words(out: &mut Vec<u8>, words: &[u64]) {
    for &w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Encode a frame's payload (kind byte + body, no len/crc prefix).
fn encode_payload(frame: &Frame) -> Vec<u8> {
    match frame {
        Frame::Hello { version, flags } => {
            let mut out = Vec::with_capacity(9);
            out.push(KIND_HELLO);
            out.extend_from_slice(&version.to_le_bytes());
            out.extend_from_slice(&flags.to_le_bytes());
            out
        }
        Frame::Submit(s) => {
            let mut out = Vec::with_capacity(27 + 8 * (s.a.len() + s.b.len()));
            out.push(KIND_SUBMIT);
            out.extend_from_slice(&s.id.to_le_bytes());
            out.push(op_to_byte(s.op));
            out.push(format_to_byte(s.format));
            out.push(s.flags);
            out.extend_from_slice(&s.deadline_us.to_le_bytes());
            out.extend_from_slice(&(s.a.len() as u32).to_le_bytes());
            out.extend_from_slice(&(s.b.len() as u32).to_le_bytes());
            put_words(&mut out, &s.a);
            put_words(&mut out, &s.b);
            out
        }
        Frame::Ticket { id } => {
            let mut out = Vec::with_capacity(9);
            out.push(KIND_TICKET);
            out.extend_from_slice(&id.to_le_bytes());
            out
        }
        Frame::Complete(c) => {
            let mut out = Vec::with_capacity(18 + 8 * c.results.len() + c.error.len());
            out.push(KIND_COMPLETE);
            out.extend_from_slice(&c.id.to_le_bytes());
            out.push(c.status);
            out.extend_from_slice(&(c.results.len() as u32).to_le_bytes());
            put_words(&mut out, &c.results);
            out.extend_from_slice(&(c.error.len() as u32).to_le_bytes());
            out.extend_from_slice(c.error.as_bytes());
            out
        }
        Frame::StatsRequest => vec![KIND_STATS_REQUEST],
        Frame::Stats(s) => {
            let mut out = Vec::with_capacity(64 + 58 * s.slots.len() + 52 * s.shards.len());
            out.push(KIND_STATS);
            out.extend_from_slice(&s.version.to_le_bytes());
            out.extend_from_slice(&s.server_ns.to_le_bytes());
            out.extend_from_slice(&s.respawns.to_le_bytes());
            out.extend_from_slice(&s.trace_drops.to_le_bytes());
            out.extend_from_slice(&s.trace_errors.to_le_bytes());
            out.extend_from_slice(&(s.slots.len() as u32).to_le_bytes());
            for slot in &s.slots {
                out.push(op_to_byte(slot.op));
                out.push(format_to_byte(slot.format));
                put_words(
                    &mut out,
                    &[
                        slot.requests,
                        slot.errors,
                        slot.shed,
                        slot.admission_rejected,
                        slot.p50_latency_ns,
                        slot.p99_latency_ns,
                        slot.queued_lanes,
                    ],
                );
            }
            out.extend_from_slice(&(s.shards.len() as u32).to_le_bytes());
            for sh in &s.shards {
                out.extend_from_slice(&sh.ring_depth.to_le_bytes());
                out.extend_from_slice(&sh.ring_capacity.to_le_bytes());
                out.extend_from_slice(&sh.ready_batches.to_le_bytes());
                put_words(
                    &mut out,
                    &[
                        sh.queued_lanes,
                        sh.oldest_ready_us,
                        sh.steals_in,
                        sh.steals_out,
                        sh.ring_full_rejects,
                    ],
                );
            }
            out.extend_from_slice(&(s.backends.len() as u32).to_le_bytes());
            for b in &s.backends {
                out.extend_from_slice(&(b.name.len() as u32).to_le_bytes());
                out.extend_from_slice(b.name.as_bytes());
                out.push(u8::from(b.breaker_open) | (u8::from(b.degraded) << 1));
                put_words(&mut out, &[b.ok_batches, b.failed_batches, b.rerouted, b.respawns]);
            }
            put_words(
                &mut out,
                &[
                    s.net.connections,
                    s.net.active_connections,
                    s.net.frames_in,
                    s.net.frames_out,
                    s.net.submits,
                    s.net.completes,
                    s.net.slow_client_drops,
                    s.net.protocol_errors,
                ],
            );
            out
        }
    }
}

/// A zero-copy cursor over a payload; every read is bounds-checked into
/// a typed protocol error.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.at < n {
            bail!(
                "truncated frame body: wanted {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn words(&mut self, n: usize) -> Result<Vec<u64>> {
        let bytes = self.take(8 * n)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after frame body", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Decode one payload (kind byte + body) back into a [`Frame`].
fn decode_payload(payload: &[u8]) -> Result<Frame> {
    let mut c = Cursor { buf: payload, at: 0 };
    let frame = match c.u8().context("empty frame payload")? {
        KIND_HELLO => Frame::Hello { version: c.u32()?, flags: c.u32()? },
        KIND_SUBMIT => {
            let id = c.u64()?;
            let op = op_from_byte(c.u8()?)?;
            let format = format_from_byte(c.u8()?)?;
            let flags = c.u8()?;
            let deadline_us = c.u32()?;
            let n_a = c.u32()? as usize;
            let n_b = c.u32()? as usize;
            // the plane counts were inside the CRC-checked payload, but
            // still bound them against the frame we actually hold
            // before allocating
            if 8 * (n_a + n_b) > payload.len() {
                bail!("submit lane counts ({n_a}+{n_b}) exceed the frame");
            }
            let a = c.words(n_a)?;
            let b = c.words(n_b)?;
            Frame::Submit(SubmitFrame { id, op, format, flags, deadline_us, a, b })
        }
        KIND_TICKET => Frame::Ticket { id: c.u64()? },
        KIND_STATS_REQUEST => Frame::StatsRequest,
        KIND_STATS => {
            let version = c.u32()?;
            let server_ns = c.u64()?;
            let respawns = c.u64()?;
            let trace_drops = c.u64()?;
            let trace_errors = c.u64()?;
            let n_slots = c.u32()? as usize;
            // 58 bytes per slot row: bound counts against the held
            // frame before allocating, as SUBMIT does for lanes
            if 58 * n_slots > payload.len() {
                bail!("stats slot count {n_slots} exceeds the frame");
            }
            let mut slots = Vec::with_capacity(n_slots);
            for _ in 0..n_slots {
                let op = op_from_byte(c.u8()?)?;
                let format = format_from_byte(c.u8()?)?;
                let w = c.words(7)?;
                slots.push(SlotStats {
                    op,
                    format,
                    requests: w[0],
                    errors: w[1],
                    shed: w[2],
                    admission_rejected: w[3],
                    p50_latency_ns: w[4],
                    p99_latency_ns: w[5],
                    queued_lanes: w[6],
                });
            }
            let n_shards = c.u32()? as usize;
            if 52 * n_shards > payload.len() {
                bail!("stats shard count {n_shards} exceeds the frame");
            }
            let mut shards = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let ring_depth = c.u32()?;
                let ring_capacity = c.u32()?;
                let ready_batches = c.u32()?;
                let w = c.words(5)?;
                shards.push(ShardStats {
                    ring_depth,
                    ring_capacity,
                    ready_batches,
                    queued_lanes: w[0],
                    oldest_ready_us: w[1],
                    steals_in: w[2],
                    steals_out: w[3],
                    ring_full_rejects: w[4],
                });
            }
            let n_backends = c.u32()? as usize;
            if 37 * n_backends > payload.len() {
                bail!("stats backend count {n_backends} exceeds the frame");
            }
            let mut backends = Vec::with_capacity(n_backends);
            for _ in 0..n_backends {
                let name_len = c.u32()? as usize;
                let name = String::from_utf8(c.take(name_len)?.to_vec())
                    .context("stats backend name is not UTF-8")?;
                let flags = c.u8()?;
                let w = c.words(4)?;
                backends.push(BackendStats {
                    name,
                    breaker_open: flags & 1 != 0,
                    degraded: flags & 2 != 0,
                    ok_batches: w[0],
                    failed_batches: w[1],
                    rerouted: w[2],
                    respawns: w[3],
                });
            }
            let w = c.words(8)?;
            Frame::Stats(StatsFrame {
                version,
                server_ns,
                respawns,
                trace_drops,
                trace_errors,
                slots,
                shards,
                backends,
                net: NetCounters {
                    connections: w[0],
                    active_connections: w[1],
                    frames_in: w[2],
                    frames_out: w[3],
                    submits: w[4],
                    completes: w[5],
                    slow_client_drops: w[6],
                    protocol_errors: w[7],
                },
            })
        }
        KIND_COMPLETE => {
            let id = c.u64()?;
            let status = c.u8()?;
            let n = c.u32()? as usize;
            if 8 * n > payload.len() {
                bail!("complete lane count {n} exceeds the frame");
            }
            let results = c.words(n)?;
            let msg_len = c.u32()? as usize;
            let error = String::from_utf8(c.take(msg_len)?.to_vec())
                .context("complete error message is not UTF-8")?;
            Frame::Complete(CompleteFrame { id, status, results, error })
        }
        other => bail!("unknown frame kind {other}"),
    };
    c.done()?;
    Ok(frame)
}

/// Encode a frame to its full wire bytes (`len | crc | payload`).
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let payload = encode_payload(frame);
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Write one frame (a single `write_all`, as the journal appends).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    w.write_all(&encode_frame(frame)).context("writing wire frame")
}

/// Blocking-read one frame: length prefix, CRC check, decode. An EOF
/// **before any prefix byte** is a clean close (`Ok(None)`); anywhere
/// else it is a torn frame and an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut prefix = [0u8; 8];
    // distinguish clean close from mid-prefix EOF by hand
    let mut got = 0;
    while got < prefix.len() {
        let n = r.read(&mut prefix[got..]).context("reading frame prefix")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-prefix ({got}/8 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(prefix[0..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(prefix[4..8].try_into().unwrap());
    if len == 0 || len > MAX_FRAME {
        bail!("bad frame length {len} (max {MAX_FRAME})");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let actual = crc32(&payload);
    if actual != crc {
        bail!("frame CRC mismatch: stored {crc:#010x}, computed {actual:#010x}");
    }
    decode_payload(&payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(frame: Frame) {
        let bytes = encode_frame(&frame);
        let mut r = &bytes[..];
        let back = read_frame(&mut r).unwrap().expect("a frame, not EOF");
        assert_eq!(back, frame);
        // and the stream is exactly consumed
        assert!(r.is_empty());
    }

    #[test]
    fn frames_round_trip() {
        round_trip(Frame::Hello { version: WIRE_VERSION, flags: FLAG_DURABLE });
        round_trip(Frame::Ticket { id: 0xDEAD_BEEF_0042 });
        round_trip(Frame::Submit(SubmitFrame {
            id: 7,
            op: OpKind::Divide,
            format: FormatKind::F16,
            flags: SUBMIT_DURABLE,
            deadline_us: 1500,
            a: vec![0x3C00, 0x4200, 0x7BFF],
            b: vec![0x3800, 0x4000, 0x3C00],
        }));
        round_trip(Frame::Submit(SubmitFrame {
            id: u64::MAX,
            op: OpKind::Rsqrt,
            format: FormatKind::F64,
            flags: 0,
            deadline_us: 0,
            a: vec![0x4000_0000_0000_0000],
            b: vec![],
        }));
        round_trip(Frame::Complete(CompleteFrame {
            id: 7,
            status: STATUS_OK,
            results: vec![1, 2, 3],
            error: String::new(),
        }));
        round_trip(Frame::Complete(CompleteFrame {
            id: 9,
            status: STATUS_EXEC_FAILED,
            results: vec![],
            error: "backend execution failed: scalar-reference".into(),
        }));
    }

    #[test]
    fn stats_frames_round_trip() {
        round_trip(Frame::StatsRequest);
        // empty snapshot (a service with nothing recorded yet)
        round_trip(Frame::Stats(StatsFrame {
            version: STATS_VERSION,
            server_ns: 0,
            respawns: 0,
            trace_drops: 0,
            trace_errors: 0,
            slots: vec![],
            shards: vec![],
            backends: vec![],
            net: NetCounters::default(),
        }));
        // fully populated snapshot
        round_trip(Frame::Stats(StatsFrame {
            version: STATS_VERSION,
            server_ns: 123_456_789_000,
            respawns: 2,
            trace_drops: 17,
            trace_errors: 3,
            slots: vec![
                SlotStats {
                    op: OpKind::Divide,
                    format: FormatKind::F32,
                    requests: 1_000_000,
                    errors: 4,
                    shed: 9,
                    admission_rejected: 1,
                    p50_latency_ns: 42_000,
                    p99_latency_ns: 990_000,
                    queued_lanes: 128,
                },
                SlotStats {
                    op: OpKind::Rsqrt,
                    format: FormatKind::F16,
                    requests: 7,
                    errors: 0,
                    shed: 0,
                    admission_rejected: 0,
                    p50_latency_ns: 0,
                    p99_latency_ns: 0,
                    queued_lanes: 0,
                },
            ],
            shards: vec![
                ShardStats {
                    ring_depth: 12,
                    ring_capacity: 65_536,
                    queued_lanes: 96,
                    ready_batches: 2,
                    oldest_ready_us: 750,
                    steals_in: 5,
                    steals_out: 3,
                    ring_full_rejects: 1,
                },
                ShardStats::default(),
            ],
            backends: vec![BackendStats {
                name: "native-fixed-point".into(),
                breaker_open: true,
                degraded: false,
                ok_batches: 500,
                failed_batches: 2,
                rerouted: 2,
                respawns: 1,
            }],
            net: NetCounters {
                connections: 10,
                active_connections: 3,
                frames_in: 4000,
                frames_out: 4100,
                submits: 1900,
                completes: 1890,
                slow_client_drops: 1,
                protocol_errors: 0,
            },
        }));
    }

    #[test]
    fn stats_row_counts_are_bounded_by_the_frame() {
        // a CRC-valid STATS whose declared slot count exceeds the held
        // bytes must fail decode without a giant allocation
        let mut payload = vec![KIND_STATS];
        payload.extend_from_slice(&STATS_VERSION.to_le_bytes());
        payload.extend_from_slice(&[0u8; 32]); // server_ns..trace_errors
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // n_slots
        let mut frame = Vec::new();
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let err = read_frame(&mut &frame[..]).unwrap_err().to_string();
        assert!(err.contains("slot count"), "{err}");
    }

    #[test]
    fn several_frames_stream_back_to_back() {
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION, flags: 0 },
            Frame::Ticket { id: 1 },
            Frame::Ticket { id: 2 },
        ];
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&encode_frame(f));
        }
        let mut r = &bytes[..];
        for f in &frames {
            assert_eq!(read_frame(&mut r).unwrap().as_ref(), Some(f));
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "then a clean EOF");
    }

    #[test]
    fn corrupt_frames_are_typed_errors() {
        let good = encode_frame(&Frame::Ticket { id: 42 });

        // flipped payload bit -> CRC mismatch
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x40;
        let err = read_frame(&mut &bad[..]).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");

        // truncated payload (torn tail) -> read error, not a hang/panic
        let torn = &good[..good.len() - 3];
        assert!(read_frame(&mut &torn[..]).is_err());

        // mid-prefix EOF is distinguished from a clean close
        let stub = &good[..5];
        let err = read_frame(&mut &stub[..]).unwrap_err().to_string();
        assert!(err.contains("mid-prefix"), "{err}");

        // an oversized length prefix is rejected before allocating
        let mut huge = good.clone();
        huge[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut &huge[..]).unwrap_err().to_string();
        assert!(err.contains("bad frame length"), "{err}");

        // unknown kind byte survives the CRC but fails decode
        let payload = [99u8];
        let mut frame = Vec::new();
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&crate::coordinator::journal::crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let err = read_frame(&mut &frame[..]).unwrap_err().to_string();
        assert!(err.contains("unknown frame kind"), "{err}");
    }

    #[test]
    fn status_codes_round_trip_the_error_surface() {
        let errors = [
            ServiceError::Rejected { reason: "empty batch".into() },
            ServiceError::Overloaded,
            ServiceError::ExecFailed { backend: "native-fixed-point".into() },
            ServiceError::Deadline,
            ServiceError::Shutdown,
        ];
        for err in errors {
            let status = status_of(&err);
            assert_ne!(status, STATUS_OK);
            let back = error_from_status(status, &format!("{err}"));
            assert_eq!(status_of(&back), status, "status stable through a round trip");
        }
        assert!(matches!(error_from_status(200, "?"), ServiceError::Rejected { .. }));
    }
}
