//! Prometheus text exposition over plain HTTP: `serve --metrics-listen
//! ADDR` binds a [`MetricsServer`] whose only endpoint, `GET /metrics`,
//! renders the same [`StatsFrame`] snapshot the `STATS` wire frame
//! carries (`curl http://ADDR/metrics` is the scrape quickstart in the
//! README).
//!
//! The HTTP surface is deliberately minimal — hand-rolled over
//! [`TcpListener`], no dependency: one request per connection, request
//! line + headers parsed just far enough to route, `Connection: close`
//! on every reply. Requests are serviced inline on the accept thread
//! under a short socket timeout, so a stalled scraper delays the next
//! scrape by at most [`CLIENT_TIMEOUT`] instead of wedging the
//! listener. Anything that is not `GET /metrics` gets a 404; anything
//! that is not parseable HTTP gets a 400.
//!
//! The exposition format is Prometheus text v0.0.4: `# HELP`/`# TYPE`
//! headers per family, `_total` suffixes on cumulative counters, plain
//! names on gauges. Per-(op, format) families are labelled
//! `{op="divide",format="f32"}`, per-shard families `{shard="0"}`, and
//! per-backend families `{backend="native-fixed-point"}` — the same
//! three axes the in-process [`MetricsSnapshot`] and
//! [`FpuService::shard_stats`] slice by, so a scrape and an in-process
//! report always agree.
//!
//! [`MetricsSnapshot`]: crate::coordinator::MetricsSnapshot
//! [`FpuService::shard_stats`]: crate::coordinator::FpuService::shard_stats

use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::FpuService;

use super::server::{stats_frame, NetStats};
use super::wire::StatsFrame;

/// Per-connection socket timeout: bounds how long one slow scraper can
/// hold the accept thread.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(2);

/// The metrics listener. Stop it explicitly with [`MetricsServer::stop`]
/// or implicitly on drop; either joins the accept thread.
pub struct MetricsServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `GET /metrics`. `net: Some` folds the wire front end's
    /// counters into the exposition; `None` (an in-process service with
    /// no TCP front end) zeroes the `fpu_net_*` family.
    pub fn start(
        svc: Arc<FpuService>,
        net: Option<Arc<NetStats>>,
        addr: &str,
    ) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics listener {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound metrics address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("fpu-metrics-http".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let _ = serve_one(stream, &svc, net.as_deref());
                    }
                })
                .context("spawning fpu-metrics-http")?
        };
        Ok(MetricsServer { local_addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop listening and join the accept thread. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Service one HTTP connection: parse the request line, drain the
/// headers, route, reply, close.
fn serve_one(stream: TcpStream, svc: &FpuService, net: Option<&NetStats>) -> Result<()> {
    stream.set_read_timeout(Some(CLIENT_TIMEOUT)).context("set_read_timeout")?;
    stream.set_write_timeout(Some(CLIENT_TIMEOUT)).context("set_write_timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning metrics socket")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    // drain headers to the blank line so the client's socket is clean
    // for our reply (pipelining is not supported: we close after one)
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).unwrap_or(0) == 0 || header.trim_end().is_empty() {
            break;
        }
    }
    let mut sock = stream;
    let (status, content_type, body) = match (method, path) {
        ("GET", "/metrics") => {
            let body = render_prometheus(&stats_frame(svc, net));
            ("200 OK", "text/plain; version=0.0.4; charset=utf-8", body)
        }
        ("GET", _) => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".into()),
        _ => ("400 Bad Request", "text/plain; charset=utf-8", "bad request\n".into()),
    };
    let reply = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    sock.write_all(reply.as_bytes()).context("writing metrics reply")?;
    sock.flush().context("flushing metrics reply")
}

/// One `# HELP` + `# TYPE` family header.
fn family(out: &mut String, name: &str, help: &str, kind: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render a [`StatsFrame`] as Prometheus text exposition v0.0.4. Pure
/// (no clocks, no I/O) so tests assert on exact lines.
pub fn render_prometheus(frame: &StatsFrame) -> String {
    let mut out = String::with_capacity(4096);

    family(&mut out, "fpu_uptime_seconds", "Seconds since the service started.", "gauge");
    let _ = writeln!(out, "fpu_uptime_seconds {}", frame.server_ns as f64 / 1e9);

    // per-(op, format) slots
    family(&mut out, "fpu_requests_total", "Lanes completed per (op, format).", "counter");
    for s in &frame.slots {
        let _ = writeln!(
            out,
            "fpu_requests_total{{op=\"{}\",format=\"{}\"}} {}",
            s.op.label(),
            s.format.label(),
            s.requests,
        );
    }
    let slot_counters: [(&str, &str, fn(&super::wire::SlotStats) -> u64); 3] = [
        ("fpu_errors_total", "Lanes failed per (op, format).", |s| s.errors),
        ("fpu_shed_total", "Lanes shed past their deadline per (op, format).", |s| s.shed),
        (
            "fpu_admission_rejected_total",
            "Lanes rejected by deadline admission control per (op, format).",
            |s| s.admission_rejected,
        ),
    ];
    for (name, help, get) in slot_counters {
        family(&mut out, name, help, "counter");
        for s in &frame.slots {
            let _ = writeln!(
                out,
                "{name}{{op=\"{}\",format=\"{}\"}} {}",
                s.op.label(),
                s.format.label(),
                get(s),
            );
        }
    }
    let slot_gauges: [(&str, &str, fn(&super::wire::SlotStats) -> u64); 3] = [
        ("fpu_queued_lanes", "Lanes currently queued per (op, format).", |s| s.queued_lanes),
        ("fpu_p50_latency_ns", "p50 completion latency per (op, format).", |s| s.p50_latency_ns),
        ("fpu_p99_latency_ns", "p99 completion latency per (op, format).", |s| s.p99_latency_ns),
    ];
    for (name, help, get) in slot_gauges {
        family(&mut out, name, help, "gauge");
        for s in &frame.slots {
            let _ = writeln!(
                out,
                "{name}{{op=\"{}\",format=\"{}\"}} {}",
                s.op.label(),
                s.format.label(),
                get(s),
            );
        }
    }

    // per-shard rows
    let shard_gauges: [(&str, &str, fn(&super::wire::ShardStats) -> u64); 5] = [
        ("fpu_shard_ring_depth", "Submit-ring occupancy per shard.", |s| s.ring_depth as u64),
        ("fpu_shard_ring_capacity", "Submit-ring slot count per shard.", |s| {
            s.ring_capacity as u64
        }),
        ("fpu_shard_queued_lanes", "Lanes queued per shard.", |s| s.queued_lanes),
        ("fpu_shard_ready_batches", "Formed batches awaiting dispatch per shard.", |s| {
            s.ready_batches as u64
        }),
        ("fpu_shard_oldest_ready_us", "Age of the oldest ready batch per shard.", |s| {
            s.oldest_ready_us
        }),
    ];
    for (name, help, get) in shard_gauges {
        family(&mut out, name, help, "gauge");
        for (i, s) in frame.shards.iter().enumerate() {
            let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", get(s));
        }
    }
    let shard_counters: [(&str, &str, fn(&super::wire::ShardStats) -> u64); 3] = [
        ("fpu_shard_steals_in_total", "Batches stolen from peers per shard.", |s| s.steals_in),
        ("fpu_shard_steals_out_total", "Batches peers stole per shard.", |s| s.steals_out),
        ("fpu_shard_ring_full_rejects_total", "Submissions bounced on a full ring per shard.", |s| {
            s.ring_full_rejects
        }),
    ];
    for (name, help, get) in shard_counters {
        family(&mut out, name, help, "counter");
        for (i, s) in frame.shards.iter().enumerate() {
            let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", get(s));
        }
    }

    // per-backend health
    let backend_gauges: [(&str, &str, fn(&super::wire::BackendStats) -> u64); 2] = [
        ("fpu_backend_breaker_open", "1 when the backend's circuit breaker is open.", |b| {
            b.breaker_open as u64
        }),
        ("fpu_backend_degraded", "1 when the backend's pool is marked degraded.", |b| {
            b.degraded as u64
        }),
    ];
    for (name, help, get) in backend_gauges {
        family(&mut out, name, help, "gauge");
        for b in &frame.backends {
            let _ = writeln!(out, "{name}{{backend=\"{}\"}} {}", b.name, get(b));
        }
    }
    let backend_counters: [(&str, &str, fn(&super::wire::BackendStats) -> u64); 4] = [
        ("fpu_backend_ok_batches_total", "Batches executed successfully per backend.", |b| {
            b.ok_batches
        }),
        ("fpu_backend_failed_batches_total", "Batches failed per backend.", |b| b.failed_batches),
        ("fpu_backend_rerouted_total", "Batches rerouted away per backend.", |b| b.rerouted),
        ("fpu_backend_respawns_total", "Workers respawned per backend.", |b| b.respawns),
    ];
    for (name, help, get) in backend_counters {
        family(&mut out, name, help, "counter");
        for b in &frame.backends {
            let _ = writeln!(out, "{name}{{backend=\"{}\"}} {}", b.name, get(b));
        }
    }

    // service-wide counters
    family(&mut out, "fpu_respawns_total", "Workers respawned, all backends.", "counter");
    let _ = writeln!(out, "fpu_respawns_total {}", frame.respawns);
    family(
        &mut out,
        "fpu_trace_drops_total",
        "Sampled lifecycle trace events lost to ring overflow.",
        "counter",
    );
    let _ = writeln!(out, "fpu_trace_drops_total {}", frame.trace_drops);
    family(
        &mut out,
        "fpu_trace_errors_total",
        "Error-class trace events captured (never dropped).",
        "counter",
    );
    let _ = writeln!(out, "fpu_trace_errors_total {}", frame.trace_errors);

    // net plane
    let net = &frame.net;
    family(&mut out, "fpu_net_active_connections", "Wire connections currently open.", "gauge");
    let _ = writeln!(out, "fpu_net_active_connections {}", net.active_connections);
    let net_counters: [(&str, &str, u64); 7] = [
        ("fpu_net_connections_total", "Wire connections accepted.", net.connections),
        ("fpu_net_frames_in_total", "Frames decoded off client sockets.", net.frames_in),
        ("fpu_net_frames_out_total", "Frames pushed to client sockets.", net.frames_out),
        ("fpu_net_submits_total", "SUBMIT frames serviced.", net.submits),
        ("fpu_net_completes_total", "COMPLETE frames queued.", net.completes),
        (
            "fpu_net_slow_client_drops_total",
            "Connections dropped for a full writer queue.",
            net.slow_client_drops,
        ),
        ("fpu_net_protocol_errors_total", "Malformed or unexpected frames.", net.protocol_errors),
    ];
    for (name, help, v) in net_counters {
        family(&mut out, name, help, "counter");
        let _ = writeln!(out, "{name} {v}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, FormatKind, OpKind, ServiceConfig};
    use crate::net::wire::{BackendStats, NetCounters, ShardStats, SlotStats};
    use crate::runtime::executor::NativeExecutor;
    use std::io::Read;

    fn sample_frame() -> StatsFrame {
        StatsFrame {
            version: 1,
            server_ns: 2_500_000_000,
            respawns: 3,
            trace_drops: 7,
            trace_errors: 2,
            slots: vec![SlotStats {
                op: OpKind::Divide,
                format: FormatKind::F32,
                requests: 100,
                errors: 1,
                shed: 2,
                admission_rejected: 3,
                p50_latency_ns: 4000,
                p99_latency_ns: 9000,
                queued_lanes: 5,
            }],
            shards: vec![
                ShardStats {
                    ring_depth: 4,
                    ring_capacity: 1024,
                    queued_lanes: 5,
                    ready_batches: 1,
                    oldest_ready_us: 250,
                    steals_in: 6,
                    steals_out: 7,
                    ring_full_rejects: 8,
                },
                ShardStats { ring_capacity: 1024, ..Default::default() },
            ],
            backends: vec![BackendStats {
                name: "native-fixed-point".into(),
                breaker_open: true,
                degraded: false,
                ok_batches: 40,
                failed_batches: 2,
                rerouted: 1,
                respawns: 3,
            }],
            net: NetCounters {
                connections: 10,
                active_connections: 2,
                frames_in: 100,
                frames_out: 90,
                submits: 50,
                completes: 49,
                slow_client_drops: 1,
                protocol_errors: 0,
            },
        }
    }

    #[test]
    fn exposition_covers_every_axis() {
        let text = render_prometheus(&sample_frame());
        for expected in [
            "# TYPE fpu_requests_total counter",
            "fpu_requests_total{op=\"divide\",format=\"f32\"} 100",
            "fpu_p99_latency_ns{op=\"divide\",format=\"f32\"} 9000",
            "fpu_queued_lanes{op=\"divide\",format=\"f32\"} 5",
            "fpu_shard_ring_depth{shard=\"0\"} 4",
            "fpu_shard_ring_capacity{shard=\"1\"} 1024",
            "fpu_shard_steals_in_total{shard=\"0\"} 6",
            "fpu_shard_steals_out_total{shard=\"0\"} 7",
            "fpu_shard_ring_full_rejects_total{shard=\"0\"} 8",
            "fpu_backend_breaker_open{backend=\"native-fixed-point\"} 1",
            "fpu_backend_ok_batches_total{backend=\"native-fixed-point\"} 40",
            "fpu_respawns_total 3",
            "fpu_trace_drops_total 7",
            "fpu_trace_errors_total 2",
            "fpu_net_active_connections 2",
            "fpu_net_slow_client_drops_total 1",
            "fpu_uptime_seconds 2.5",
        ] {
            assert!(text.contains(expected), "missing {expected:?} in:\n{text}");
        }
        // every family header precedes its samples exactly once
        assert_eq!(text.matches("# TYPE fpu_requests_total").count(), 1);
    }

    fn quick_service() -> Arc<FpuService> {
        let cfg = ServiceConfig {
            batcher: BatcherConfig::new(64, Duration::from_micros(100)),
            queue_depth: 1024,
            workers: 1,
            ..ServiceConfig::default()
        };
        Arc::new(
            FpuService::start(cfg, || Ok(Box::new(NativeExecutor::with_defaults()) as _)).unwrap(),
        )
    }

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut reply = String::new();
        sock.read_to_string(&mut reply).unwrap();
        reply
    }

    #[test]
    fn scrape_round_trips_over_http() {
        let svc = quick_service();
        let h = svc.handle();
        for i in 1..=20u32 {
            assert_eq!(h.divide((3 * i) as f32, 3.0).unwrap(), i as f32);
        }
        let mut server = MetricsServer::start(svc.clone(), None, "127.0.0.1:0").unwrap();
        let reply = http_get(server.local_addr(), "/metrics");
        assert!(reply.starts_with("HTTP/1.1 200 OK"), "{reply}");
        assert!(reply.contains("text/plain; version=0.0.4"), "{reply}");
        assert!(reply.contains("fpu_requests_total{op=\"divide\",format=\"f32\"} 20"), "{reply}");
        assert!(reply.contains("fpu_shard_ring_capacity{shard=\"0\"} 1024"), "{reply}");
        assert!(
            reply.contains("fpu_backend_breaker_open{backend=\"native-fixed-point\"} 0"),
            "{reply}"
        );
        // the scrape agrees with the in-process snapshot
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.op_format(OpKind::Divide, FormatKind::F32).requests, 20);
        // anything else is a 404; the listener survives both
        let miss = http_get(server.local_addr(), "/other");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");
        let again = http_get(server.local_addr(), "/metrics");
        assert!(again.starts_with("HTTP/1.1 200 OK"), "{again}");
        server.stop();
        server.stop(); // idempotent
        drop(server); // joined accept thread released its service Arc
        drop(svc); // FpuService::drop shuts the shards down
    }
}
