//! The net plane: a TCP front end for the FPU service.
//!
//! The in-process service ([`crate::coordinator::FpuService`]) serves
//! callers in the same address space; this module puts a socket in
//! front of it so the "divider unit as a shared resource" can be shared
//! across processes and machines — and so the serving claims can be
//! measured against real request traffic (the `net_loopback` bench
//! section and the `goldschmidt loadgen` harness drive exactly this
//! path).
//!
//! Four pieces:
//!
//! * [`wire`] — the compact length-prefixed binary protocol:
//!   `HELLO{version, flags}` handshake, `SUBMIT` frames carrying one
//!   vectored batch each (mapping 1:1 onto
//!   `submit_batch`/`submit_batch_durable`), `TICKET{id}` acks,
//!   out-of-order `COMPLETE{id, status, results}` frames, and the
//!   `STATS_REQUEST`/`STATS` pair that round-trips a versioned
//!   [`StatsFrame`] metrics snapshot. Framing — `len | crc32(payload)
//!   | payload` — reuses the request journal's discipline and its
//!   CRC-32.
//! * [`server`] — [`NetServer`]: per-connection blocking reader
//!   threads feed the service directly (no reactor), completions are
//!   pushed by a per-connection writer thread fed from a **bounded**
//!   handoff queue; a client whose queue fills is counted
//!   (`net_slow_client_drops`) and disconnected. The `conn-drop`,
//!   `partial-write` and `read-stall` fault sites inject here.
//! * [`client`] — [`NetClient`] (synchronous submit/wait with
//!   out-of-order buffering, plus [`NetClient::stats`] polling) and
//!   the split [`NetSender`] / [`NetReceiver`] halves the open-loop
//!   load generator drives from separate threads.
//! * [`metrics_http`] — [`MetricsServer`]: Prometheus text exposition
//!   of the same [`StatsFrame`] snapshot over plain HTTP
//!   (`serve --metrics-listen ADDR`, then `curl http://ADDR/metrics`).
//!
//! See the README's "Wire protocol" section for the frame layout
//! tables and handshake rules, and "Observability" for the stats and
//! scrape surfaces.

pub mod client;
pub mod metrics_http;
pub mod server;
pub mod wire;

pub use client::{result_of, Event, NetClient, NetReceiver, NetSender, SubmitOpts};
pub use metrics_http::{render_prometheus, MetricsServer};
pub use server::{stats_frame, NetConfig, NetServer, NetStats, NetStatsSnapshot};
pub use wire::{
    error_from_status, status_of, BackendStats, CompleteFrame, Frame, NetCounters, ShardStats,
    SlotStats, StatsFrame, SubmitFrame, FLAG_DURABLE, MAX_FRAME, STATS_VERSION, STATUS_DEADLINE,
    STATUS_EXEC_FAILED, STATUS_OK, STATUS_OVERLOADED, STATUS_REJECTED, STATUS_SHUTDOWN,
    SUBMIT_DURABLE, WIRE_VERSION,
};
