//! The wire client: handshake, submits, and completion delivery.
//!
//! [`NetClient`] is the simple synchronous shape — submit, then
//! [`NetClient::wait`] (completions for *other* outstanding ids arrive
//! out of order and are buffered, so interleaved submits work). The
//! open-loop load generator wants independent send and receive threads
//! instead; [`NetClient::split`] hands out the two socket halves as
//! [`NetSender`] / [`NetReceiver`].

use std::collections::HashMap;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{FormatKind, OpKind, ServiceError};

use super::wire::{
    error_from_status, read_frame, write_frame, CompleteFrame, Frame, StatsFrame, SubmitFrame,
    STATUS_OK, SUBMIT_DURABLE, WIRE_VERSION,
};

/// Submit-time options beyond the operand planes.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// Completion deadline in microseconds (0 = none).
    pub deadline_us: u32,
    /// Journal the batch server-side (`submit_batch_durable`); requires
    /// the durable flag to have been granted in the handshake.
    pub durable: bool,
}

/// One frame received from the server.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// The submit with this id was accepted and queued.
    Ticket { id: u64 },
    /// Terminal outcome for one id (out of order).
    Complete(CompleteFrame),
    /// A metrics snapshot answering a [`NetSender::request_stats`].
    Stats(StatsFrame),
}

/// Turn a completion frame into the typed result surface.
pub fn result_of(frame: &CompleteFrame) -> Result<Vec<u64>, ServiceError> {
    if frame.status == STATUS_OK {
        Ok(frame.results.clone())
    } else {
        Err(error_from_status(frame.status, &frame.error))
    }
}

/// The sending half: assigns request ids and writes SUBMIT frames.
pub struct NetSender {
    sock: TcpStream,
    next_id: u64,
    granted_flags: u32,
}

impl NetSender {
    /// Flags the server granted in the handshake (see
    /// [`super::wire::FLAG_DURABLE`]).
    pub fn granted_flags(&self) -> u32 {
        self.granted_flags
    }

    /// Submit one vectored batch; returns the client-assigned id its
    /// TICKET/COMPLETE frames will carry.
    pub fn submit(
        &mut self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        opts: SubmitOpts,
    ) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Submit(SubmitFrame {
            id,
            op,
            format,
            flags: if opts.durable { SUBMIT_DURABLE } else { 0 },
            deadline_us: opts.deadline_us,
            a: a.to_vec(),
            b: b.to_vec(),
        });
        write_frame(&mut self.sock, &frame)?;
        Ok(id)
    }

    /// Ask the server for a metrics snapshot; the reply arrives on the
    /// receiving half as [`Event::Stats`], ordered with this sender's
    /// other replies (the stats poller thread of `loadgen
    /// --stats-poll` drives exactly this).
    pub fn request_stats(&mut self) -> Result<()> {
        write_frame(&mut self.sock, &Frame::StatsRequest)
    }

    /// Half-close: FIN the write direction. The server treats this as a
    /// clean close, flushes every outstanding TICKET/COMPLETE through
    /// its writer, then closes — so a paired [`NetReceiver`] sees all
    /// remaining completions followed by EOF instead of blocking on a
    /// quiet socket.
    pub fn finish(&self) {
        let _ = self.sock.shutdown(Shutdown::Write);
    }
}

/// The receiving half: blocking frame reads.
pub struct NetReceiver {
    sock: TcpStream,
}

impl NetReceiver {
    /// Blocking-read the next server frame (`None` = clean close).
    pub fn recv(&mut self) -> Result<Option<Event>> {
        match read_frame(&mut self.sock)? {
            None => Ok(None),
            Some(Frame::Ticket { id }) => Ok(Some(Event::Ticket { id })),
            Some(Frame::Complete(c)) => Ok(Some(Event::Complete(c))),
            Some(Frame::Stats(s)) => Ok(Some(Event::Stats(s))),
            Some(other) => bail!("unexpected server frame {other:?}"),
        }
    }

    /// Bound every subsequent [`Self::recv`] (`None` = block forever).
    /// A timeout surfaces as an error from `recv`.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.sock.set_read_timeout(timeout).context("set_read_timeout")
    }
}

/// A connected, handshaken wire client.
pub struct NetClient {
    sender: NetSender,
    receiver: NetReceiver,
    /// Completions that arrived while waiting on a different id.
    buffered: HashMap<u64, CompleteFrame>,
}

impl NetClient {
    /// Connect and handshake with no flags requested.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<NetClient> {
        Self::connect_with_flags(addr, 0)
    }

    /// Connect, send `HELLO{version, flags}`, and check the server's
    /// reply speaks our version. The granted flag subset is readable
    /// via [`NetSender::granted_flags`].
    pub fn connect_with_flags<A: ToSocketAddrs>(addr: A, flags: u32) -> Result<NetClient> {
        let mut sock = TcpStream::connect(addr).context("connecting")?;
        // request/response round trips dominate an interactive client;
        // never trade them for Nagle coalescing
        let _ = sock.set_nodelay(true);
        write_frame(&mut sock, &Frame::Hello { version: WIRE_VERSION, flags })?;
        let reply = read_frame(&mut sock)?.context("server closed during handshake")?;
        let granted = match reply {
            Frame::Hello { version: WIRE_VERSION, flags: granted } => granted,
            Frame::Hello { version, .. } => {
                bail!("server speaks wire version {version}, this client speaks {WIRE_VERSION}")
            }
            other => bail!("expected HELLO, got {other:?}"),
        };
        let reader = sock.try_clone().context("cloning socket")?;
        Ok(NetClient {
            sender: NetSender { sock, next_id: 0, granted_flags: granted },
            receiver: NetReceiver { sock: reader },
            buffered: HashMap::new(),
        })
    }

    /// Flags the server granted in the handshake.
    pub fn granted_flags(&self) -> u32 {
        self.sender.granted_flags
    }

    /// Submit one vectored batch (see [`NetSender::submit`]).
    pub fn submit(
        &mut self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
        opts: SubmitOpts,
    ) -> Result<u64> {
        self.sender.submit(op, format, a, b, opts)
    }

    /// Block until the completion for `id` arrives. TICKET acks are
    /// consumed silently; completions for other ids are buffered for
    /// their own `wait` calls, so out-of-order delivery is transparent.
    pub fn wait(&mut self, id: u64) -> Result<CompleteFrame> {
        if let Some(c) = self.buffered.remove(&id) {
            return Ok(c);
        }
        loop {
            match self.receiver.recv()? {
                None => bail!("connection closed with id {id} outstanding"),
                Some(Event::Ticket { .. }) => {}
                Some(Event::Complete(c)) => {
                    if c.id == id {
                        return Ok(c);
                    }
                    self.buffered.insert(c.id, c);
                }
                // a stats reply nobody is waiting on (stale poll): drop
                Some(Event::Stats(_)) => {}
            }
        }
    }

    /// Round-trip a `STATS` request: returns the server's versioned
    /// metrics snapshot. TICKET acks are consumed silently and
    /// completions for outstanding ids are buffered exactly as in
    /// [`Self::wait`], so polling stats mid-conversation is safe.
    pub fn stats(&mut self) -> Result<StatsFrame> {
        self.sender.request_stats()?;
        loop {
            match self.receiver.recv()? {
                None => bail!("connection closed with a stats request outstanding"),
                Some(Event::Ticket { .. }) => {}
                Some(Event::Complete(c)) => {
                    self.buffered.insert(c.id, c);
                }
                Some(Event::Stats(s)) => return Ok(s),
            }
        }
    }

    /// Submit + wait + typed result: the blocking convenience that
    /// mirrors `submit_batch(...).wait()` over the wire.
    pub fn call(
        &mut self,
        op: OpKind,
        format: FormatKind,
        a: &[u64],
        b: &[u64],
    ) -> Result<Result<Vec<u64>, ServiceError>> {
        let id = self.submit(op, format, a, b, SubmitOpts::default())?;
        Ok(result_of(&self.wait(id)?))
    }

    /// Split into independent send/receive halves (separate threads for
    /// open-loop driving). Buffered completions are discarded — split
    /// before waiting, not mid-conversation.
    pub fn split(self) -> (NetSender, NetReceiver) {
        (self.sender, self.receiver)
    }
}
