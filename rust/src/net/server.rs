//! The TCP front end: blocking per-connection reader threads feeding
//! the service's vectored submit path directly, completions pushed by a
//! per-connection writer thread fed from a **bounded** handoff queue.
//!
//! Threading model, per Eden's strategy (SNIPPETS.md): dedicated
//! blocking reads skip the epoll+read double syscall, and the
//! reader-to-writer handoff queue breaks the pool-to-pool deadlock
//! cycle — a worker pool never writes a socket, and a slow client can
//! only ever fill its own connection's queue. Eden leaves that queue
//! unbounded to make the deadlock argument trivial; we bound it and
//! make the overflow policy explicit instead: a client whose queue is
//! full when a completion arrives is **counted**
//! ([`NetStats::slow_client_drops`], the `net_slow_client_drops`
//! metric) **and disconnected**, so slow-loris readers cost one queue
//! of memory, not the heap.
//!
//! Per accepted connection:
//!
//! * one **reader** thread (`net-conn-N`) — handshake, then blocking
//!   `read_frame` loop; each `SUBMIT` maps 1:1 onto
//!   `submit_batch_tagged` (the client's request id rides into the
//!   trace plane) or `submit_batch_durable`, acked with a `TICKET`
//!   frame and handed to a completer; a `STATS` request is answered
//!   inline with the [`stats_frame`] snapshot through the same writer
//!   queue;
//! * `completers` **completer** threads (`net-completer-N-K`) — block
//!   on the ticket (or the durable plane's condvar via
//!   [`FpuService::wait_for_id`]) and push the `COMPLETE` frame; with
//!   more than one completer per connection, a fast batch overtakes a
//!   slow one and completions genuinely leave out of order;
//! * one **writer** thread (`net-writer-N`) — the only thread that
//!   writes the socket, draining the bounded handoff queue.
//!
//! Teardown cascades without joins: shutting the socket down unblocks
//! the reader, the reader's exit drops its queue senders, the
//! completers drain and drop theirs, and the writer exits when the
//! queue disconnects.
//!
//! Each reader clones its own
//! [`ServiceHandle`](crate::coordinator::ServiceHandle) off the
//! service, and a
//! handle clone draws a fresh shard key — so every connection gets its
//! own coordinator-shard affinity for free: concurrent connections
//! spread across the sharded submit rings instead of serializing on
//! one queue, while one connection's (op, format) stream stays on one
//! shard (FIFO preserved end to end).
//!
//! The chaos sites `conn-drop`, `partial-write` and `read-stall`
//! ([`crate::fault::FaultSite`]) are consulted here with backend filter
//! `"net"`; see the module docs of [`crate::fault`].

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{FpuService, JobPoll, NetPlaneStats, ServiceError};
use crate::fault::{FaultPlan, FaultSite};

use super::wire::{
    encode_frame, read_frame, status_of, write_frame, BackendStats, CompleteFrame, Frame,
    NetCounters, ShardStats, SlotStats, StatsFrame, SubmitFrame, FLAG_DURABLE, STATS_VERSION,
    STATUS_OK, SUBMIT_DURABLE, WIRE_VERSION,
};

/// Front-end configuration.
#[derive(Clone)]
pub struct NetConfig {
    /// Bounded per-connection writer handoff depth: completions queued
    /// for a client that is not reading. Past it the client is counted
    /// and disconnected.
    pub writer_queue: usize,
    /// Completion-waiter threads per connection. More than one lets a
    /// fast batch's `COMPLETE` overtake a slow one (out-of-order
    /// completion); one serializes completions in submit order.
    pub completers: usize,
    /// Armed net-site fault plan (`conn-drop`, `partial-write`,
    /// `read-stall`), consulted with backend filter `"net"`.
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self { writer_queue: 1024, completers: 2, fault: None }
    }
}

/// Monotonic front-end counters (all relaxed; read via [`NetStats`]
/// accessors or [`NetStats::snapshot`]).
#[derive(Default)]
pub struct NetStats {
    connections: AtomicU64,
    /// Connections currently open (a gauge: reader entry increments,
    /// reader exit decrements — signed so a racy read never wraps).
    active_connections: AtomicI64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    submits: AtomicU64,
    completes: AtomicU64,
    slow_client_drops: AtomicU64,
    injected_conn_drops: AtomicU64,
    protocol_errors: AtomicU64,
}

/// A point-in-time copy of [`NetStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStatsSnapshot {
    /// Connections accepted (handshake attempted).
    pub connections: u64,
    /// Connections currently open.
    pub active_connections: u64,
    /// Frames decoded off client sockets.
    pub frames_in: u64,
    /// Frames pushed to client sockets.
    pub frames_out: u64,
    /// `SUBMIT` frames that reached a submit call.
    pub submits: u64,
    /// `COMPLETE` frames queued for delivery.
    pub completes: u64,
    /// `net_slow_client_drops`: connections dropped because their
    /// bounded writer queue was full when a frame arrived for them.
    pub slow_client_drops: u64,
    /// Connections dropped by the `conn-drop` fault site.
    pub injected_conn_drops: u64,
    /// Malformed/unexpected frames (each also ends its connection).
    pub protocol_errors: u64,
}

impl NetStats {
    /// The `net_slow_client_drops` metric: connections dropped for a
    /// full writer queue.
    pub fn slow_client_drops(&self) -> u64 {
        self.slow_client_drops.load(Ordering::Relaxed)
    }

    /// Connections accepted so far.
    pub fn connections(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    /// Connections currently open (clamped at zero: the gauge is
    /// incremented and decremented by racing reader threads).
    pub fn active_connections(&self) -> u64 {
        self.active_connections.load(Ordering::Relaxed).max(0) as u64
    }

    /// `SUBMIT` frames serviced so far.
    pub fn submits(&self) -> u64 {
        self.submits.load(Ordering::Relaxed)
    }

    /// Copy every counter.
    pub fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            active_connections: self.active_connections(),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            submits: self.submits.load(Ordering::Relaxed),
            completes: self.completes.load(Ordering::Relaxed),
            slow_client_drops: self.slow_client_drops.load(Ordering::Relaxed),
            injected_conn_drops: self.injected_conn_drops.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
        }
    }
}

/// What a completer waits on for one acked submit.
enum Outstanding {
    /// Non-durable: the batch ticket itself.
    Ticket { id: u64, ticket: crate::coordinator::BatchTicket },
    /// Durable: the job id to `wait_for_id` on.
    Durable { id: u64, job: u64 },
}

/// Per-connection shared state: the writer handoff queue, the socket
/// (for disconnects from any of the connection's threads), and the
/// server-wide stats.
struct ConnShared {
    tx: SyncSender<Frame>,
    sock: TcpStream,
    stats: Arc<NetStats>,
    /// Set once the connection is condemned (slow client, injected
    /// drop, protocol error) so later pushes don't double-count.
    dead: AtomicBool,
}

impl ConnShared {
    /// Queue a frame for the writer. `false` ends the caller's interest
    /// in this connection: the client was disconnected (slow-client
    /// policy) or is already gone.
    fn push(&self, frame: Frame) -> bool {
        if self.dead.load(Ordering::Relaxed) {
            return false;
        }
        match self.tx.try_send(frame) {
            Ok(()) => {
                self.stats.frames_out.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(TrySendError::Full(_)) => {
                // the bounded-queue policy: count, then disconnect
                if !self.dead.swap(true, Ordering::Relaxed) {
                    self.stats.slow_client_drops.fetch_add(1, Ordering::Relaxed);
                    let _ = self.sock.shutdown(Shutdown::Both);
                }
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }

    /// Condemn the connection without the slow-client accounting.
    fn drop_conn(&self) {
        if !self.dead.swap(true, Ordering::Relaxed) {
            let _ = self.sock.shutdown(Shutdown::Both);
        }
    }
}

/// The running TCP front end. Stop it explicitly with
/// [`NetServer::stop`] or implicitly on drop; either joins the accept
/// and reader threads after shutting every live socket down.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    stats: Arc<NetStats>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting. The service must be shared (`Arc`) because
    /// durable submits and `wait_for_id` live on [`FpuService`], not
    /// the cloneable handle; the server holds clones for as long as
    /// connections live.
    pub fn start(svc: Arc<FpuService>, addr: &str, config: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(NetStats::default());
        let conns = Arc::new(Mutex::new(HashMap::new()));
        let readers = Arc::new(Mutex::new(Vec::new()));

        // feed the service's stats emitter the net-plane fields
        // (active connections, slow-client drops); the source outlives
        // this server harmlessly — counters freeze once it stops
        {
            let ns = stats.clone();
            svc.attach_net_stats_source(move || NetPlaneStats {
                active_connections: ns.active_connections(),
                slow_client_drops: ns.slow_client_drops(),
            });
        }

        let accept = {
            let stop = stop.clone();
            let stats = stats.clone();
            let conns = conns.clone();
            let readers = readers.clone();
            let conn_seq = AtomicU64::new(0);
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        let conn_id = conn_seq.fetch_add(1, Ordering::Relaxed);
                        stats.connections.fetch_add(1, Ordering::Relaxed);
                        if let Ok(clone) = stream.try_clone() {
                            conns.lock().unwrap().insert(conn_id, clone);
                        }
                        let reader = spawn_connection(
                            conn_id,
                            stream,
                            svc.clone(),
                            config.clone(),
                            stats.clone(),
                            stop.clone(),
                            conns.clone(),
                        );
                        match reader {
                            Ok(h) => readers.lock().unwrap().push(h),
                            Err(_) => {
                                conns.lock().unwrap().remove(&conn_id);
                            }
                        }
                    }
                })
                .context("spawning net-accept")?
        };

        Ok(NetServer { local_addr, stop, accept: Some(accept), conns, readers, stats })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Live front-end counters.
    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }

    /// Stop accepting, disconnect every client, and join the accept +
    /// reader threads. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for (_, sock) in self.conns.lock().unwrap().drain() {
            let _ = sock.shutdown(Shutdown::Both);
        }
        let handles: Vec<_> = std::mem::take(&mut *self.readers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Assemble the versioned [`StatsFrame`] the `STATS` wire reply and the
/// Prometheus exposition both render: per-(op, format) counters and
/// latency percentiles from the merged [`MetricsSnapshot`]
/// (slots that never saw traffic are omitted), per-shard introspection
/// rows, per-backend health, trace-plane loss accounting, and the raw
/// net counters (`net: None` zeroes them — the in-process callers).
///
/// Every counter is **cumulative**; `server_ns` is the service's
/// monotonic uptime, so a polling client computes rates by differencing
/// two frames without trusting wall clocks on either end.
pub fn stats_frame(svc: &FpuService, net: Option<&NetStats>) -> StatsFrame {
    let metrics = svc.metrics();
    let snap = metrics.snapshot();
    let slots = snap
        .op_formats
        .iter()
        .filter(|s| s.requests > 0 || s.errors > 0 || s.shed > 0 || s.admission_rejected > 0)
        .map(|s| SlotStats {
            op: s.op,
            format: s.format,
            requests: s.requests,
            errors: s.errors,
            shed: s.shed,
            admission_rejected: s.admission_rejected,
            p50_latency_ns: s.p50_latency_ns,
            p99_latency_ns: s.p99_latency_ns,
            queued_lanes: metrics.queued_lanes(s.op, s.format),
        })
        .collect();
    let shards = svc
        .shard_stats()
        .into_iter()
        .map(|s| ShardStats {
            ring_depth: s.ring_depth.min(u32::MAX as usize) as u32,
            ring_capacity: s.ring_capacity.min(u32::MAX as usize) as u32,
            queued_lanes: s.queued_lanes,
            ready_batches: s.ready_batches.min(u32::MAX as usize) as u32,
            oldest_ready_us: s.oldest_ready_us,
            steals_in: s.steals_in,
            steals_out: s.steals_out,
            ring_full_rejects: s.ring_full_rejects,
        })
        .collect();
    let report = svc.dispatch_report();
    let respawns = report.iter().map(|(_, b)| b.respawns).sum();
    let backends = report
        .into_iter()
        .map(|(name, b)| BackendStats {
            name: name.to_string(),
            breaker_open: b.breaker_open,
            degraded: b.degraded,
            ok_batches: b.ok_batches,
            failed_batches: b.failed_batches,
            rerouted: b.rerouted,
            respawns: b.respawns,
        })
        .collect();
    let (trace_drops, trace_errors) = svc
        .trace()
        .map(|t| (t.drops(), t.error_count() as u64))
        .unwrap_or((0, 0));
    let net = net.map(|n| n.snapshot()).unwrap_or_default();
    StatsFrame {
        version: STATS_VERSION,
        server_ns: svc.uptime_ns(),
        respawns,
        trace_drops,
        trace_errors,
        slots,
        shards,
        backends,
        net: NetCounters {
            connections: net.connections,
            active_connections: net.active_connections,
            frames_in: net.frames_in,
            frames_out: net.frames_out,
            submits: net.submits,
            completes: net.completes,
            slow_client_drops: net.slow_client_drops,
            protocol_errors: net.protocol_errors,
        },
    }
}

/// Handshake + reader loop for one accepted socket. Returns the reader
/// thread's handle; the writer and completer threads it spawns tear
/// down by queue-disconnect cascade.
fn spawn_connection(
    conn_id: u64,
    mut stream: TcpStream,
    svc: Arc<FpuService>,
    config: NetConfig,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("net-conn-{conn_id}"))
        .spawn(move || {
            stats.active_connections.fetch_add(1, Ordering::Relaxed);
            run_connection(conn_id, &mut stream, svc, &config, &stats, &stop);
            stats.active_connections.fetch_sub(1, Ordering::Relaxed);
            conns.lock().unwrap().remove(&conn_id);
            // no shutdown here: on a clean close the writer is still
            // flushing queued COMPLETEs — the client sees FIN when the
            // teardown cascade closes the last duplicated fd
        })
        .with_context(|| format!("spawning net-conn-{conn_id}"))
}

fn run_connection(
    conn_id: u64,
    stream: &mut TcpStream,
    svc: Arc<FpuService>,
    config: &NetConfig,
    stats: &Arc<NetStats>,
    stop: &Arc<AtomicBool>,
) {
    // --- handshake, on the raw socket before any thread is spawned ---
    let hello = match read_frame(stream) {
        Ok(Some(Frame::Hello { version, flags })) => Some((version, flags)),
        Ok(_) | Err(_) => {
            stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            None
        }
    };
    let Some((version, flags)) = hello else { return };
    let granted = if svc.is_durable() { flags & FLAG_DURABLE } else { 0 };
    if write_frame(stream, &Frame::Hello { version: WIRE_VERSION, flags: granted }).is_err() {
        return;
    }
    stats.frames_in.fetch_add(1, Ordering::Relaxed);
    stats.frames_out.fetch_add(1, Ordering::Relaxed);
    if version != WIRE_VERSION {
        // the reply told the client what we speak; nothing more to say
        stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
        return;
    }

    // --- writer + completer plumbing ---
    let (tx, rx) = mpsc::sync_channel::<Frame>(config.writer_queue.max(1));
    let shared = match stream.try_clone() {
        Ok(sock) => Arc::new(ConnShared {
            tx,
            sock,
            stats: stats.clone(),
            dead: AtomicBool::new(false),
        }),
        Err(_) => return,
    };
    let writer = {
        // the writer must NOT hold an Arc<ConnShared>: ConnShared owns
        // the queue's sender, so a strong reference from the writer
        // would keep its own receiver connected forever
        let shared = Arc::downgrade(&shared);
        let fault = config.fault.clone();
        let sock = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        std::thread::Builder::new()
            .name(format!("net-writer-{conn_id}"))
            .spawn(move || writer_loop(sock, rx, shared, fault))
    };
    if writer.is_err() {
        return;
    }

    let completers = config.completers.max(1);
    let mut completer_txs = Vec::with_capacity(completers);
    for k in 0..completers {
        let (ctx, crx) = mpsc::channel::<Outstanding>();
        let shared = shared.clone();
        let svc = svc.clone();
        let stop = stop.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("net-completer-{conn_id}-{k}"))
            .spawn(move || completer_loop(crx, shared, svc, stop));
        if spawned.is_err() {
            return;
        }
        completer_txs.push(ctx);
    }

    // --- the blocking read loop: SUBMIT frames -> the submit path ---
    let handle = svc.handle();
    let mut next_completer = 0usize;
    loop {
        if stop.load(Ordering::Acquire) || shared.dead.load(Ordering::Relaxed) {
            break;
        }
        if let Some(plan) = &config.fault {
            if let Some(shot) = plan.check(FaultSite::ReadStall, "net") {
                std::thread::sleep(Duration::from_micros(shot.micros));
            }
        }
        let submit = match read_frame(stream) {
            Ok(Some(Frame::Submit(s))) => s,
            Ok(Some(Frame::StatsRequest)) => {
                // wire-queryable metrics: reply with the versioned
                // snapshot through the writer queue (ordering with
                // in-flight COMPLETEs preserved) and keep reading
                stats.frames_in.fetch_add(1, Ordering::Relaxed);
                shared.push(Frame::Stats(stats_frame(&svc, Some(stats))));
                continue;
            }
            Ok(None) => break, // clean close
            Ok(Some(_)) => {
                // HELLO twice, or a server-only frame from a client
                stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Err(_) => {
                // torn frame / CRC mismatch / unknown kind: the stream
                // cannot be resynchronized, drop the connection
                if !stop.load(Ordering::Acquire) && !shared.dead.load(Ordering::Relaxed) {
                    stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
        };
        stats.frames_in.fetch_add(1, Ordering::Relaxed);
        service_submit(&handle, &svc, submit, &shared, &completer_txs, &mut next_completer);
        if let Some(plan) = &config.fault {
            if plan.check(FaultSite::ConnDrop, "net").is_some() {
                // inject *after* servicing: a journalled job survives
                // its client's death — the chaos tests pin that
                stats.injected_conn_drops.fetch_add(1, Ordering::Relaxed);
                shared.drop_conn();
                break;
            }
        }
    }
    // dropping `shared` (and the completer senders) cascades teardown:
    // completers drain, the writer's queue disconnects, the writer exits
}

/// One SUBMIT frame onto the 1:1 submit path: TICKET ack, then hand the
/// wait to a completer (round-robin, so a slow batch doesn't block the
/// next frame's completion path).
fn service_submit(
    handle: &crate::coordinator::ServiceHandle,
    svc: &Arc<FpuService>,
    s: SubmitFrame,
    shared: &Arc<ConnShared>,
    completer_txs: &[mpsc::Sender<Outstanding>],
    next_completer: &mut usize,
) {
    shared.stats.submits.fetch_add(1, Ordering::Relaxed);
    let deadline = (s.deadline_us > 0).then(|| Duration::from_micros(s.deadline_us as u64));
    let outcome = if s.flags & SUBMIT_DURABLE != 0 {
        // durable ignores the deadline knob: a journalled job's
        // contract is "runs exactly once", not "runs by T"
        svc.submit_batch_durable(s.op, s.format, &s.a, &s.b)
            .map(|job| Outstanding::Durable { id: s.id, job })
    } else {
        handle
            .submit_batch_tagged(s.op, s.format, &s.a, &s.b, deadline, s.id)
            .map(|ticket| Outstanding::Ticket { id: s.id, ticket })
    };
    match outcome {
        Ok(out) => {
            if !shared.push(Frame::Ticket { id: s.id }) {
                return;
            }
            let k = *next_completer % completer_txs.len();
            *next_completer = next_completer.wrapping_add(1);
            let _ = completer_txs[k].send(out);
        }
        Err(err) => {
            // rejected at submit: the COMPLETE is the only reply (no
            // TICKET — the work never entered the service)
            shared.stats.completes.fetch_add(1, Ordering::Relaxed);
            shared.push(Frame::Complete(CompleteFrame {
                id: s.id,
                status: status_of(&err),
                results: Vec::new(),
                error: format!("{err}"),
            }));
        }
    }
}

/// Wait each acked submit to resolution and queue its COMPLETE frame.
fn completer_loop(
    rx: Receiver<Outstanding>,
    shared: Arc<ConnShared>,
    svc: Arc<FpuService>,
    stop: Arc<AtomicBool>,
) {
    while let Ok(out) = rx.recv() {
        let frame = match out {
            Outstanding::Ticket { id, ticket } => match ticket.wait() {
                Ok(resp) => Frame::Complete(CompleteFrame {
                    id,
                    status: STATUS_OK,
                    results: resp.bits,
                    error: String::new(),
                }),
                Err(err) => Frame::Complete(CompleteFrame {
                    id,
                    status: status_of(&err),
                    results: Vec::new(),
                    error: format!("{err}"),
                }),
            },
            Outstanding::Durable { id, job } => {
                // condvar wait in slices so a stopping server (or a
                // condemned connection) lets the thread go
                let outcome = loop {
                    match svc.wait_for_id(job, Duration::from_millis(200)) {
                        Some(JobPoll::Pending) => {
                            if stop.load(Ordering::Acquire)
                                || shared.dead.load(Ordering::Relaxed)
                            {
                                break None;
                            }
                        }
                        Some(done) => break Some(done),
                        None => {
                            break Some(JobPoll::Failed(ServiceError::Rejected {
                                reason: format!("durable job {job} unknown to the service"),
                            }))
                        }
                    }
                };
                match outcome {
                    None => continue,
                    Some(JobPoll::Done(bits)) => Frame::Complete(CompleteFrame {
                        id,
                        status: STATUS_OK,
                        results: bits,
                        error: String::new(),
                    }),
                    Some(JobPoll::Failed(err)) => Frame::Complete(CompleteFrame {
                        id,
                        status: status_of(&err),
                        results: Vec::new(),
                        error: format!("{err}"),
                    }),
                    Some(JobPoll::Pending) => unreachable!("loop only breaks resolved"),
                }
            }
        };
        shared.stats.completes.fetch_add(1, Ordering::Relaxed);
        shared.push(frame);
    }
}

/// Drain the handoff queue onto the socket; the single writing thread.
/// Holds only a weak reference to the connection state (see the spawn
/// site) so the queue disconnects once the reader and completers are
/// gone — the writer then exits, closing the last fd (the client's FIN).
fn writer_loop(
    mut sock: TcpStream,
    rx: Receiver<Frame>,
    shared: std::sync::Weak<ConnShared>,
    fault: Option<Arc<FaultPlan>>,
) {
    let condemn = |sock: &TcpStream| match shared.upgrade() {
        Some(s) => s.drop_conn(),
        None => {
            let _ = sock.shutdown(Shutdown::Both);
        }
    };
    while let Ok(frame) = rx.recv() {
        if let Some(plan) = &fault {
            if plan.check(FaultSite::PartialWrite, "net").is_some() {
                // write a torn prefix, then kill the connection: the
                // client's CRC/length framing must reject the fragment
                let bytes = encode_frame(&frame);
                let cut = (bytes.len() / 2).max(1);
                let _ = sock.write_all(&bytes[..cut]);
                let _ = sock.flush();
                condemn(&sock);
                break;
            }
        }
        if write_frame(&mut sock, &frame).is_err() {
            condemn(&sock);
            break;
        }
    }
    // queue disconnected (reader + completers gone) or the socket died
}
