//! Optimal reciprocal ROM: p input bits, p+2 output bits.
//!
//! Entry `j` covers `D in [1 + j/2^p, 1 + (j+1)/2^p)` and stores the
//! round-to-nearest `(p+2)`-fraction-bit reciprocal of the interval
//! *midpoint* — the choice that minimizes the worst-case relative error
//! (Sarma–Matula), giving `|D*K - 1| <~ 2^-(p+1)` and hence `p+1` good
//! bits out of the first Goldschmidt step.
//!
//! The construction is exact integer arithmetic and is replicated
//! bit-for-bit by `python/compile/tables.py`; golden-entry tests on both
//! sides pin the correspondence.

use crate::arith::fixed::Fixed;

/// The reciprocal ROM.
#[derive(Clone, Debug)]
pub struct ReciprocalTable {
    p: u32,
    /// Raw (p+2)-fraction-bit entries: value = entry / 2^(p+2).
    entries: Vec<u64>,
}

impl ReciprocalTable {
    /// Build the table for `p` input bits (`1 <= p <= 21`; a 2^21-entry
    /// ROM is already far beyond anything hardware would spend).
    pub fn new(p: u32) -> Self {
        assert!((1..=21).contains(&p), "p={p} out of [1, 21]");
        let n = 1usize << p;
        let mut entries = Vec::with_capacity(n);
        // K_j = round(2^(2p+3) / (2^(p+1) + 2j + 1)); denominator odd, so
        // round-half never ties.
        let num = 1u128 << (2 * p + 3);
        for j in 0..n as u64 {
            let den = (1u128 << (p + 1)) + (2 * j + 1) as u128;
            let k = (num + den / 2) / den;
            entries.push(k as u64);
        }
        Self { p, entries }
    }

    /// Input width in bits.
    pub fn p(&self) -> u32 {
        self.p
    }

    /// Number of entries (2^p).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if empty (never, but clippy appeasement).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Raw integer entry (scaled by 2^(p+2)).
    pub fn entry(&self, index: usize) -> u64 {
        self.entries[index]
    }

    /// ROM index for a mantissa `d in [1, 2)`: its top `p` fraction bits.
    pub fn index_of(&self, d: &Fixed) -> usize {
        let frac = d.frac();
        assert!(frac >= self.p, "mantissa narrower than table input");
        let fraction_bits = d.bits() - (1u64 << frac); // strip leading 1
        (fraction_bits >> (frac - self.p)) as usize
    }

    /// Look up `K_1` for a mantissa `d in [1, 2)`, returned at `frac`
    /// fraction bits (the table's p+2 bits, left-aligned).
    pub fn lookup(&self, d: &Fixed) -> Fixed {
        let k = self.entries[self.index_of(d)];
        let out_frac = self.p + 2;
        let frac = d.frac();
        assert!(frac >= out_frac, "datapath narrower than table output");
        Fixed::from_bits(k << (frac - out_frac), frac)
    }

    /// Exhaustive worst-case `|D*K - 1|` over all interval endpoints
    /// (analytic; used by verification tests and the accuracy bench).
    pub fn max_error(&self) -> f64 {
        let scale = (1u64 << (self.p + 2)) as f64;
        let n = self.entries.len();
        let mut worst: f64 = 0.0;
        for (j, &ki) in self.entries.iter().enumerate() {
            let k = ki as f64 / scale;
            let lo = 1.0 + j as f64 / n as f64;
            let hi = 1.0 + (j + 1) as f64 / n as f64;
            worst = worst.max((lo * k - 1.0).abs()).max((hi * k - 1.0).abs());
        }
        worst
    }

    /// The guaranteed bound the construction targets: `~1.5 * 2^-(p+1)`
    /// (midpoint placement 2^-(p+1) plus output quantization 2^-(p+2)…
    /// times D < 2).
    pub fn error_bound(&self) -> f64 {
        1.5 * 2f64.powi(-(self.p as i32) - 1)
    }

    /// ROM bit count (for the area model): 2^p words of p+2 bits.
    pub fn storage_bits(&self) -> u64 {
        (self.entries.len() as u64) * (self.p as u64 + 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn golden_entries_p10() {
        // Pinned against python/compile/tables.py (same integer formula):
        // j=0: round(2^23 / (2^11 + 1)) = round(8388608/2049) = 4094
        // j=2^10-1: round(8388608/4095) = round(2048.5000...) = 2049
        let t = ReciprocalTable::new(10);
        assert_eq!(t.entry(0), 4094);
        assert_eq!(t.entry(1), 4090);
        assert_eq!(t.entry((1 << 10) - 1), 2049);
        assert_eq!(t.len(), 1024);
    }

    #[test]
    fn entries_monotone_nonincreasing() {
        let t = ReciprocalTable::new(12);
        for w in t.entries.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn entries_in_output_range() {
        for p in [4, 8, 10] {
            let t = ReciprocalTable::new(p);
            for j in 0..t.len() {
                let e = t.entry(j);
                assert!(e > (1 << (p + 1)), "p={p} j={j}");
                assert!(e <= (1 << (p + 2)), "p={p} j={j}");
            }
        }
    }

    #[test]
    fn max_error_within_bound_exhaustive() {
        for p in 2..=12 {
            let t = ReciprocalTable::new(p);
            assert!(
                t.max_error() <= t.error_bound(),
                "p={p}: {} > {}",
                t.max_error(),
                t.error_bound()
            );
        }
    }

    #[test]
    fn index_of_picks_correct_interval() {
        check::property("index matches float computation", |g| {
            let t = ReciprocalTable::new(10);
            let frac = g.usize_in(16, 50) as u32;
            // mantissa in [1, 2)
            let bits = (1u64 << frac) + g.u64_below(1u64 << frac);
            let d = Fixed::from_bits(bits, frac);
            let want = ((d.to_f64() - 1.0) * 1024.0).floor() as usize;
            let got = t.index_of(&d);
            ensure(got == want.min(1023), format!("d={} got={got} want={want}", d.to_f64()))
        });
    }

    #[test]
    fn lookup_first_step_error_bound() {
        check::property("|d*K1 - 1| <= bound", |g| {
            let t = ReciprocalTable::new(10);
            let frac = 40u32;
            let bits = (1u64 << frac) + g.u64_below(1u64 << frac);
            let d = Fixed::from_bits(bits, frac);
            let k1 = t.lookup(&d);
            let r1 = d.to_f64() * k1.to_f64();
            ensure(
                (r1 - 1.0).abs() <= t.error_bound(),
                format!("d={} r1={r1}", d.to_f64()),
            )
        });
    }

    #[test]
    fn storage_bits() {
        assert_eq!(ReciprocalTable::new(10).storage_bits(), 1024 * 12);
        assert_eq!(ReciprocalTable::new(8).storage_bits(), 256 * 10);
    }

    #[test]
    #[should_panic(expected = "out of [1, 21]")]
    fn p_range_checked() {
        ReciprocalTable::new(0);
    }

    #[test]
    fn d_one_gives_k_near_one() {
        let t = ReciprocalTable::new(10);
        let d = Fixed::one(30);
        let k = t.lookup(&d);
        assert!((k.to_f64() - 1.0).abs() < 2e-3);
    }
}
