//! ROM lookup tables: the `K_1` source of the Goldschmidt datapath.
//!
//! * [`reciprocal`] — the "optimal" p-bits-in / (p+2)-bits-out reciprocal
//!   table of Sarma–Matula (paper ref [7]), the exact construction the
//!   python build path uses (`python/compile/tables.py`) — the two are
//!   kept in lock-step by golden-value tests on both sides.
//! * [`rsqrt`] — the reciprocal-square-root variant over `[1, 4)` used by
//!   the square-root datapath (EIMMW variants).

pub mod reciprocal;
pub mod rsqrt;

pub use reciprocal::ReciprocalTable;
pub use rsqrt::RsqrtTable;

/// Default table input width used across the repo (matches
/// `python/compile/tables.py::DEFAULT_P` and the AOT artifacts).
pub const DEFAULT_P: u32 = 10;
