//! Reciprocal-square-root ROM over `[1, 4)` for the Goldschmidt sqrt /
//! rsqrt datapath (EIMMW-2000 variants).
//!
//! Index layout matches real sqrt hardware (and
//! `python/compile/tables.py::rsqrt_table_ints`): the top bit of the
//! index is the operand's exponent parity (`0`: `D in [1,2)`, `1`:
//! `D in [2,4)`), the low `p-1` bits are the mantissa's leading fraction
//! bits. Entries store the round-to-nearest `(p+2)`-fraction-bit value
//! of `1/sqrt(midpoint)`.

use crate::arith::fixed::Fixed;

/// The rsqrt ROM.
#[derive(Clone, Debug)]
pub struct RsqrtTable {
    p: u32,
    entries: Vec<u64>,
}

impl RsqrtTable {
    /// Build for `p` index bits (`2 <= p <= 21`).
    pub fn new(p: u32) -> Self {
        assert!((2..=21).contains(&p), "p={p} out of [2, 21]");
        let half = 1usize << (p - 1);
        let scale = (1u64 << (p + 2)) as f64;
        let mut entries = Vec::with_capacity(half * 2);
        for e0 in 0..2 {
            let base = if e0 == 0 { 1.0 } else { 2.0 };
            for j in 0..half {
                let lo = base * (1.0 + j as f64 / half as f64);
                let hi = base * (1.0 + (j + 1) as f64 / half as f64);
                let mid = 0.5 * (lo + hi);
                entries.push((scale / mid.sqrt()).round() as u64);
            }
        }
        Self { p, entries }
    }

    /// Index width in bits.
    pub fn p(&self) -> u32 {
        self.p
    }

    /// Number of entries (2^p).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if the table has no entries (never happens post-construction).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Raw integer entry (scaled by 2^(p+2)).
    pub fn entry(&self, index: usize) -> u64 {
        self.entries[index]
    }

    /// ROM index for an operand `d in [1, 4)`.
    pub fn index_of(&self, d: &Fixed) -> usize {
        let frac = d.frac();
        assert!(frac + 2 >= self.p, "operand narrower than table input");
        let half = 1usize << (self.p - 1);
        let v = d.bits();
        let two = 1u64 << (frac + 1);
        let (e0, m_bits) = if v >= two {
            (1usize, v - two) // m = d/2 - 1 scaled: strip leading "2"
        } else {
            (0usize, v - (1u64 << frac))
        };
        // top p-1 fraction bits of the in-[1,2) mantissa
        let shift = if e0 == 1 { frac + 1 } else { frac };
        let f = (m_bits << 1 >> (shift + 2 - self.p)) as usize;
        // equivalently floor(m_frac * 2^(p-1)); clamp for safety
        e0 * half + f.min(half - 1)
    }

    /// Look up `y0 ~= 1/sqrt(d)` for `d in [1, 4)` at `frac` fraction bits.
    pub fn lookup(&self, d: &Fixed) -> Fixed {
        let y = self.entries[self.index_of(d)];
        let out_frac = self.p + 2;
        let frac = d.frac();
        assert!(frac >= out_frac);
        Fixed::from_bits(y << (frac - out_frac), frac)
    }

    /// Worst-case `|y0 * sqrt(mid) - 1|` over interval midpoints.
    pub fn max_midpoint_error(&self) -> f64 {
        let scale = (1u64 << (self.p + 2)) as f64;
        let half = self.entries.len() / 2;
        let mut worst: f64 = 0.0;
        for (i, &yi) in self.entries.iter().enumerate() {
            let (e0, j) = (i / half, i % half);
            let base = if e0 == 0 { 1.0 } else { 2.0 };
            let mid = base * (1.0 + (j as f64 + 0.5) / half as f64);
            worst = worst.max((yi as f64 / scale * mid.sqrt() - 1.0).abs());
        }
        worst
    }

    /// ROM bit count for the area model.
    pub fn storage_bits(&self) -> u64 {
        (self.entries.len() as u64) * (self.p as u64 + 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{self, ensure};

    #[test]
    fn construction_matches_python_formula() {
        // golden: p=10, e0=0, j=0: mid = 1 + 0.5/512; K = round(4096/sqrt(mid))
        let t = RsqrtTable::new(10);
        let mid: f64 = 1.0 + 0.5 / 512.0;
        assert_eq!(t.entry(0), (4096.0 / mid.sqrt()).round() as u64);
        // e0=1, j=0: mid = 2*(1 + 0.5/512)
        let mid2: f64 = 2.0 * mid;
        assert_eq!(t.entry(512), (4096.0 / mid2.sqrt()).round() as u64);
    }

    #[test]
    fn entries_monotone_within_halves() {
        let t = RsqrtTable::new(10);
        let half = t.len() / 2;
        for w in t.entries[..half].windows(2) {
            assert!(w[0] >= w[1]);
        }
        for w in t.entries[half..].windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn index_of_matches_float_computation() {
        check::property("rsqrt index", |g| {
            let t = RsqrtTable::new(10);
            let frac = g.usize_in(16, 50) as u32;
            // d in [1, 4): 2 integer bits
            let bits = (1u64 << frac) + g.u64_below(3u64 << frac);
            let d = Fixed::from_bits(bits, frac);
            let v = d.to_f64();
            let half = 512usize;
            let (e0, m) = if v >= 2.0 { (1usize, v / 2.0) } else { (0usize, v) };
            let want = e0 * half + (((m - 1.0) * half as f64).floor() as usize).min(half - 1);
            let got = t.index_of(&d);
            ensure(got == want, format!("d={v} got={got} want={want}"))
        });
    }

    #[test]
    fn lookup_error_small() {
        check::property("|y0*sqrt(d) - 1| small", |g| {
            let t = RsqrtTable::new(10);
            let frac = 40u32;
            let bits = (1u64 << frac) + g.u64_below(3u64 << frac);
            let d = Fixed::from_bits(bits, frac);
            let y0 = t.lookup(&d).to_f64();
            let err = (y0 * d.to_f64().sqrt() - 1.0).abs();
            // interval width /1 relative error ~ 2^-p * 1.5 worst case
            ensure(err < 3.0 * 2f64.powi(-10), format!("d={} err={err}", d.to_f64()))
        });
    }

    #[test]
    fn midpoint_error_tight() {
        let t = RsqrtTable::new(10);
        // at midpoints only quantization remains: 2^-(p+2)-ish
        assert!(t.max_midpoint_error() < 2f64.powi(-11));
    }

    #[test]
    fn storage() {
        assert_eq!(RsqrtTable::new(10).storage_bits(), 1024 * 12);
    }

    #[test]
    #[should_panic(expected = "out of [2, 21]")]
    fn p_range() {
        RsqrtTable::new(1);
    }
}
