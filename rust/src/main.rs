//! `goldschmidt` CLI: simulate the paper's datapaths, print schedules,
//! area reports, accuracy studies, ROM tables, and serve the FPU
//! service. Run with no arguments for usage.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use goldschmidt::arith::fixed::Fixed;
use goldschmidt::arith::twos::ComplementKind;
use goldschmidt::arith::ulp;
use goldschmidt::area::Comparison;
use goldschmidt::coordinator::{
    BatcherConfig, FormatKind, FpuService, JobPoll, OpKind, ServiceConfig,
};
use goldschmidt::dispatch::{standard_registry, RoutePolicy};
use goldschmidt::fault::FaultPlan;
use goldschmidt::goldschmidt::{variants, Config};
use goldschmidt::obs::TraceConfig;
use goldschmidt::sim::Design;
use goldschmidt::tables::ReciprocalTable;
use goldschmidt::util::cli::Args;
use goldschmidt::util::rng::Xoshiro256;
use goldschmidt::util::tablefmt::{fmt_f64, fmt_ns, Align, Table};
use goldschmidt::workload::{ArrivalProcess, WorkloadGen, WorkloadSpec};

const USAGE: &str = "\
goldschmidt — Goldschmidt division with hardware reduction (CS.AR 2019)

USAGE:
  goldschmidt <command> [options]

COMMANDS:
  simulate   run one division through a datapath simulator
             --design baseline|feedback  --n F --d F  --steps K
             --p BITS --frac BITS --complement exact|ones --gantt
  schedule   cycle-count table across step counts (paper Fig. 4)
             --max-steps K
  area       gate-equivalent area comparison (paper claim A1)
             --p BITS --frac BITS --steps K
  accuracy   ulp-accuracy study of variants A/B vs steps (claims ACC/V1/V2)
             --samples N --steps K
  table      dump the reciprocal ROM (paper's K1 source)
             --p BITS --limit N
  stream     sustained-throughput model: back-to-back operation streams
             --ops N --max-steps K
  sqrt       simulate square root on the reduced datapath (EIMMW variant)
             --d F --steps K --gantt
  serve      run the FPU service on a synthetic workload (E2E driver)
             --requests N --workers W
             --shards N (independent coordinator shards, each with its
             own lock-free submit ring, batcher and worker set; 0 =
             one per CPU, default 0 — set 1 to reproduce the old
             single-dispatcher service)
             --backend LIST (comma-separated registry, preference order:
             native|u128|scalar|pjrt — e.g. --backend native,u128,scalar
             routes per (op, format) across three pools; u128 serves
             divide only, pjrt needs --features pjrt and is f32-only)
             --route-policy static|latency (multi-backend arbitration)
             --format f16|bf16|f32|f64|mix (native backend serves all
             four; mix rotates the stream across every format)
             --batch MAX --wait-us US --rate R --artifacts DIR
             --deadline-us US (shed requests older than US; 0 = off)
             --<fmt>-wait-us US / --<fmt>-batch MAX (per-format policy
             override, e.g. --f16-wait-us 25 --f64-batch 2048; with the
             default wait, f16/bf16 queues run a 4x tighter age budget)
             --journal PATH (durable request journal: still-pending
             records are replayed through the submit path on restart)
             --durable (journal every request as a single-lane job via
             the durable API; needs --journal — kill -9 the process and
             a restart replays whatever never retired)
             --fault-spec SPEC --fault-seed U64 (deterministic chaos:
             arm a fault plan, e.g. \"exec-error:p=0.01;latency:us=200\"
             — see goldschmidt::fault for the grammar; env FAULT_PLAN /
             FAULT_SEED are the fallbacks, for CI smoke runs)
             --trace-out PATH (streaming lifecycle trace: a background
             drainer appends rotating JSONL segments during the run
             and merges them into PATH at shutdown — .jsonl => flat
             JSONL, anything else => Chrome trace_event JSON for
             chrome://tracing / Perfetto)
             --trace-rotate-mb MB (rotate trace segments once the
             current one passes MB MiB, default 64)
             --trace-sample N (trace 1 in N requests whole-lifecycle,
             default 64; error-class events are always captured)
             --metrics-listen ADDR (Prometheus text exposition: GET
             http://ADDR/metrics serves the same snapshot as the
             STATS wire frame — 127.0.0.1:0 binds an ephemeral port,
             printed as \"metrics: listening on ...\")
             --stats-interval-ms MS (live stats emitter: one snapshot
             line per interval — qps, queue depth, per-slot p50/p99,
             breaker states, respawns, trace drops)
             --listen ADDR (wire front end: serve the binary protocol
             on a TCP socket instead of the synthetic driver —
             127.0.0.1:0 binds an ephemeral port; the bound address is
             printed as \"net: listening on ...\")
             --listen-for-ms MS (with --listen: serve for MS then shut
             down cleanly; 0 = serve until killed)
  loadgen    open-loop scenario load harness against a `serve --listen`
             --connect ADDR (default 127.0.0.1:7070)
             --scenario steady|burst|ramp|mixed|reconnect|slowloris
             --requests N (total SUBMIT frames across all connections)
             --rate QPS (total offered frame rate across connections)
             --lanes L (vectored lanes per frame) --seed U64
             --format f16|bf16|f32|f64|mix (override the preset's mix)
             --deadline-us US (per-frame wire deadline; 0 = none)
             --durable (journalled submits; server needs --journal)
             --sweep (max-sustained-qps search: double the offered rate
             from --rate until the p99 SLO breaks, then binary-refine
             to the knee; each probe sends --requests frames)
             --slo-p99-ms MS (p99 SLO the sweep holds rates to,
             default 5)
             --stats-poll SECS (poll the server's STATS frame every
             SECS over a side connection and print one \"stats-poll:\"
             line per sample; 0 = off, ignored with --sweep)
  trace-report  per-stage latency breakdown of a --trace-out file
             goldschmidt trace-report TRACE.json (or .jsonl)
  version    print version
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("simulate") => cmd_simulate(args),
        Some("schedule") => cmd_schedule(args),
        Some("area") => cmd_area(args),
        Some("accuracy") => cmd_accuracy(args),
        Some("table") => cmd_table(args),
        Some("stream") => cmd_stream(args),
        Some("sqrt") => cmd_sqrt(args),
        Some("serve") => cmd_serve(args),
        Some("loadgen") => cmd_loadgen(args),
        Some("trace-report") => cmd_trace_report(args),
        Some("version") => {
            println!("goldschmidt {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn config_from(args: &Args) -> Result<Config> {
    let cfg = Config::default()
        .with_table_p(args.get("p", 10u32).map_err(anyhow::Error::msg)?)
        .with_frac(args.get("frac", 30u32).map_err(anyhow::Error::msg)?)
        .with_steps(args.get("steps", 3u32).map_err(anyhow::Error::msg)?)
        .with_complement(
            ComplementKind::parse(&args.get_str("complement", "exact"))
                .map_err(anyhow::Error::msg)?,
        );
    cfg.validate().map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let design = Design::parse(&args.get_str("design", "feedback")).map_err(anyhow::Error::msg)?;
    let nf: f64 = args.get("n", 1.5f64).map_err(anyhow::Error::msg)?;
    let df: f64 = args.get("d", 1.25f64).map_err(anyhow::Error::msg)?;
    if !(1.0..2.0).contains(&nf) || !(1.0..2.0).contains(&df) {
        bail!("--n and --d must be mantissas in [1, 2)");
    }
    let table = ReciprocalTable::new(cfg.table_p);
    let n = Fixed::from_f64(nf, cfg.frac);
    let d = Fixed::from_f64(df, cfg.frac);
    let result = design.simulate(&n, &d, &table, &cfg);
    println!("design    : {design:?}");
    println!("n / d     : {nf} / {df}");
    println!("quotient  : {:.10}  (exact {:.10})", result.quotient.to_f64(), nf / df);
    println!("cycles    : {}", result.cycles);
    if args.flag("gantt") {
        println!("\n{}", result.trace.render_gantt());
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let max_steps: u32 = args.get("max-steps", 4u32).map_err(anyhow::Error::msg)?;
    let base = config_from(args)?;
    let table = ReciprocalTable::new(base.table_p);
    let n = Fixed::from_f64(1.5, base.frac);
    let d = Fixed::from_f64(1.25, base.frac);
    let mut t = Table::new(
        "clock cycles per refinement count (paper Fig. 4)",
        &["steps (q_i)", "baseline", "feedback", "delta"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for k in 1..=max_steps {
        let cfg = base.with_steps(k);
        let b = Design::Baseline.simulate(&n, &d, &table, &cfg).cycles;
        let f = Design::Feedback.simulate(&n, &d, &table, &cfg).cycles;
        t.row(&[
            format!("{k} (q{})", k + 1),
            b.to_string(),
            f.to_string(),
            format!("{:+}", f as i64 - b as i64),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_area(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let cmp = Comparison::at(&cfg);
    let mut t = Table::new(
        format!(
            "area (gate equivalents), p={}, frac={}, steps={}",
            cfg.table_p, cfg.frac, cfg.steps
        ),
        &["component", "baseline", "feedback"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    let row = |t: &mut Table, name: &str, b: (u32, f64), f: (u32, f64)| {
        t.row(&[
            name.to_string(),
            format!("{}x = {:.0} GE", b.0, b.1),
            format!("{}x = {:.0} GE", f.0, f.1),
        ]);
    };
    row(&mut t, "multipliers", cmp.baseline.multipliers, cmp.feedback.multipliers);
    row(&mut t, "2's complement", cmp.baseline.complements, cmp.feedback.complements);
    t.row(&[
        "ROM".to_string(),
        format!("{} bits = {:.0} GE", cmp.baseline.rom.0, cmp.baseline.rom.1),
        format!("{} bits = {:.0} GE", cmp.feedback.rom.0, cmp.feedback.rom.1),
    ]);
    row(&mut t, "logic block", cmp.baseline.logic_blocks, cmp.feedback.logic_blocks);
    t.row(&[
        "registers".to_string(),
        format!("{:.0} GE", cmp.baseline.registers),
        format!("{:.0} GE", cmp.feedback.registers),
    ]);
    t.row(&[
        "TOTAL".to_string(),
        format!("{:.0} GE", cmp.baseline.total()),
        format!("{:.0} GE", cmp.feedback.total()),
    ]);
    t.print();
    println!(
        "saved: {:.0} GE ({:.1}%)",
        cmp.saved(),
        100.0 * cmp.saved_fraction()
    );
    let mut t = Table::new(
        "per-format ROM sizing (seed table at each format's table_p)",
        &["format", "table_p", "entries", "bits", "ROM area"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for row in goldschmidt::area::format_rom_rows() {
        t.row(&[
            row.format.label().to_string(),
            row.table_p.to_string(),
            row.entries.to_string(),
            row.bits.to_string(),
            format!("{:.0} GE", row.gate_equivalents),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let samples: usize = args.get("samples", 20_000usize).map_err(anyhow::Error::msg)?;
    let base = config_from(args)?;
    let table = ReciprocalTable::new(base.table_p);
    let mut t = Table::new(
        "worst-case ulp error vs exact f32 division",
        &["steps", "variant A", "variant B", "predicted rel err"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for k in 1..=base.steps.max(3) {
        let cfg = base.with_steps(k);
        let mut rng = Xoshiro256::new(0xACC);
        let (mut worst_a, mut worst_b) = (0u64, 0u64);
        for _ in 0..samples {
            let n = rng.range_f32(1e-6, 1e6);
            let d = rng.range_f32(1e-6, 1e6);
            let exact = n / d;
            worst_a = worst_a.max(ulp::ulp_diff_f32(
                variants::variant_a_f32(n, d, &table, &cfg),
                exact,
            ));
            worst_b = worst_b.max(ulp::ulp_diff_f32(
                variants::variant_b_f32(n, d, &table, &cfg),
                exact,
            ));
        }
        t.row(&[
            format!("{k} (q{})", k + 1),
            format!("{worst_a} ulp"),
            format!("{worst_b} ulp"),
            fmt_f64(cfg.predicted_error(), 12),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let p: u32 = args.get("p", 10u32).map_err(anyhow::Error::msg)?;
    let limit: usize = args.get("limit", 16usize).map_err(anyhow::Error::msg)?;
    let table = ReciprocalTable::new(p);
    let mut t = Table::new(
        format!("reciprocal ROM p={p} ({} entries, {} bits)", table.len(), table.storage_bits()),
        &["index", "entry", "K", "interval"],
    )
    .aligns(&[Align::Right, Align::Right, Align::Right, Align::Left]);
    let n = table.len();
    for j in (0..n).take(limit) {
        let lo = 1.0 + j as f64 / n as f64;
        let hi = 1.0 + (j + 1) as f64 / n as f64;
        t.row(&[
            j.to_string(),
            table.entry(j).to_string(),
            fmt_f64(table.entry(j) as f64 / (1u64 << (p + 2)) as f64, 6),
            format!("[{lo:.6}, {hi:.6})"),
        ]);
    }
    t.print();
    println!("max |D*K - 1| = {} (bound {})", fmt_f64(table.max_error(), 8), fmt_f64(table.error_bound(), 8));
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let ops: u64 = args.get("ops", 1000u64).map_err(anyhow::Error::msg)?;
    let max_steps: u32 = args.get("max-steps", 4u32).map_err(anyhow::Error::msg)?;
    let base = config_from(args)?;
    let mut t = Table::new(
        format!("back-to-back stream of {ops} divisions (sim::stream)"),
        &["steps", "design", "latency", "II", "total cycles", "ops/cycle"],
    )
    .aligns(&[Align::Right, Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for k in 1..=max_steps {
        for design in [Design::Baseline, Design::Feedback] {
            let r = goldschmidt::sim::stream(design, &base.with_steps(k), ops);
            t.row(&[
                k.to_string(),
                format!("{design:?}"),
                r.latency.to_string(),
                r.initiation_interval.to_string(),
                r.total_cycles.to_string(),
                format!("{:.3}", r.ops_per_cycle()),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn cmd_sqrt(args: &Args) -> Result<()> {
    use goldschmidt::sim::SqrtFeedbackDatapath;
    use goldschmidt::tables::RsqrtTable;
    let cfg = config_from(args)?;
    let df: f64 = args.get("d", 2.5f64).map_err(anyhow::Error::msg)?;
    if !(1.0..4.0).contains(&df) {
        bail!("--d must be a sqrt-mantissa in [1, 4)");
    }
    let dp = SqrtFeedbackDatapath::new(RsqrtTable::new(cfg.table_p), cfg);
    let d = Fixed::from_f64(df, cfg.frac);
    let r = dp.run(&d);
    println!("d        : {df}");
    println!("sqrt(d)  : {:.10}  (exact {:.10})", r.sqrt.to_f64(), df.sqrt());
    println!("1/sqrt(d): {:.10}  (exact {:.10})", r.rsqrt.to_f64(), 1.0 / df.sqrt());
    println!("cycles   : {}", r.cycles);
    if args.flag("gantt") {
        println!("\n{}", r.trace.render_gantt());
    }
    Ok(())
}

/// Start the FPU service on the requested backend registry (a comma-
/// separated preference list — `native,u128,scalar` routes per (op,
/// format) across three worker pools). The PJRT backend only exists
/// when the crate is built with `--features pjrt`; the offline default
/// build serves through the native batch kernels.
fn start_service(
    config: ServiceConfig,
    backend: &str,
    policy: RoutePolicy,
    artifacts: &std::path::Path,
) -> Result<FpuService> {
    let registry = standard_registry(backend, policy, Some(artifacts.to_path_buf()))?;
    FpuService::start_routed(config, registry)
        .context("starting FPU service (pjrt backends need `make artifacts` first)")
}

/// Print the per-stage latency breakdown of a trace file written by
/// `serve --trace-out` (either the Chrome JSON or the JSONL form).
fn cmd_trace_report(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .map(PathBuf::from)
        .ok_or_else(|| anyhow::anyhow!("usage: goldschmidt trace-report TRACE.json"))?;
    print!("{}", goldschmidt::obs::trace_report(&path)?);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests: usize = args.get("requests", 50_000usize).map_err(anyhow::Error::msg)?;
    let backend = args.get_str("backend", "native");
    let policy = RoutePolicy::parse(&args.get_str("route-policy", "static"))
        .map_err(anyhow::Error::msg)?;
    let format_str = args.get_str("format", "f32");
    let mix = format_str == "mix";
    let format = if mix {
        FormatKind::F32
    } else {
        FormatKind::parse(&format_str).map_err(anyhow::Error::msg)?
    };
    if backend == "pjrt" && (mix || format != FormatKind::F32) {
        bail!("the pjrt backend serves f32 only (AOT artifacts are single-precision); use --backend native for {format_str}");
    }
    let workers: usize = args.get("workers", 1usize).map_err(anyhow::Error::msg)?;
    let shards: usize = args.get("shards", 0usize).map_err(anyhow::Error::msg)?;
    let max_batch: usize = args.get("batch", 1024usize).map_err(anyhow::Error::msg)?;
    let explicit_wait: Option<u64> = args.get_opt("wait-us").map_err(anyhow::Error::msg)?;
    let wait_us = explicit_wait.unwrap_or(200);
    let rate: f64 = args.get("rate", 0.0f64).map_err(anyhow::Error::msg)?;
    let deadline_us: u64 = args.get("deadline-us", 0u64).map_err(anyhow::Error::msg)?;
    let artifacts: PathBuf =
        PathBuf::from(args.get_str("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")));

    // format-aware batching policy: with the *default* age budget the
    // half-precision queues run 4x tighter; an explicit --wait-us is
    // honored verbatim for every format (per-format flags still win)
    let mut batcher = BatcherConfig::new(max_batch, Duration::from_micros(wait_us));
    if explicit_wait.is_none() {
        batcher = batcher.tight_half_precision();
    }
    for fmt in FormatKind::ALL {
        let wait_key = format!("{}-wait-us", fmt.label());
        if let Some(us) = args.get_opt::<u64>(&wait_key).map_err(anyhow::Error::msg)? {
            batcher = batcher.with_format_max_wait(fmt, Duration::from_micros(us));
        }
        let batch_key = format!("{}-batch", fmt.label());
        if let Some(mb) = args.get_opt::<usize>(&batch_key).map_err(anyhow::Error::msg)? {
            batcher = batcher.with_format_max_batch(fmt, mb);
        }
    }

    // deterministic chaos: --fault-spec / --fault-seed arm a seeded
    // fault plan over every backend (env FAULT_PLAN / FAULT_SEED are
    // the CI-facing fallbacks — same seed, same spec => same faults)
    let fault_spec = {
        let s = args.get_str("fault-spec", "");
        if s.is_empty() { std::env::var("FAULT_PLAN").unwrap_or_default() } else { s }
    };
    let fault_seed: u64 = match args.get_opt::<u64>("fault-seed").map_err(anyhow::Error::msg)? {
        Some(seed) => seed,
        None => std::env::var("FAULT_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(1),
    };
    let fault = if fault_spec.is_empty() {
        None
    } else {
        let plan = FaultPlan::parse(&fault_spec, fault_seed)
            .context("parsing --fault-spec / FAULT_PLAN")?;
        println!("fault plan armed: {plan}");
        Some(Arc::new(plan))
    };
    // the net plane consults the same plan (conn-drop / partial-write /
    // read-stall sites filter on backend "net")
    let net_fault = fault.clone();
    // lifecycle tracing: --trace-out arms the trace plane for the whole
    // run (1-in-N whole-request sampling; error-class events are always
    // captured) and the file is written at shutdown
    let trace_out = {
        let p = args.get_str("trace-out", "");
        if p.is_empty() { None } else { Some(PathBuf::from(p)) }
    };
    let trace_sample: u64 = args.get("trace-sample", 64u64).map_err(anyhow::Error::msg)?;
    let trace_rotate_mb: u64 = args.get("trace-rotate-mb", 64u64).map_err(anyhow::Error::msg)?;
    let metrics_listen = args.get_str("metrics-listen", "");
    let stats_interval_ms: u64 =
        args.get("stats-interval-ms", 0u64).map_err(anyhow::Error::msg)?;
    let journal_arg = args.get_str("journal", "");
    let journal =
        if journal_arg.is_empty() { None } else { Some(PathBuf::from(journal_arg)) };
    let journal_armed = journal.is_some();
    let durable = args.flag("durable");
    if durable && !journal_armed {
        bail!("--durable needs --journal PATH");
    }

    let config = ServiceConfig {
        batcher,
        queue_depth: 65_536,
        workers,
        shards,
        poll: Duration::from_micros(50),
        fault,
        journal,
        trace: trace_out
            .as_ref()
            .map(|_| TraceConfig { sample: trace_sample, ..TraceConfig::default() }),
        stats_interval: (stats_interval_ms > 0)
            .then(|| Duration::from_millis(stats_interval_ms)),
        ..ServiceConfig::default()
    };

    let svc = Arc::new(start_service(config, &backend, policy, &artifacts)?);
    if journal_armed {
        println!("journal: replayed {} pending job(s)", svc.replayed_jobs());
    }

    // streaming trace export: the drainer pumps the trace rings while
    // the service runs, so a serve's history is bounded by disk, not by
    // ring capacity; segments are merged into --trace-out at shutdown
    let drainer = match (&trace_out, svc.trace()) {
        (Some(path), Some(plane)) => {
            let cfg = goldschmidt::obs::DrainConfig {
                path: path.clone(),
                rotate_bytes: trace_rotate_mb.max(1) << 20,
                backend_names: svc.backend_names().iter().map(|s| s.to_string()).collect(),
                ..Default::default()
            };
            let d = goldschmidt::obs::TraceDrainer::start(plane, cfg)?;
            println!(
                "trace: streaming to {} (segments rotate at {} MiB)",
                path.display(),
                trace_rotate_mb.max(1)
            );
            Some(d)
        }
        _ => None,
    };

    // --listen swaps the synthetic driver for the wire front end: the
    // service stays up serving SUBMIT frames until the window elapses
    // (or forever), then tears down cleanly
    let listen = args.get_str("listen", "");
    if !listen.is_empty() {
        let listen_for_ms: u64 = args.get("listen-for-ms", 0u64).map_err(anyhow::Error::msg)?;
        let net_cfg = goldschmidt::net::NetConfig { fault: net_fault, ..Default::default() };
        let mut server = goldschmidt::net::NetServer::start(Arc::clone(&svc), &listen, net_cfg)?;
        println!("net: listening on {}", server.local_addr());
        // the scrape endpoint folds the front end's counters into the
        // same snapshot the STATS wire frame serves
        let metrics_server = if metrics_listen.is_empty() {
            None
        } else {
            let m = goldschmidt::net::MetricsServer::start(
                Arc::clone(&svc),
                Some(server.stats()),
                &metrics_listen,
            )?;
            println!("metrics: listening on http://{}/metrics", m.local_addr());
            Some(m)
        };
        // the accept loop runs on its own thread; CI tails these lines
        // from a redirected log, so push them out of the stdout buffer
        std::io::Write::flush(&mut std::io::stdout()).ok();
        if listen_for_ms == 0 {
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        std::thread::sleep(Duration::from_millis(listen_for_ms));
        server.stop();
        let net = server.stats().snapshot();
        println!(
            "net: served {} submit(s) / {} completion(s) over {} connection(s), \
             {} slow-client drop(s), {} injected conn-drop(s), {} protocol error(s)",
            net.submits,
            net.completes,
            net.connections,
            net.slow_client_drops,
            net.injected_conn_drops,
            net.protocol_errors
        );
        if let Some(mut m) = metrics_server {
            m.stop();
        }
        drop(server);
        // tear the service down before the final drain so every
        // lifecycle event is emitted by the time the segments merge
        drop(svc);
        finish_drainer(drainer)?;
        return Ok(());
    }

    // synthetic driver: the scrape endpoint still works (no wire front
    // end, so the fpu_net_* family reads zero)
    let metrics_server = if metrics_listen.is_empty() {
        None
    } else {
        let m = goldschmidt::net::MetricsServer::start(Arc::clone(&svc), None, &metrics_listen)?;
        println!("metrics: listening on http://{}/metrics", m.local_addr());
        Some(m)
    };

    let spec = WorkloadSpec {
        count: requests,
        arrivals: if rate > 0.0 {
            ArrivalProcess::Poisson { rate }
        } else {
            ArrivalProcess::Closed
        },
        divide_frac: 0.7,
        format,
        ..Default::default()
    };
    println!(
        "serving {requests} {format_str} requests on backend={backend} policy={} \
         workers={workers} (per pool) ...",
        policy.label()
    );
    let mut reqs = WorkloadGen::generate(spec);
    if mix {
        // rotate the four formats in blocks of five requests: every
        // per-format batcher queue carries traffic, and the block
        // length is coprime to power-of-two --trace-sample strides so
        // a sampled trace still sees all four formats
        for (i, r) in reqs.iter_mut().enumerate() {
            r.format = FormatKind::ALL[(i / 5) % FormatKind::ALL.len()];
        }
    }
    let t0 = std::time::Instant::now();
    let mut ok = 0u64;
    if durable {
        // every request becomes a journalled single-lane durable job:
        // kill -9 anywhere in this loop and a restart replays exactly
        // the records that never retired
        let mut ids = Vec::with_capacity(requests);
        for r in reqs {
            let a = [r.value_a().bits()];
            let b = [r.value_b().bits()];
            let b: &[u64] = if matches!(r.op, OpKind::Divide) { &b } else { &[] };
            ids.push(svc.submit_batch_durable(r.op, r.format, &a, b)?);
        }
        for id in ids {
            // streaming completion: the retirer's condvar wakes this
            // exactly when the job resolves (no poll/sleep spin); the
            // timeout only bounds each wait so a wedged job cannot
            // hang the driver silently
            loop {
                match svc.wait_for_id(id, Duration::from_millis(500)) {
                    Some(JobPoll::Done(_)) => {
                        ok += 1;
                        break;
                    }
                    Some(JobPoll::Failed(_)) => break,
                    Some(JobPoll::Pending) => {}
                    None => break,
                }
            }
        }
    } else {
        let handle = svc.handle();
        let deadline = Duration::from_micros(deadline_us);
        let mut tickets = Vec::with_capacity(requests);
        for r in reqs {
            if deadline_us > 0 {
                // admission control may reject at submit time when the
                // queue-delay estimate already exceeds the budget: that
                // is load shedding working, not a serve failure (the
                // rejects are counted in the metrics snapshot below)
                match handle.submit_value_deadline(r.op, r.value_a(), r.value_b(), deadline) {
                    Ok(ticket) => tickets.push(ticket),
                    Err(goldschmidt::coordinator::ServiceError::Deadline) => {}
                    Err(e) => return Err(e.into()),
                }
            } else {
                tickets.push(handle.submit_value(r.op, r.value_a(), r.value_b())?);
            }
        }
        for t in tickets {
            if t.wait().is_ok() {
                ok += 1;
            }
        }
    }
    let elapsed = t0.elapsed();
    let snap = svc.metrics().snapshot();
    let mut t = Table::new(
        format!(
            "FPU service: {ok}/{requests} ok in {:.2}s  ({:.0} req/s)",
            elapsed.as_secs_f64(),
            ok as f64 / elapsed.as_secs_f64()
        ),
        &["op", "requests", "batches", "mean lat", "p99 lat", "occupancy"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for s in &snap.ops {
        t.row(&[
            s.op.label().to_string(),
            s.requests.to_string(),
            s.batches.to_string(),
            fmt_ns(s.mean_latency_ns),
            fmt_ns(s.p99_latency_ns as f64),
            format!("{:.0}%", 100.0 * s.occupancy),
        ]);
    }
    t.print();
    if snap.total_shed() > 0 || snap.total_errors() > 0 || snap.total_admission_rejected() > 0 {
        println!(
            "shed (deadline): {}   rejected (admission): {}   errors (exec/worker): {}",
            snap.total_shed(),
            snap.total_admission_rejected(),
            snap.total_errors()
        );
    }
    // multi-backend runs: show where the traffic went and how the
    // breakers fared
    let report = svc.dispatch_report();
    if report.len() > 1 {
        let mut t = Table::new(
            "dispatch plane (per backend)",
            &[
                "backend", "batches ok", "failed", "rerouted", "trips", "probes", "respawns",
                "p50 ns/l", "p99 ns/l", "breaker",
            ],
        )
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for (name, s) in &report {
            t.row(&[
                name.to_string(),
                s.ok_batches.to_string(),
                s.failed_batches.to_string(),
                s.rerouted.to_string(),
                s.trips.to_string(),
                s.probes.to_string(),
                s.respawns.to_string(),
                fmt_ns(s.p50_exec_ns_per_lane),
                fmt_ns(s.p99_exec_ns_per_lane),
                if s.degraded {
                    "DEGRADED".into()
                } else if s.breaker_open {
                    "OPEN".into()
                } else {
                    "closed".into()
                },
            ]);
        }
        t.print();
    }
    if let Some(mut m) = metrics_server {
        m.stop();
    }
    // graceful teardown (drains queues, joins workers) before the final
    // trace drain so the merged document carries the whole run
    drop(svc);
    finish_drainer(drainer)?;
    Ok(())
}

/// Stop a streaming trace drainer (if armed), merge its segments into
/// the target path, and print the accounting line CI greps for.
fn finish_drainer(drainer: Option<goldschmidt::obs::TraceDrainer>) -> Result<()> {
    let Some(d) = drainer else { return Ok(()) };
    let r = d.finish()?;
    println!(
        "trace: merged {} event(s) from {} segment(s) into {} \
         ({} streamed, {} ring drop(s), {} io drop(s))",
        r.merged_events,
        r.segments,
        r.path.display(),
        r.events_written,
        r.ring_drops,
        r.io_drops
    );
    Ok(())
}

/// Drive a `serve --listen` endpoint with one of the named open-loop
/// scenarios (see `goldschmidt::workload::scenario`). Prints the
/// headline `loadgen: N/N ok` line CI asserts on; exits nonzero when a
/// scenario that promises zero rider-visible errors loses frames.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use goldschmidt::workload::{run_scenario, sweep_max_qps, ScenarioSpec, SCENARIOS};

    let connect = args.get_str("connect", "127.0.0.1:7070");
    let scenario = args.get_str("scenario", "steady");
    let requests: usize = args.get("requests", 10_000usize).map_err(anyhow::Error::msg)?;
    let rate: f64 = args.get("rate", 20_000.0f64).map_err(anyhow::Error::msg)?;
    let seed: u64 = args.get("seed", 0xFEEDu64).map_err(anyhow::Error::msg)?;
    let mut spec = ScenarioSpec::preset(&scenario, requests, rate, seed).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario {scenario:?} (try {})", SCENARIOS.join("|"))
    })?;
    spec.lanes = args.get("lanes", 8usize).map_err(anyhow::Error::msg)?;
    spec.deadline_us = args.get("deadline-us", 0u32).map_err(anyhow::Error::msg)?;
    spec.durable = args.flag("durable");
    let fmt_str = args.get_str("format", "");
    if !fmt_str.is_empty() {
        spec.formats = if fmt_str == "mix" {
            FormatKind::ALL.to_vec()
        } else {
            vec![FormatKind::parse(&fmt_str).map_err(anyhow::Error::msg)?]
        };
    }
    if args.flag("sweep") {
        // max-sustained-qps search: probe offered rates until the p99
        // SLO breaks, then binary-refine to the knee; --rate is the
        // starting (floor) rate and --requests the frames per probe
        let slo_ms: u64 = args.get("slo-p99-ms", 5u64).map_err(anyhow::Error::msg)?;
        let slo = Duration::from_millis(slo_ms.max(1));
        println!(
            "loadgen: sweep scenario={scenario} start={rate:.0} qps slo-p99={slo_ms}ms \
             probe-requests={} -> {connect}",
            spec.requests
        );
        let sweep = sweep_max_qps(connect, &spec, rate, slo)?;
        let mut t = Table::new(
            "offered-rate sweep (open-loop probes)",
            &["offered qps", "achieved qps", "p99", "all ok", "verdict"],
        )
        .aligns(&[Align::Right, Align::Right, Align::Right, Align::Right, Align::Left]);
        for p in &sweep.probes {
            t.row(&[
                format!("{:.0}", p.offered_qps),
                format!("{:.0}", p.achieved_qps),
                fmt_ns(p.p99_ns as f64),
                p.all_ok.to_string(),
                if p.sustained { "sustained".into() } else { "over SLO".to_string() },
            ]);
        }
        t.print();
        if sweep.max_sustained_qps > 0.0 {
            println!(
                "loadgen: max sustained {:.0} qps within p99 <= {slo_ms}ms",
                sweep.max_sustained_qps
            );
            return Ok(());
        }
        bail!("no offered rate met the p99 SLO (even {rate:.0} qps missed {slo_ms}ms)");
    }

    // --stats-poll: a side connection round-trips the STATS frame on an
    // interval while the scenario runs; rates come from differencing
    // consecutive snapshots against the server's own monotonic clock
    let stats_poll: u64 = args.get("stats-poll", 0u64).map_err(anyhow::Error::msg)?;
    let poller = if stats_poll > 0 {
        let addr = connect.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut client = match goldschmidt::net::NetClient::connect(addr.as_str()) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("stats-poll: connect failed: {e:#}");
                        return;
                    }
                };
                let total =
                    |f: &goldschmidt::net::StatsFrame| f.slots.iter().map(|s| s.requests).sum::<u64>();
                let mut last: Option<goldschmidt::net::StatsFrame> = None;
                loop {
                    // the server tearing down ends the poll quietly
                    let Ok(frame) = client.stats() else { return };
                    let qps = match &last {
                        Some(prev) if frame.server_ns > prev.server_ns => {
                            total(&frame).saturating_sub(total(prev)) as f64
                                / ((frame.server_ns - prev.server_ns) as f64 / 1e9)
                        }
                        _ => 0.0,
                    };
                    let queued: u64 = frame.slots.iter().map(|s| s.queued_lanes).sum();
                    println!(
                        "stats-poll: qps={qps:.0} queued={queued} shards={} conns={} \
                         slow-drops={} trace-drops={} respawns={}",
                        frame.shards.len(),
                        frame.net.active_connections,
                        frame.net.slow_client_drops,
                        frame.trace_drops,
                        frame.respawns
                    );
                    last = Some(frame);
                    // sleep in slices so the post-run join is prompt
                    let mut left = Duration::from_secs(stats_poll);
                    while !left.is_zero() {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        let slice = left.min(Duration::from_millis(100));
                        std::thread::sleep(slice);
                        left = left.saturating_sub(slice);
                    }
                }
            })
        };
        Some((stop, thread))
    } else {
        None
    };

    println!(
        "loadgen: scenario={scenario} requests={} connections={} lanes={} -> {connect}",
        spec.requests, spec.connections, spec.lanes
    );
    let report = run_scenario(connect, &spec)?;
    if let Some((stop, thread)) = poller {
        stop.store(true, Ordering::Release);
        let _ = thread.join();
    }
    println!(
        "loadgen: {:.0} qps achieved in {:.2}s, p50 {} p99 {}, {} service error(s), \
         {} transport loss(es), {} reconnect(s)",
        report.qps(),
        report.elapsed_s,
        fmt_ns(report.p50_ns() as f64),
        fmt_ns(report.p99_ns() as f64),
        report.service_errors,
        report.transport_errors,
        report.reconnects
    );
    println!("loadgen: {}/{} ok", report.ok, requests);
    // slow-loris deliberately gets its slow reader shed; every other
    // scenario promises zero rider-visible errors
    if scenario != "slowloris" && report.ok != requests as u64 {
        bail!(
            "{} of {requests} frame(s) did not complete ok",
            (requests as u64).saturating_sub(report.ok)
        );
    }
    Ok(())
}
