//! [`RoutingTable`]: several backends' [`BackendCaps`] merged into one
//! per-(op, format) routing table.
//!
//! The merge produces two things:
//!
//! * **candidate lists** — for every (op, format) pair, the indices of
//!   the backends that serve it, in registration (= static preference)
//!   order. The dispatch plane picks among these per batch.
//! * **the union capability table** — one [`BackendCaps`] whose
//!   supported set is the union of every backend's (with merged
//!   ladders). The service handle rejects submissions against this
//!   union: a pair *some* backend serves is admissible even if the
//!   preferred backend cannot run it — that is the whole point of a
//!   router.
//!
//! Per-backend shape (ladders, plane widths) is **not** collapsed: the
//! batcher keeps one shape table per backend and forms each batch at
//! the width and ladder of the backend the plane selected, so a `u64`-
//! planes-only baseline backend and the width-true native backend can
//! share one service without either compromising its geometry.

use anyhow::{bail, Result};

use crate::coordinator::request::{op_format_slot, OpKind, OP_FORMAT_SLOTS};
use crate::formats::FormatKind;
use crate::runtime::caps::BackendCaps;

/// Merged routing table over an ordered list of backends.
#[derive(Debug)]
pub struct RoutingTable {
    caps: Vec<BackendCaps>,
    /// Per (op, format) slot: indices of serving backends, preference
    /// order.
    candidates: [Vec<usize>; OP_FORMAT_SLOTS],
    union: BackendCaps,
}

impl RoutingTable {
    /// Merge the probed capability tables (index order = registration
    /// order = static preference order). Fails when no backend serves
    /// any (op, format) pair at all — such a service could only reject.
    pub fn merge(caps: Vec<BackendCaps>) -> Result<Self> {
        if caps.is_empty() {
            bail!("no backends to merge");
        }
        let mut candidates: [Vec<usize>; OP_FORMAT_SLOTS] = std::array::from_fn(|_| Vec::new());
        // the union table is what the client handle sees; a multi-
        // backend union reports the plane's own name, a single backend
        // keeps its own
        let name = if caps.len() == 1 { caps[0].backend() } else { "dispatch" };
        let mut union = BackendCaps::new(name);
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                let mut ladder: Vec<usize> = Vec::new();
                for (i, c) in caps.iter().enumerate() {
                    if c.supports(op, format) {
                        candidates[op_format_slot(op, format)].push(i);
                        ladder.extend_from_slice(c.ladder(op, format));
                    }
                }
                // BackendCaps::with sorts + dedups the merged ladder
                union = union.with(op, format, &ladder);
            }
        }
        if union.supported().is_empty() {
            bail!("no registered backend serves any (op, format) pair");
        }
        Ok(Self { caps, candidates, union })
    }

    /// Number of merged backends.
    pub fn backend_count(&self) -> usize {
        self.caps.len()
    }

    /// One backend's own capability table.
    pub fn caps(&self, backend: usize) -> &BackendCaps {
        &self.caps[backend]
    }

    /// Every backend's capability table, registration order (the
    /// batcher builds its per-backend shape tables from this).
    pub fn caps_list(&self) -> &[BackendCaps] {
        &self.caps
    }

    /// One backend's name (from its own capability table).
    pub fn name(&self, backend: usize) -> &'static str {
        self.caps[backend].backend()
    }

    /// Every backend name, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.caps.iter().map(|c| c.backend()).collect()
    }

    /// The backends serving one (op, format) pair, preference order
    /// (empty when nothing serves it).
    pub fn candidates(&self, op: OpKind, format: FormatKind) -> &[usize] {
        &self.candidates[op_format_slot(op, format)]
    }

    /// The union capability table (what the client handle can admit).
    pub fn union(&self) -> &BackendCaps {
        &self.union
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full(name: &'static str, ladder: &[usize]) -> BackendCaps {
        BackendCaps::uniform(name, ladder)
    }

    fn divide_only(name: &'static str, ladder: &[usize]) -> BackendCaps {
        let mut caps = BackendCaps::new(name);
        for &format in &FormatKind::ALL {
            caps = caps.with(OpKind::Divide, format, ladder);
        }
        caps
    }

    #[test]
    fn single_backend_union_is_identity() {
        let t = RoutingTable::merge(vec![full("native", &[64, 256])]).unwrap();
        assert_eq!(t.backend_count(), 1);
        assert_eq!(t.union().backend(), "native");
        assert_eq!(t.union().supported().len(), 12);
        for &op in &OpKind::ALL {
            for &format in &FormatKind::ALL {
                assert_eq!(t.candidates(op, format), &[0]);
            }
        }
    }

    #[test]
    fn merge_keeps_preference_order_and_partial_caps() {
        let t = RoutingTable::merge(vec![
            divide_only("u128", &[64]),
            full("native", &[64, 256]),
        ])
        .unwrap();
        assert_eq!(t.backend_count(), 2);
        assert_eq!(t.name(0), "u128");
        assert_eq!(t.name(1), "native");
        assert_eq!(t.names(), vec!["u128", "native"]);
        // divide: both serve, registration order
        assert_eq!(t.candidates(OpKind::Divide, FormatKind::F32), &[0, 1]);
        // sqrt: only the full backend
        assert_eq!(t.candidates(OpKind::Sqrt, FormatKind::F32), &[1]);
        // the union admits everything either serves, with merged ladders
        assert_eq!(t.union().backend(), "dispatch");
        assert_eq!(t.union().supported().len(), 12);
        assert_eq!(t.union().ladder(OpKind::Divide, FormatKind::F16), &[64, 256]);
        assert_eq!(t.union().ladder(OpKind::Rsqrt, FormatKind::F64), &[64, 256]);
    }

    #[test]
    fn union_rejects_pairs_nobody_serves() {
        let t = RoutingTable::merge(vec![
            divide_only("a", &[64]),
            divide_only("b", &[256]),
        ])
        .unwrap();
        assert!(t.union().supports(OpKind::Divide, FormatKind::BF16));
        assert!(!t.union().supports(OpKind::Sqrt, FormatKind::F32));
        assert!(t.candidates(OpKind::Sqrt, FormatKind::F32).is_empty());
        assert_eq!(t.union().ladder(OpKind::Divide, FormatKind::F32), &[64, 256]);
    }

    #[test]
    fn degenerate_merges_fail() {
        assert!(RoutingTable::merge(vec![]).is_err());
        // a backend set in which nobody serves anything is unservable
        assert!(RoutingTable::merge(vec![BackendCaps::new("empty")]).is_err());
    }
}
