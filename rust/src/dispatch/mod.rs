//! The dispatch plane: a capability-merging multi-backend router.
//!
//! The paper's thesis is cost-driven backend choice — pick the cheaper
//! datapath when it serves the workload. PR 3's capability-negotiated
//! executor contract ([`BackendCaps`](crate::runtime::BackendCaps))
//! made that decidable at runtime: every backend declares exactly which
//! (op, format) pairs it serves, at which batch ladders and plane
//! widths. This subsystem turns those per-backend tables into a
//! *routing* table and picks, per formed batch, the worker pool that
//! executes it:
//!
//! ```text
//!             ExecutorRegistry (named factories, registration order =
//!                 │             static preference)
//!                 │ probe once at FpuService::start_routed
//!                 ▼
//!             RoutingTable (merged per-(op, format) candidate lists +
//!                 │         the union BackendCaps the handle rejects
//!                 │         against)
//!                 ▼
//!             DispatchPlane::select(op, format)
//!                 │   policy: Static (preference order) or Latency
//!                 │   (measured ns/lane per backend slot, with a
//!                 │   periodic exploration tick so losers re-measure)
//!                 │   health: HealthBoard circuit breakers — an open
//!                 │   backend is routed around, and probed back to
//!                 │   life with one batch in every few considerations
//!                 ▼
//!             per-backend worker pool (coordinator)
//! ```
//!
//! Failure handling is rider-transparent: a batch a backend fails is
//! handed back to the dispatcher, which records the failure on that
//! backend's breaker and **re-routes the batch** to the next candidate
//! (rebuilding its planes at the new backend's negotiated width and
//! ladder). Riders only observe an error when *every* registered
//! candidate for the pair has failed the same batch. Three consecutive
//! failures open a backend's breaker; while open it receives no routed
//! traffic except the probe batches that let a recovered backend
//! rejoin — and closing takes **three consecutive probe successes**
//! (half-open hysteresis), so a flapping backend cannot buy its slot
//! back with one lucky batch. A worker *death* (panic or injected
//! exit) is the pool's problem, not the backend's: the batch requeues
//! unblamed, the coordinator's supervisor respawns the worker, and
//! only when respawns keep failing is the pool marked **degraded** —
//! which the router treats exactly like an open breaker.
//!
//! The registry/table/health split mirrors the coordinator's
//! router/batcher/metrics split: [`registry`] is configuration,
//! [`table`] is the merged static shape, [`health`] is the shared
//! mutable state (workers record outcomes into it), and [`plane`] is
//! the pure selection logic the dispatcher thread owns.

pub mod health;
pub mod plane;
pub mod registry;
pub mod table;

pub use health::{BackendHealthSnapshot, HealthBoard};
pub use plane::{DispatchPlane, Selection};
pub use registry::{standard_registry, ExecutorFactory, ExecutorRegistry, RoutePolicy};
pub use table::RoutingTable;
