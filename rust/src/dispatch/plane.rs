//! [`DispatchPlane`]: the per-batch backend selection logic the
//! dispatcher thread owns.
//!
//! `select` answers "which worker pool executes the next (op, format)
//! batch", combining three inputs:
//!
//! 1. the [`RoutingTable`]'s candidate list for the pair (static
//!    preference order);
//! 2. the [`HealthBoard`]'s breakers and degradation flags — open
//!    backends are routed around, except for the periodic probe that
//!    lets a recovered backend rejoin; pools the supervisor marked
//!    degraded (respawn kept failing, see [`crate::fault`]) are routed
//!    around whenever any alternative exists, and are not probed —
//!    only the supervisor can clear degradation;
//! 3. the [`RoutePolicy`] — registration order, or measured ns/lane
//!    with a periodic exploration tick (every [`EXPLORE_PERIOD`]-th
//!    batch per slot rotates through the other healthy candidates so
//!    their latency signal stays fresh; without it, a backend that
//!    loses the slot once would never be re-measured and could never
//!    win it back).
//!
//! `select_excluding` is the retry chain: given the set of backends a
//! batch has already failed on, it returns the next candidate to try
//! (healthy ones first), or `None` when the batch has exhausted every
//! registered option.
//!
//! In the sharded coordinator each shard dispatcher owns a
//! `DispatchPlane` of its own (selection counters are per shard), but
//! every plane shares one [`HealthBoard`]: breaker trips, probes and
//! degradation are service-wide signals, so a backend opened by one
//! shard's traffic is routed around by all of them — and the health
//! counters aggregate all shards without extra merging.

use std::sync::Arc;

use crate::coordinator::request::{op_format_slot, OpKind, OP_FORMAT_SLOTS};
use crate::formats::FormatKind;
use crate::obs::{TraceEvent, TraceKind, TracePlane};

use super::health::HealthBoard;
use super::registry::RoutePolicy;
use super::table::RoutingTable;

/// Under the latency policy, every `N`-th selection for a slot is an
/// exploration tick: it rotates through the healthy candidates instead
/// of picking the measured-fastest, keeping every backend's latency
/// window warm enough to re-rank.
pub const EXPLORE_PERIOD: u64 = 32;

/// One routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Selection {
    /// Index of the backend (worker pool) to execute on.
    pub backend: usize,
    /// True when this batch is a probe of an open-breaker backend.
    pub probe: bool,
}

/// The dispatcher-owned selection state: merged table + policy +
/// shared health, plus a per-slot sequence counter driving exploration.
#[derive(Debug)]
pub struct DispatchPlane {
    table: RoutingTable,
    policy: RoutePolicy,
    health: Arc<HealthBoard>,
    seq: [u64; OP_FORMAT_SLOTS],
    trace: Option<Arc<TracePlane>>,
}

impl DispatchPlane {
    /// New plane over a merged table.
    pub fn new(table: RoutingTable, policy: RoutePolicy, health: Arc<HealthBoard>) -> Self {
        Self { table, policy, health, seq: [0; OP_FORMAT_SLOTS], trace: None }
    }

    /// Attach a trace plane: `select` then emits sampled
    /// backend-selected events, and the dispatcher's failover path
    /// reaches the plane through [`Self::trace`].
    pub fn with_trace(mut self, trace: Option<Arc<TracePlane>>) -> Self {
        self.trace = trace;
        self
    }

    /// The attached trace plane, if any.
    pub fn trace(&self) -> Option<&Arc<TracePlane>> {
        self.trace.as_ref()
    }

    /// The merged routing table.
    pub fn table(&self) -> &RoutingTable {
        &self.table
    }

    /// The shared health board.
    pub fn health(&self) -> &HealthBoard {
        &self.health
    }

    /// A backend is routable when its breaker is closed and its pool is
    /// not degraded.
    fn routable(&self, b: usize) -> bool {
        !self.health.is_open(b) && !self.health.is_degraded(b)
    }

    /// Trace a routing decision (1-in-N of selections — there is no
    /// request id at selection time, so the gate is a plane-local
    /// tick, not the per-request sample).
    fn note_selection(&self, op: OpKind, format: FormatKind, sel: Selection) -> Selection {
        if let Some(trace) = &self.trace {
            if trace.tick_sampled() {
                trace.emit(
                    TraceEvent::new(TraceKind::BackendSelected, trace.now_ns())
                        .req(0, op, format)
                        .on_backend(sel.backend)
                        .with_arg(u64::from(sel.probe)),
                );
            }
        }
        sel
    }

    /// Non-consuming peek: the backend whose batch *shape* (cap,
    /// ladder) the flush decision should assume — the first healthy
    /// candidate, or the preferred one when every breaker is open.
    /// Unlike [`Self::select`] this touches no probe or exploration
    /// state, so the dispatcher can evaluate "should this queue flush?"
    /// every poll tick without burning probe ticks on polls that form
    /// no batch (which would inflate the probe counters and starve a
    /// broken backend's recovery under light traffic).
    pub fn peek_candidate(&self, op: OpKind, format: FormatKind) -> Option<usize> {
        let cands = self.table.candidates(op, format);
        cands
            .iter()
            .copied()
            .find(|&b| self.routable(b))
            .or_else(|| cands.first().copied())
    }

    /// Pick the backend for the next (op, format) batch. `None` only
    /// when no registered backend serves the pair at all (the handle's
    /// union-caps check rejects such submissions before queueing, so a
    /// routed service never actually sees this).
    pub fn select(&mut self, op: OpKind, format: FormatKind) -> Option<Selection> {
        let cands = self.table.candidates(op, format);
        if cands.is_empty() {
            return None;
        }
        let any_healthy = cands.iter().any(|&b| self.routable(b));
        if !any_healthy {
            // every candidate is open or degraded: serve through the
            // first non-degraded one (a degraded pool may have zero
            // workers) — the retry chain still walks the alternatives,
            // and refusing to route would strand riders
            let backend = cands
                .iter()
                .copied()
                .find(|&b| !self.health.is_degraded(b))
                .unwrap_or(cands[0]);
            return Some(self.note_selection(op, format, Selection { backend, probe: false }));
        }
        // probe an open backend back to life (only worth a batch when a
        // healthy fallback exists to absorb a failed probe); degraded
        // pools are never probed — traffic cannot heal a pool with no
        // workers, only the supervisor can
        for &b in cands {
            if self.health.is_open(b)
                && !self.health.is_degraded(b)
                && self.health.probe_tick(b)
            {
                return Some(self.note_selection(op, format, Selection { backend: b, probe: true }));
            }
        }
        let slot = op_format_slot(op, format);
        let n = self.seq[slot];
        self.seq[slot] += 1;
        let backend = match self.policy {
            RoutePolicy::Static => cands
                .iter()
                .copied()
                .find(|&b| self.routable(b))
                .expect("any_healthy checked"),
            RoutePolicy::Latency => {
                let healthy: Vec<usize> =
                    cands.iter().copied().filter(|&b| self.routable(b)).collect();
                if healthy.len() > 1 && n % EXPLORE_PERIOD == EXPLORE_PERIOD - 1 {
                    // exploration tick: rotate through the candidates
                    healthy[((n / EXPLORE_PERIOD) as usize) % healthy.len()]
                } else {
                    // unmeasured candidates rank ahead of any measured
                    // one (mean < 0 is unreachable for real signal), so
                    // every backend gets signal before ranking settles;
                    // ties break toward registration order
                    let ns_of = |b: usize| {
                        self.health.mean_exec_ns_per_lane(b, op, format).unwrap_or(-1.0)
                    };
                    let mut best = healthy[0];
                    let mut best_ns = ns_of(best);
                    for &b in &healthy[1..] {
                        let ns = ns_of(b);
                        if ns < best_ns {
                            best = b;
                            best_ns = ns;
                        }
                    }
                    best
                }
            }
        };
        Some(self.note_selection(op, format, Selection { backend, probe: false }))
    }

    /// The retry chain: the next candidate for a batch that already
    /// failed on every backend in `tried` (a bitmask of backend
    /// indices). Healthy untried candidates first, then any untried
    /// one; `None` when the batch has exhausted the registry.
    pub fn select_excluding(
        &self,
        op: OpKind,
        format: FormatKind,
        tried: u8,
    ) -> Option<Selection> {
        let untried = |b: &usize| tried & (1u8 << *b) == 0;
        let cands = self.table.candidates(op, format);
        cands
            .iter()
            .copied()
            .find(|b| untried(b) && self.routable(*b))
            .or_else(|| cands.iter().copied().find(untried))
            .map(|backend| Selection { backend, probe: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::health::{
        CLOSE_AFTER_PROBE_SUCCESSES, OPEN_AFTER_CONSECUTIVE, PROBE_PERIOD,
    };
    use crate::runtime::caps::BackendCaps;

    const F32: FormatKind = FormatKind::F32;

    fn two_backend_plane(policy: RoutePolicy) -> DispatchPlane {
        let table = RoutingTable::merge(vec![
            BackendCaps::uniform("a", &[64]),
            BackendCaps::uniform("b", &[64]),
        ])
        .unwrap();
        let health = Arc::new(HealthBoard::new(2));
        DispatchPlane::new(table, policy, health)
    }

    #[test]
    fn static_policy_prefers_registration_order() {
        let mut plane = two_backend_plane(RoutePolicy::Static);
        for _ in 0..10 {
            assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 0);
        }
    }

    #[test]
    fn open_breaker_routes_around_and_probes_periodically() {
        let mut plane = two_backend_plane(RoutePolicy::Static);
        for _ in 0..OPEN_AFTER_CONSECUTIVE {
            plane.health().record_failure(0);
        }
        assert!(plane.health().is_open(0));
        let mut probes = 0;
        let mut fallbacks = 0;
        for _ in 0..(2 * PROBE_PERIOD) {
            let sel = plane.select(OpKind::Divide, F32).unwrap();
            if sel.probe {
                assert_eq!(sel.backend, 0, "probes target the open backend");
                probes += 1;
            } else {
                assert_eq!(sel.backend, 1, "routed traffic avoids the open backend");
                fallbacks += 1;
            }
        }
        assert_eq!(probes, 2, "one probe per period");
        assert_eq!(fallbacks, 2 * PROBE_PERIOD - 2);
        // recovery is hysteretic: preference only returns after K
        // consecutive probe successes close the breaker
        for _ in 0..CLOSE_AFTER_PROBE_SUCCESSES - 1 {
            plane.health().record_success(0, OpKind::Divide, F32, 64, 1_000);
            assert!(plane.health().is_open(0), "one lucky probe must not restore");
        }
        plane.health().record_success(0, OpKind::Divide, F32, 64, 1_000);
        assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 0);
    }

    #[test]
    fn degraded_pool_routes_around_without_probing() {
        let mut plane = two_backend_plane(RoutePolicy::Static);
        plane.health().set_degraded(0, true);
        for _ in 0..(4 * PROBE_PERIOD) {
            let sel = plane.select(OpKind::Divide, F32).unwrap();
            assert_eq!(sel.backend, 1, "traffic avoids the degraded pool");
            assert!(!sel.probe, "degraded pools are not probed");
        }
        assert_eq!(plane.health().snapshot()[0].probes, 0);
        // the retry chain prefers the non-degraded candidate...
        assert_eq!(plane.select_excluding(OpKind::Divide, F32, 0b00).unwrap().backend, 1);
        // ...but still uses the degraded one as a last resort
        assert_eq!(plane.select_excluding(OpKind::Divide, F32, 0b10).unwrap().backend, 0);
        // everything down: prefer the merely-open backend over the
        // degraded (possibly workerless) one
        for _ in 0..OPEN_AFTER_CONSECUTIVE {
            plane.health().record_failure(1);
        }
        assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 1);
        // the supervisor restaffs the pool: preference returns
        plane.health().set_degraded(0, false);
        assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 0);
    }

    #[test]
    fn all_breakers_open_still_routes_preferred() {
        let mut plane = two_backend_plane(RoutePolicy::Static);
        for b in 0..2 {
            for _ in 0..OPEN_AFTER_CONSECUTIVE {
                plane.health().record_failure(b);
            }
        }
        let sel = plane.select(OpKind::Divide, F32).unwrap();
        assert_eq!(sel.backend, 0, "degraded mode serves through the preferred backend");
    }

    #[test]
    fn latency_policy_prefers_measured_fastest() {
        let mut plane = two_backend_plane(RoutePolicy::Latency);
        // no signal: both unmeasured, first candidate wins the tie
        assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 0);
        // backend 0 measured slow, backend 1 unmeasured -> 1 is tried
        plane.health().record_success(0, OpKind::Divide, F32, 64, 640_000);
        assert_eq!(plane.select(OpKind::Divide, F32).unwrap().backend, 1);
        // both measured: the faster one wins the slot
        plane.health().record_success(1, OpKind::Divide, F32, 64, 6_400);
        let picks: Vec<usize> = (0..8)
            .map(|_| plane.select(OpKind::Divide, F32).unwrap().backend)
            .collect();
        assert!(picks.iter().all(|&b| b == 1), "{picks:?}");
        // slots rank independently: sqrt has no signal, ties to 0
        assert_eq!(plane.select(OpKind::Sqrt, F32).unwrap().backend, 0);
    }

    #[test]
    fn latency_policy_explores_periodically() {
        let mut plane = two_backend_plane(RoutePolicy::Latency);
        plane.health().record_success(0, OpKind::Divide, F32, 64, 1_000);
        plane.health().record_success(1, OpKind::Divide, F32, 64, 9_999_000);
        let mut off_preference = 0;
        for _ in 0..(2 * EXPLORE_PERIOD) {
            if plane.select(OpKind::Divide, F32).unwrap().backend != 0 {
                off_preference += 1;
            }
        }
        assert!(
            (1..=2).contains(&off_preference),
            "exploration should visit the loser about once per period, got {off_preference}"
        );
    }

    #[test]
    fn select_excluding_walks_the_chain() {
        let plane = two_backend_plane(RoutePolicy::Static);
        assert_eq!(plane.select_excluding(OpKind::Divide, F32, 0b00).unwrap().backend, 0);
        assert_eq!(plane.select_excluding(OpKind::Divide, F32, 0b01).unwrap().backend, 1);
        assert!(plane.select_excluding(OpKind::Divide, F32, 0b11).is_none());
        // an open-breaker untried backend still serves as last resort
        for _ in 0..OPEN_AFTER_CONSECUTIVE {
            plane.health().record_failure(1);
        }
        assert_eq!(plane.select_excluding(OpKind::Divide, F32, 0b01).unwrap().backend, 1);
    }

    #[test]
    fn peek_candidate_consumes_no_probe_or_exploration_state() {
        let mut plane = two_backend_plane(RoutePolicy::Static);
        for _ in 0..OPEN_AFTER_CONSECUTIVE {
            plane.health().record_failure(0);
        }
        // peeking many times (idle poll ticks) must not tick the probe
        // gate: the first actual selections still route around backend
        // 0 until a real probe period elapses
        for _ in 0..(10 * PROBE_PERIOD) {
            assert_eq!(plane.peek_candidate(OpKind::Divide, F32), Some(1));
        }
        assert_eq!(plane.health().snapshot()[0].probes, 0, "peeks are not probes");
        let mut probes = 0;
        for _ in 0..PROBE_PERIOD {
            if plane.select(OpKind::Divide, F32).unwrap().probe {
                probes += 1;
            }
        }
        assert_eq!(probes, 1, "the probe budget was preserved for real selections");
        // healthy preference: peek returns the first healthy candidate,
        // and the preferred backend once its breaker closes (which
        // takes K consecutive probe successes)
        for _ in 0..CLOSE_AFTER_PROBE_SUCCESSES {
            plane.health().record_success(0, OpKind::Divide, F32, 64, 1_000);
        }
        assert_eq!(plane.peek_candidate(OpKind::Divide, F32), Some(0));
    }

    #[test]
    fn selections_emit_sampled_trace_events() {
        use crate::obs::TraceConfig;
        let table = RoutingTable::merge(vec![
            BackendCaps::uniform("a", &[64]),
            BackendCaps::uniform("b", &[64]),
        ])
        .unwrap();
        let health = Arc::new(HealthBoard::new(2));
        let trace = Arc::new(TracePlane::new(TraceConfig { sample: 2, capacity: 64 }));
        let mut plane = DispatchPlane::new(table, RoutePolicy::Static, health)
            .with_trace(Some(trace.clone()));
        assert!(plane.trace().is_some());
        for _ in 0..10 {
            plane.select(OpKind::Divide, F32).unwrap();
        }
        let evs = trace.events();
        let sel: Vec<_> =
            evs.iter().filter(|e| e.kind == TraceKind::BackendSelected).collect();
        assert_eq!(sel.len(), 5, "1-in-2 of 10 selections");
        assert!(sel.iter().all(|e| e.backend == 0 && e.arg == 0));
    }

    #[test]
    fn unserved_pair_selects_nothing() {
        let mut caps = BackendCaps::new("div-only");
        caps = caps.with(OpKind::Divide, F32, &[64]);
        let table = RoutingTable::merge(vec![caps]).unwrap();
        let health = Arc::new(HealthBoard::new(1));
        let mut plane = DispatchPlane::new(table, RoutePolicy::Static, health);
        assert!(plane.select(OpKind::Sqrt, F32).is_none());
        assert!(plane.select_excluding(OpKind::Sqrt, F32, 0).is_none());
        assert!(plane.select(OpKind::Divide, F32).is_some());
    }
}
