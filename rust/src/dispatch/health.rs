//! [`HealthBoard`]: per-backend health and latency state shared between
//! the dispatcher (which reads it to route) and the worker pools (which
//! write outcomes into it).
//!
//! Two signals per backend:
//!
//! * **circuit breaker** — [`OPEN_AFTER_CONSECUTIVE`] consecutive batch
//!   failures open the breaker; while open the dispatch plane routes
//!   around the backend, except that one consideration in every
//!   [`PROBE_PERIOD`] becomes a *probe* batch sent there anyway. A
//!   probe that succeeds closes the breaker (the backend rejoins at
//!   full preference); a probe that fails is re-routed like any other
//!   failed batch, so riders never pay for probing. Counted failures
//!   are *batch* failures, not lane counts — one wedged batch and one
//!   wedged 4096-lane flush trip the breaker at the same rate.
//! * **latency window** — per (backend, op, format): the last
//!   [`LAT_WINDOW`] successful batches' execution time per lane, the
//!   signal behind
//!   [`RoutePolicy::Latency`](super::registry::RoutePolicy). Windowed,
//!   so a backend that warms up (or cools down) is re-ranked within a
//!   few batches.
//!
//! Everything is atomics plus one per-*batch* mutex for the latency
//! windows — the same locking budget the coordinator's
//! [`Metrics`](crate::coordinator::Metrics) (one lock per batch, never
//! per request) already spends.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::request::{op_format_slot, OpKind, OP_FORMAT_SLOTS};
use crate::formats::FormatKind;
use crate::util::stats::RateWindow;

/// Consecutive batch failures that open a backend's breaker.
pub const OPEN_AFTER_CONSECUTIVE: u32 = 3;

/// While a breaker is open, every `N`-th consideration of that backend
/// becomes a probe batch routed to it anyway.
pub const PROBE_PERIOD: u64 = 8;

/// Per-(backend, slot) latency window length (successful batches).
pub const LAT_WINDOW: usize = 16;

#[derive(Debug, Default)]
struct BackendHealth {
    /// Consecutive batch failures (reset by any success).
    consecutive: AtomicU32,
    /// Breaker state: open = route around.
    open: AtomicBool,
    /// Times the breaker opened.
    trips: AtomicU64,
    /// Probe batches sent while open.
    probes: AtomicU64,
    /// Considerations of this backend while open (drives the probe
    /// period).
    probe_gate: AtomicU64,
    /// Batches served successfully.
    ok_batches: AtomicU64,
    /// Batches failed.
    failed_batches: AtomicU64,
    /// Failed batches of this backend re-routed to another backend
    /// (rider-invisible failures).
    rerouted: AtomicU64,
}

/// One backend's health counters at a point in time.
#[derive(Clone, Copy, Debug)]
pub struct BackendHealthSnapshot {
    /// Batches served successfully.
    pub ok_batches: u64,
    /// Batches failed (whether or not riders saw the failure).
    pub failed_batches: u64,
    /// Failed batches absorbed by re-routing to another backend.
    pub rerouted: u64,
    /// Times the circuit breaker opened.
    pub trips: u64,
    /// Probe batches sent while the breaker was open.
    pub probes: u64,
    /// Whether the breaker is open right now.
    pub breaker_open: bool,
}

/// Shared health/latency state for every registered backend.
#[derive(Debug)]
pub struct HealthBoard {
    backends: Vec<BackendHealth>,
    /// Per backend, per (op, format) slot: successful-batch service-
    /// rate windows (one lock per recorded batch) — the shared
    /// [`RateWindow`] type the admission model also uses.
    lat: Mutex<Vec<[RateWindow<LAT_WINDOW>; OP_FORMAT_SLOTS]>>,
}

impl HealthBoard {
    /// Fresh board for `n` backends (all breakers closed, no signal).
    pub fn new(n: usize) -> Self {
        Self {
            backends: (0..n).map(|_| BackendHealth::default()).collect(),
            lat: Mutex::new(
                (0..n).map(|_| std::array::from_fn(|_| RateWindow::default())).collect(),
            ),
        }
    }

    /// Number of tracked backends.
    pub fn backend_count(&self) -> usize {
        self.backends.len()
    }

    /// Record one successfully executed batch: closes the breaker,
    /// resets the consecutive-failure count and feeds the latency
    /// window for the batch's slot.
    pub fn record_success(
        &self,
        backend: usize,
        op: OpKind,
        format: FormatKind,
        lanes: u64,
        exec_ns: u64,
    ) {
        let b = &self.backends[backend];
        b.ok_batches.fetch_add(1, Ordering::Relaxed);
        b.consecutive.store(0, Ordering::Relaxed);
        b.open.store(false, Ordering::Release);
        let mut lat = self.lat.lock().expect("health board poisoned");
        lat[backend][op_format_slot(op, format)].push(exec_ns, lanes);
    }

    /// Record one failed batch. Returns `true` when this failure just
    /// opened the breaker.
    pub fn record_failure(&self, backend: usize) -> bool {
        let b = &self.backends[backend];
        b.failed_batches.fetch_add(1, Ordering::Relaxed);
        let consecutive = b.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if consecutive >= OPEN_AFTER_CONSECUTIVE && !b.open.swap(true, Ordering::AcqRel) {
            b.trips.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Record a failed batch of this backend being re-routed to another
    /// one (the rider-invisible outcome).
    pub fn record_reroute(&self, backend: usize) {
        self.backends[backend].rerouted.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether the backend's breaker is open.
    pub fn is_open(&self, backend: usize) -> bool {
        self.backends[backend].open.load(Ordering::Acquire)
    }

    /// Called each time the dispatch plane *considers* an open backend:
    /// every [`PROBE_PERIOD`]-th consideration returns `true` — send a
    /// probe batch there.
    pub fn probe_tick(&self, backend: usize) -> bool {
        let b = &self.backends[backend];
        let n = b.probe_gate.fetch_add(1, Ordering::Relaxed) + 1;
        if n % PROBE_PERIOD == 0 {
            b.probes.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Windowed mean execution nanoseconds per lane for one (backend,
    /// op, format) — `None` until that backend has served the slot.
    pub fn mean_exec_ns_per_lane(
        &self,
        backend: usize,
        op: OpKind,
        format: FormatKind,
    ) -> Option<f64> {
        let lat = self.lat.lock().expect("health board poisoned");
        lat[backend][op_format_slot(op, format)].ns_per_lane()
    }

    /// Per-backend snapshots, index order.
    pub fn snapshot(&self) -> Vec<BackendHealthSnapshot> {
        self.backends
            .iter()
            .map(|b| BackendHealthSnapshot {
                ok_batches: b.ok_batches.load(Ordering::Relaxed),
                failed_batches: b.failed_batches.load(Ordering::Relaxed),
                rerouted: b.rerouted.load(Ordering::Relaxed),
                trips: b.trips.load(Ordering::Relaxed),
                probes: b.probes.load(Ordering::Relaxed),
                breaker_open: b.open.load(Ordering::Acquire),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn breaker_opens_after_consecutive_failures_and_success_closes_it() {
        let h = HealthBoard::new(2);
        assert!(!h.is_open(0));
        for i in 0..OPEN_AFTER_CONSECUTIVE {
            let opened = h.record_failure(0);
            assert_eq!(opened, i + 1 == OPEN_AFTER_CONSECUTIVE, "failure {i}");
        }
        assert!(h.is_open(0));
        assert!(!h.is_open(1), "breakers are per backend");
        // further failures do not re-trip
        assert!(!h.record_failure(0));
        let snap = h.snapshot();
        assert_eq!(snap[0].trips, 1);
        assert_eq!(snap[0].failed_batches, (OPEN_AFTER_CONSECUTIVE + 1) as u64);
        assert!(snap[0].breaker_open);
        // one success closes the breaker and resets the streak
        h.record_success(0, OpKind::Divide, F32, 64, 1_000);
        assert!(!h.is_open(0));
        assert!(!h.record_failure(0), "streak restarted from zero");
        assert!(!h.is_open(0));
    }

    #[test]
    fn interleaved_successes_keep_breaker_closed() {
        let h = HealthBoard::new(1);
        for _ in 0..20 {
            h.record_failure(0);
            h.record_failure(0);
            h.record_success(0, OpKind::Sqrt, F32, 64, 500);
        }
        assert!(!h.is_open(0), "non-consecutive failures must not trip");
        assert_eq!(h.snapshot()[0].trips, 0);
    }

    #[test]
    fn probe_ticks_fire_once_per_period() {
        let h = HealthBoard::new(1);
        let mut fired = 0;
        for _ in 0..(2 * PROBE_PERIOD) {
            if h.probe_tick(0) {
                fired += 1;
            }
        }
        assert_eq!(fired, 2);
        assert_eq!(h.snapshot()[0].probes, 2);
    }

    #[test]
    fn latency_windows_are_per_slot_and_decay() {
        let h = HealthBoard::new(2);
        assert!(h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).is_none());
        h.record_success(0, OpKind::Divide, F32, 100, 100_000);
        let m = h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).unwrap();
        assert!((m - 1_000.0).abs() < 1e-9, "{m}");
        // other slots and backends stay unsignalled
        assert!(h.mean_exec_ns_per_lane(0, OpKind::Sqrt, F32).is_none());
        assert!(h.mean_exec_ns_per_lane(1, OpKind::Divide, F32).is_none());
        // the window decays: fill it with fast batches and the slow
        // first sample ages out
        for _ in 0..LAT_WINDOW {
            h.record_success(0, OpKind::Divide, F32, 100, 1_000);
        }
        let m = h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).unwrap();
        assert!((m - 10.0).abs() < 1e-9, "window did not decay: {m}");
    }

    #[test]
    fn reroutes_counted() {
        let h = HealthBoard::new(1);
        h.record_reroute(0);
        h.record_reroute(0);
        assert_eq!(h.snapshot()[0].rerouted, 2);
    }
}
