//! [`HealthBoard`]: per-backend health and latency state shared between
//! the dispatcher (which reads it to route) and the worker pools (which
//! write outcomes into it).
//!
//! Two signals per backend:
//!
//! * **circuit breaker** — [`OPEN_AFTER_CONSECUTIVE`] consecutive batch
//!   failures open the breaker; while open the dispatch plane routes
//!   around the backend, except that one consideration in every
//!   [`PROBE_PERIOD`] becomes a *probe* batch sent there anyway. The
//!   breaker is **half-open** under probing: it takes
//!   [`CLOSE_AFTER_PROBE_SUCCESSES`] consecutive probe successes to
//!   close (one lucky probe of a still-sick backend is not recovery),
//!   and any failure resets that streak; a probe that fails is
//!   re-routed like any other failed batch, so riders never pay for
//!   probing. Counted failures are *batch* failures, not lane counts —
//!   one wedged batch and one wedged 4096-lane flush trip the breaker
//!   at the same rate.
//!
//! The board also carries the **supervision signals** of the fault
//! plane (see [`crate::fault`]): `respawns` counts workers the per-pool
//! supervisor brought back after a death, and `degraded` marks a pool
//! whose respawns kept failing — the dispatch plane routes around a
//! degraded pool whenever a healthy alternative exists, and
//! `dispatch_report` surfaces both.
//! * **latency window** — per (backend, op, format): the last
//!   [`LAT_WINDOW`] successful batches' execution time per lane, the
//!   signal behind
//!   [`RoutePolicy::Latency`](super::registry::RoutePolicy). Windowed,
//!   so a backend that warms up (or cools down) is re-ranked within a
//!   few batches.
//!
//! Everything is atomics plus one per-*batch* mutex for the latency
//! windows — the same locking budget the coordinator's
//! [`Metrics`](crate::coordinator::Metrics) (one lock per batch, never
//! per request) already spends.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::request::{op_format_slot, OpKind, OP_FORMAT_SLOTS};
use crate::formats::FormatKind;
use crate::util::stats::{RateWindow, Summary};

/// Consecutive batch failures that open a backend's breaker.
pub const OPEN_AFTER_CONSECUTIVE: u32 = 3;

/// While a breaker is open, every `N`-th consideration of that backend
/// becomes a probe batch routed to it anyway.
pub const PROBE_PERIOD: u64 = 8;

/// Consecutive successes an *open* breaker must see before it closes
/// again (half-open hysteresis — one lucky probe is not recovery).
pub const CLOSE_AFTER_PROBE_SUCCESSES: u32 = 3;

/// Per-(backend, slot) latency window length (successful batches).
pub const LAT_WINDOW: usize = 16;

#[derive(Debug, Default)]
struct BackendHealth {
    /// Consecutive batch failures (reset by any success).
    consecutive: AtomicU32,
    /// Breaker state: open = route around.
    open: AtomicBool,
    /// Times the breaker opened.
    trips: AtomicU64,
    /// Probe batches sent while open.
    probes: AtomicU64,
    /// Considerations of this backend while open (drives the probe
    /// period).
    probe_gate: AtomicU64,
    /// Batches served successfully.
    ok_batches: AtomicU64,
    /// Batches failed.
    failed_batches: AtomicU64,
    /// Failed batches of this backend re-routed to another backend
    /// (rider-invisible failures).
    rerouted: AtomicU64,
    /// Consecutive successes since the breaker opened (half-open
    /// streak; reset by any failure).
    probe_successes: AtomicU32,
    /// Supervisor could not keep this pool staffed — route around it
    /// whenever an alternative exists.
    degraded: AtomicBool,
    /// Workers respawned by the pool supervisor after a death.
    respawns: AtomicU64,
}

/// One backend's health counters at a point in time.
#[derive(Clone, Copy, Debug)]
pub struct BackendHealthSnapshot {
    /// Batches served successfully.
    pub ok_batches: u64,
    /// Batches failed (whether or not riders saw the failure).
    pub failed_batches: u64,
    /// Failed batches absorbed by re-routing to another backend.
    pub rerouted: u64,
    /// Times the circuit breaker opened.
    pub trips: u64,
    /// Probe batches sent while the breaker was open.
    pub probes: u64,
    /// Whether the breaker is open right now.
    pub breaker_open: bool,
    /// Whether the supervisor has marked the pool degraded (respawn
    /// attempts kept failing).
    pub degraded: bool,
    /// Workers respawned by the pool supervisor after a death.
    pub respawns: u64,
    /// Windowed p50 of the backend's per-batch exec ns/lane, across
    /// every (op, format) slot it served (0 with no signal yet).
    pub p50_exec_ns_per_lane: f64,
    /// Windowed p99 of the backend's per-batch exec ns/lane (0 with no
    /// signal yet).
    pub p99_exec_ns_per_lane: f64,
}

/// Shared health/latency state for every registered backend.
#[derive(Debug)]
pub struct HealthBoard {
    backends: Vec<BackendHealth>,
    /// Per backend, per (op, format) slot: successful-batch service-
    /// rate windows (one lock per recorded batch) — the shared
    /// [`RateWindow`] type the admission model also uses.
    lat: Mutex<Vec<[RateWindow<LAT_WINDOW>; OP_FORMAT_SLOTS]>>,
}

impl HealthBoard {
    /// Fresh board for `n` backends (all breakers closed, no signal).
    pub fn new(n: usize) -> Self {
        Self {
            backends: (0..n).map(|_| BackendHealth::default()).collect(),
            lat: Mutex::new(
                (0..n).map(|_| std::array::from_fn(|_| RateWindow::default())).collect(),
            ),
        }
    }

    /// Number of tracked backends.
    pub fn backend_count(&self) -> usize {
        self.backends.len()
    }

    /// Record one successfully executed batch: resets the consecutive-
    /// failure count and feeds the latency window for the batch's slot.
    /// An open breaker only closes after
    /// [`CLOSE_AFTER_PROBE_SUCCESSES`] consecutive successes.
    pub fn record_success(
        &self,
        backend: usize,
        op: OpKind,
        format: FormatKind,
        lanes: u64,
        exec_ns: u64,
    ) {
        let b = &self.backends[backend];
        b.ok_batches.fetch_add(1, Ordering::Relaxed);
        b.consecutive.store(0, Ordering::Relaxed);
        if b.open.load(Ordering::Acquire) {
            let streak = b.probe_successes.fetch_add(1, Ordering::AcqRel) + 1;
            if streak >= CLOSE_AFTER_PROBE_SUCCESSES {
                b.probe_successes.store(0, Ordering::Relaxed);
                b.open.store(false, Ordering::Release);
            }
        }
        let mut lat = self.lat.lock().expect("health board poisoned");
        lat[backend][op_format_slot(op, format)].push(exec_ns, lanes);
    }

    /// Record one failed batch. Returns `true` when this failure just
    /// opened the breaker.
    pub fn record_failure(&self, backend: usize) -> bool {
        let b = &self.backends[backend];
        b.failed_batches.fetch_add(1, Ordering::Relaxed);
        b.probe_successes.store(0, Ordering::Relaxed);
        let consecutive = b.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if consecutive >= OPEN_AFTER_CONSECUTIVE && !b.open.swap(true, Ordering::AcqRel) {
            b.trips.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Record a failed batch of this backend being re-routed to another
    /// one (the rider-invisible outcome).
    pub fn record_reroute(&self, backend: usize) {
        self.backends[backend].rerouted.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether the backend's breaker is open.
    pub fn is_open(&self, backend: usize) -> bool {
        self.backends[backend].open.load(Ordering::Acquire)
    }

    /// Whether the supervisor has marked the pool degraded.
    pub fn is_degraded(&self, backend: usize) -> bool {
        self.backends[backend].degraded.load(Ordering::Acquire)
    }

    /// Supervisor verdict on whether the pool can be kept staffed.
    pub fn set_degraded(&self, backend: usize, degraded: bool) {
        self.backends[backend].degraded.store(degraded, Ordering::Release);
    }

    /// Count one supervisor respawn of a dead worker in this pool.
    pub fn record_respawn(&self, backend: usize) {
        self.backends[backend].respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Called each time the dispatch plane *considers* an open backend:
    /// every [`PROBE_PERIOD`]-th consideration returns `true` — send a
    /// probe batch there.
    pub fn probe_tick(&self, backend: usize) -> bool {
        let b = &self.backends[backend];
        let n = b.probe_gate.fetch_add(1, Ordering::Relaxed) + 1;
        if n % PROBE_PERIOD == 0 {
            b.probes.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Windowed mean execution nanoseconds per lane for one (backend,
    /// op, format) — `None` until that backend has served the slot.
    pub fn mean_exec_ns_per_lane(
        &self,
        backend: usize,
        op: OpKind,
        format: FormatKind,
    ) -> Option<f64> {
        let lat = self.lat.lock().expect("health board poisoned");
        lat[backend][op_format_slot(op, format)].ns_per_lane()
    }

    /// Per-backend snapshots, index order.
    pub fn snapshot(&self) -> Vec<BackendHealthSnapshot> {
        // per-backend rate percentiles across every (op, format)
        // window the backend has served (one lock for the whole pass)
        let rates: Vec<Summary> = {
            let lat = self.lat.lock().expect("health board poisoned");
            lat.iter()
                .map(|slots| {
                    let mut s = Summary::new();
                    for w in slots.iter() {
                        for r in w.batch_rates() {
                            s.add(r);
                        }
                    }
                    s
                })
                .collect()
        };
        self.backends
            .iter()
            .zip(rates)
            .map(|(b, rate)| BackendHealthSnapshot {
                ok_batches: b.ok_batches.load(Ordering::Relaxed),
                failed_batches: b.failed_batches.load(Ordering::Relaxed),
                rerouted: b.rerouted.load(Ordering::Relaxed),
                trips: b.trips.load(Ordering::Relaxed),
                probes: b.probes.load(Ordering::Relaxed),
                breaker_open: b.open.load(Ordering::Acquire),
                degraded: b.degraded.load(Ordering::Acquire),
                respawns: b.respawns.load(Ordering::Relaxed),
                p50_exec_ns_per_lane: rate.percentile(50.0),
                p99_exec_ns_per_lane: rate.percentile(99.0),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F32: FormatKind = FormatKind::F32;

    #[test]
    fn breaker_opens_after_consecutive_failures_and_success_closes_it() {
        let h = HealthBoard::new(2);
        assert!(!h.is_open(0));
        for i in 0..OPEN_AFTER_CONSECUTIVE {
            let opened = h.record_failure(0);
            assert_eq!(opened, i + 1 == OPEN_AFTER_CONSECUTIVE, "failure {i}");
        }
        assert!(h.is_open(0));
        assert!(!h.is_open(1), "breakers are per backend");
        // further failures do not re-trip
        assert!(!h.record_failure(0));
        let snap = h.snapshot();
        assert_eq!(snap[0].trips, 1);
        assert_eq!(snap[0].failed_batches, (OPEN_AFTER_CONSECUTIVE + 1) as u64);
        assert!(snap[0].breaker_open);
        // half-open hysteresis: one or two probe successes keep the
        // breaker open; the K-th closes it and resets the streak
        for k in 1..CLOSE_AFTER_PROBE_SUCCESSES {
            h.record_success(0, OpKind::Divide, F32, 64, 1_000);
            assert!(h.is_open(0), "closed after only {k} probe success(es)");
        }
        h.record_success(0, OpKind::Divide, F32, 64, 1_000);
        assert!(!h.is_open(0));
        assert!(!h.record_failure(0), "streak restarted from zero");
        assert!(!h.is_open(0));
    }

    #[test]
    fn failure_resets_half_open_success_streak() {
        let h = HealthBoard::new(1);
        for _ in 0..OPEN_AFTER_CONSECUTIVE {
            h.record_failure(0);
        }
        assert!(h.is_open(0));
        // K-1 successes, then a failure: the streak must restart, so
        // K-1 further successes still leave the breaker open
        for _ in 0..CLOSE_AFTER_PROBE_SUCCESSES - 1 {
            h.record_success(0, OpKind::Divide, F32, 64, 1_000);
        }
        h.record_failure(0);
        for _ in 0..CLOSE_AFTER_PROBE_SUCCESSES - 1 {
            h.record_success(0, OpKind::Divide, F32, 64, 1_000);
        }
        assert!(h.is_open(0), "failure must reset the half-open streak");
        h.record_success(0, OpKind::Divide, F32, 64, 1_000);
        assert!(!h.is_open(0));
    }

    #[test]
    fn degraded_flag_and_respawns_reach_snapshot() {
        let h = HealthBoard::new(2);
        assert!(!h.is_degraded(0));
        h.record_respawn(0);
        h.record_respawn(0);
        h.set_degraded(0, true);
        assert!(h.is_degraded(0));
        assert!(!h.is_degraded(1), "degradation is per pool");
        let snap = h.snapshot();
        assert!(snap[0].degraded);
        assert_eq!(snap[0].respawns, 2);
        assert!(!snap[1].degraded);
        h.set_degraded(0, false);
        assert!(!h.is_degraded(0));
    }

    #[test]
    fn interleaved_successes_keep_breaker_closed() {
        let h = HealthBoard::new(1);
        for _ in 0..20 {
            h.record_failure(0);
            h.record_failure(0);
            h.record_success(0, OpKind::Sqrt, F32, 64, 500);
        }
        assert!(!h.is_open(0), "non-consecutive failures must not trip");
        assert_eq!(h.snapshot()[0].trips, 0);
    }

    #[test]
    fn probe_ticks_fire_once_per_period() {
        let h = HealthBoard::new(1);
        let mut fired = 0;
        for _ in 0..(2 * PROBE_PERIOD) {
            if h.probe_tick(0) {
                fired += 1;
            }
        }
        assert_eq!(fired, 2);
        assert_eq!(h.snapshot()[0].probes, 2);
    }

    #[test]
    fn latency_windows_are_per_slot_and_decay() {
        let h = HealthBoard::new(2);
        assert!(h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).is_none());
        h.record_success(0, OpKind::Divide, F32, 100, 100_000);
        let m = h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).unwrap();
        assert!((m - 1_000.0).abs() < 1e-9, "{m}");
        // other slots and backends stay unsignalled
        assert!(h.mean_exec_ns_per_lane(0, OpKind::Sqrt, F32).is_none());
        assert!(h.mean_exec_ns_per_lane(1, OpKind::Divide, F32).is_none());
        // the window decays: fill it with fast batches and the slow
        // first sample ages out
        for _ in 0..LAT_WINDOW {
            h.record_success(0, OpKind::Divide, F32, 100, 1_000);
        }
        let m = h.mean_exec_ns_per_lane(0, OpKind::Divide, F32).unwrap();
        assert!((m - 10.0).abs() < 1e-9, "window did not decay: {m}");
    }

    #[test]
    fn snapshot_rate_percentiles_span_slots() {
        let h = HealthBoard::new(2);
        // no signal: percentiles read 0, not NaN
        let snap = h.snapshot();
        assert_eq!(snap[0].p50_exec_ns_per_lane, 0.0);
        assert_eq!(snap[0].p99_exec_ns_per_lane, 0.0);
        // rates from different (op, format) slots pool into one
        // per-backend envelope
        h.record_success(0, OpKind::Divide, F32, 10, 1_000); // 100 ns/lane
        h.record_success(0, OpKind::Sqrt, F32, 10, 3_000); // 300 ns/lane
        h.record_success(0, OpKind::Divide, FormatKind::F64, 10, 9_000); // 900 ns/lane
        let snap = h.snapshot();
        assert!(snap[0].p50_exec_ns_per_lane >= 100.0);
        assert!(snap[0].p50_exec_ns_per_lane <= 900.0);
        assert!((snap[0].p99_exec_ns_per_lane - 900.0).abs() < 1e-9);
        assert!(snap[0].p99_exec_ns_per_lane >= snap[0].p50_exec_ns_per_lane);
        // per backend: backend 1 still unsignalled
        assert_eq!(snap[1].p99_exec_ns_per_lane, 0.0);
    }

    #[test]
    fn reroutes_counted() {
        let h = HealthBoard::new(1);
        h.record_reroute(0);
        h.record_reroute(0);
        assert_eq!(h.snapshot()[0].rerouted, 2);
    }
}
