//! [`ExecutorRegistry`]: the named executor factories a routed service
//! is built from, plus the [`RoutePolicy`] that arbitrates among them.
//!
//! Registration order is the **static preference order**: with
//! `RoutePolicy::Static` the earliest-registered healthy candidate for
//! an (op, format) pair serves it; with `RoutePolicy::Latency` the
//! measured-fastest healthy candidate wins instead (falling back to
//! registration order until every candidate has latency signal).
//!
//! Factories — not executors — are registered because executors are
//! deliberately not `Send` (the PJRT client wraps thread-local FFI
//! state): each worker thread builds its own executor from the shared
//! factory, exactly as [`FpuService::start`](crate::coordinator::FpuService::start)
//! always did for the single-backend case.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::executor::Executor;

/// A shared, thread-safe executor factory (called once per worker
/// thread, plus once at startup for capability probing).
pub type ExecutorFactory = Arc<dyn Fn() -> Result<Box<dyn Executor>> + Send + Sync>;

/// How the dispatch plane arbitrates among healthy candidate backends
/// for one (op, format) pair.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Registration order: the earliest-registered healthy candidate
    /// serves the pair. Deterministic, zero measurement overhead.
    #[default]
    Static,
    /// Measured-latency preference: the healthy candidate with the
    /// lowest windowed mean execution time per lane for the pair
    /// serves it. Candidates without signal are tried first (so every
    /// backend gets measured), and a periodic exploration tick
    /// re-measures the losers so a recovered or warmed-up backend can
    /// win the slot back.
    Latency,
}

impl RoutePolicy {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "static" => Ok(RoutePolicy::Static),
            "latency" => Ok(RoutePolicy::Latency),
            other => Err(format!("unknown route policy {other:?} (static|latency)")),
        }
    }

    /// Stable label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::Static => "static",
            RoutePolicy::Latency => "latency",
        }
    }
}

/// One registered backend: its factory and an optional per-backend
/// worker count (defaulting to the service config's `workers`).
pub struct BackendEntry {
    factory: ExecutorFactory,
    workers: Option<usize>,
}

impl BackendEntry {
    /// Build one executor from this entry's factory.
    pub fn make(&self) -> Result<Box<dyn Executor>> {
        (self.factory)()
    }

    /// A clone of the shared factory (each worker thread gets one).
    pub fn factory(&self) -> ExecutorFactory {
        self.factory.clone()
    }

    /// Per-backend worker-pool size override, if any.
    pub fn workers(&self) -> Option<usize> {
        self.workers
    }
}

/// The ordered set of executor factories a routed service serves
/// through. Backend *names* are not stored here — they come from each
/// probed executor's own [`BackendCaps::backend`](crate::runtime::BackendCaps::backend),
/// so a registry entry can never claim a name its executor disowns.
#[derive(Default)]
pub struct ExecutorRegistry {
    entries: Vec<BackendEntry>,
    policy: RoutePolicy,
}

impl ExecutorRegistry {
    /// Empty registry (static policy until overridden).
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the routing policy.
    pub fn with_policy(mut self, policy: RoutePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Register one backend factory. Registration order is the static
    /// preference order.
    pub fn register<F>(self, factory: F) -> Self
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        self.push(Arc::new(factory), None)
    }

    /// [`Self::register`] with a per-backend worker-pool size (instead
    /// of the service config's global `workers`).
    pub fn register_with_workers<F>(self, factory: F, workers: usize) -> Self
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        self.push(Arc::new(factory), Some(workers))
    }

    fn push(mut self, factory: ExecutorFactory, workers: Option<usize>) -> Self {
        self.entries.push(BackendEntry { factory, workers });
        self
    }

    /// The routing policy in force.
    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Registered backends, in preference order.
    pub fn entries(&self) -> &[BackendEntry] {
        &self.entries
    }

    /// Number of registered backends.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Decompose into (entries, policy) — the service start path.
    pub fn into_parts(self) -> (Vec<BackendEntry>, RoutePolicy) {
        (self.entries, self.policy)
    }
}

/// Build the standard registry from a comma-separated backend list
/// (the CLI's `--backend native,u128,scalar` grammar). Known names:
///
/// * `native` — [`NativeExecutor`](crate::runtime::NativeExecutor), the
///   width-true limb-sliced batch kernels (serves all 12 pairs);
/// * `u128` — [`U128BaselineExecutor`](crate::runtime::U128BaselineExecutor),
///   the retained u64×u64→u128 divide kernel family (divide only, u64
///   planes — genuinely partial capabilities);
/// * `scalar` — [`ScalarReferenceExecutor`](crate::runtime::ScalarReferenceExecutor),
///   the scalar bit-accurate reference datapath, one lane at a time;
/// * `pjrt` — the XLA AOT backend (f32 only; needs the `pjrt` feature
///   and an artifacts directory).
///
/// List order is the static preference order. Duplicates and unknown
/// names are errors.
pub fn standard_registry(
    spec: &str,
    policy: RoutePolicy,
    artifacts: Option<std::path::PathBuf>,
) -> Result<ExecutorRegistry> {
    use crate::runtime::executor::{
        NativeExecutor, ScalarReferenceExecutor, U128BaselineExecutor,
    };
    #[cfg(not(feature = "pjrt"))]
    let _ = &artifacts;
    let mut registry = ExecutorRegistry::new().with_policy(policy);
    let mut seen: Vec<&str> = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if seen.contains(&name) {
            bail!("backend {name:?} registered twice");
        }
        seen.push(name);
        registry = match name {
            "native" => registry.register(|| Ok(Box::new(NativeExecutor::with_defaults()) as _)),
            "u128" => {
                registry.register(|| Ok(Box::new(U128BaselineExecutor::with_defaults()) as _))
            }
            "scalar" => {
                registry.register(|| Ok(Box::new(ScalarReferenceExecutor::with_defaults()) as _))
            }
            #[cfg(feature = "pjrt")]
            "pjrt" => {
                let dir = match &artifacts {
                    Some(dir) => dir.clone(),
                    None => bail!("backend pjrt needs an artifacts directory"),
                };
                registry.register(move || {
                    let mut ex = crate::runtime::PjrtExecutor::from_dir(&dir)?;
                    ex.warmup()?;
                    Ok(Box::new(ex) as _)
                })
            }
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => {
                bail!("backend pjrt requires a build with `--features pjrt`")
            }
            other => bail!("unknown backend {other:?} (native|u128|scalar|pjrt)"),
        };
    }
    if registry.is_empty() {
        bail!("no backends in {spec:?}");
    }
    Ok(registry)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policies() {
        assert_eq!(RoutePolicy::parse("static").unwrap(), RoutePolicy::Static);
        assert_eq!(RoutePolicy::parse("latency").unwrap(), RoutePolicy::Latency);
        assert!(RoutePolicy::parse("fastest").is_err());
        assert_eq!(RoutePolicy::default(), RoutePolicy::Static);
        assert_eq!(RoutePolicy::Latency.label(), "latency");
    }

    #[test]
    fn standard_registry_parses_lists() {
        let reg = standard_registry("native,u128,scalar", RoutePolicy::Latency, None).unwrap();
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.policy(), RoutePolicy::Latency);
        // every entry's factory builds a live executor
        for entry in reg.entries() {
            assert!(entry.make().is_ok());
            assert!(entry.workers().is_none());
        }
        // whitespace tolerated, single entries fine
        assert_eq!(standard_registry(" native ", RoutePolicy::Static, None).unwrap().len(), 1);
    }

    #[test]
    fn standard_registry_rejects_bad_specs() {
        assert!(standard_registry("native,native", RoutePolicy::Static, None).is_err());
        assert!(standard_registry("warp-drive", RoutePolicy::Static, None).is_err());
        assert!(standard_registry("", RoutePolicy::Static, None).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(standard_registry("pjrt", RoutePolicy::Static, None).is_err());
    }

    #[test]
    fn register_with_workers_records_override() {
        use crate::runtime::executor::NativeExecutor;
        let reg = ExecutorRegistry::new()
            .register(|| Ok(Box::new(NativeExecutor::with_defaults()) as _))
            .register_with_workers(|| Ok(Box::new(NativeExecutor::with_defaults()) as _), 3);
        assert_eq!(reg.entries()[0].workers(), None);
        assert_eq!(reg.entries()[1].workers(), Some(3));
        let (entries, policy) = reg.into_parts();
        assert_eq!(entries.len(), 2);
        assert_eq!(policy, RoutePolicy::Static);
    }
}
