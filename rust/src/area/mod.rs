//! Gate-equivalent area model: turns a datapath [`Inventory`] into
//! numbers, quantifying the paper's claim A1 ("avoided the use of 3
//! multipliers and 2 two's complement units which saves a significant
//! area").
//!
//! Unit costs come from the bit-level models in [`crate::arith::mult`]
//! and [`crate::arith::twos`]; ROM bits and the logic block are costed
//! here. Conventions (unit-gate accounting) are documented in
//! [`crate::arith::mult`].

use crate::arith::mult::{BoothWallaceMultiplier, MultiplierModel, UnitCost};
use crate::arith::twos::{ComplementBlock, ComplementKind};
use crate::sim::Inventory;

/// Area cost per ROM bit in gate equivalents (dense NOR ROM).
pub const ROM_GE_PER_BIT: f64 = 0.25;

/// Flip-flop cost in gate equivalents.
pub const FF_GE: f64 = 4.0;

/// Full area breakdown of one datapath instance, in gate equivalents.
#[derive(Clone, Debug)]
pub struct AreaReport {
    /// Design label ("baseline" / "feedback").
    pub design: String,
    /// Multiplier count and total GE.
    pub multipliers: (u32, f64),
    /// Complement-block count and total GE.
    pub complements: (u32, f64),
    /// ROM bits and GE.
    pub rom: (u64, f64),
    /// Logic-block count and GE (mux row + counter + select FF).
    pub logic_blocks: (u32, f64),
    /// Pipeline/output registers GE (same for both designs: q, r, K regs).
    pub registers: f64,
}

impl AreaReport {
    /// Total gate equivalents.
    pub fn total(&self) -> f64 {
        self.multipliers.1 + self.complements.1 + self.rom.1 + self.logic_blocks.1 + self.registers
    }
}

/// Parameters of the area evaluation.
#[derive(Clone, Copy, Debug)]
pub struct AreaParams {
    /// Datapath word fraction width (multiplier operand width - 2).
    pub frac: u32,
    /// ROM input width.
    pub table_p: u32,
    /// Complement circuit kind.
    pub complement: ComplementKind,
}

impl AreaParams {
    /// Derive from an algorithm config.
    pub fn from_config(cfg: &crate::goldschmidt::Config) -> Self {
        Self { frac: cfg.frac, table_p: cfg.table_p, complement: cfg.complement }
    }

    /// Multiplier operand width (integer + fraction bits).
    pub fn mult_width(&self) -> u32 {
        self.frac + 2
    }
}

/// Cost of one multiplier at these parameters (Booth–Wallace: the
/// high-speed design the 4-cycle pipelined unit corresponds to).
pub fn multiplier_cost(params: &AreaParams) -> UnitCost {
    BoothWallaceMultiplier::new(params.mult_width().min(62)).cost()
}

/// Cost of one complement block.
pub fn complement_cost(params: &AreaParams) -> UnitCost {
    ComplementBlock::new(params.frac, params.complement).cost()
}

/// Cost of the logic block: a 2:1 mux row over the word (3 GE/bit), a
/// ceil(log2(steps))-ish pass counter (~4 FF + inc logic), and the
/// registered select line.
pub fn logic_block_cost(params: &AreaParams) -> UnitCost {
    let word = (params.frac + 2) as f64;
    let mux = 3.0 * word;
    let counter = 4.0 * FF_GE + 10.0; // 4-bit counter + compare/reset
    let select_ff = FF_GE;
    UnitCost { gates: mux + counter + select_ff, depth: 3.0 }
}

/// ROM storage bits for a `p`-in / `p+2`-out table.
pub fn rom_bits(table_p: u32) -> u64 {
    (1u64 << table_p) * (table_p as u64 + 2)
}

/// One row of the per-format ROM sizing table.
#[derive(Clone, Copy, Debug)]
pub struct FormatRomRow {
    /// IEEE format.
    pub format: crate::formats::FormatKind,
    /// ROM input width from the format's datapath configuration.
    pub table_p: u32,
    /// Table entries (`2^table_p`).
    pub entries: u64,
    /// Storage bits (`entries * (table_p + 2)`).
    pub bits: u64,
    /// Gate-equivalent area of those bits.
    pub gate_equivalents: f64,
}

/// Per-format ROM sizing across the format plane: each format's seed
/// table at its own `table_p` (bf16 runs p=5 — 32 entries — where the
/// other formats keep the paper's p=10), pricing the area side of the
/// ROM-size-vs-refinement-steps trade the paper's §III knob exposes.
pub fn format_rom_rows() -> Vec<FormatRomRow> {
    crate::formats::FormatKind::ALL
        .iter()
        .map(|&format| {
            let p = format.datapath_config().table_p;
            let bits = rom_bits(p);
            FormatRomRow {
                format,
                table_p: p,
                entries: 1u64 << p,
                bits,
                gate_equivalents: bits as f64 * ROM_GE_PER_BIT,
            }
        })
        .collect()
}

/// Build the area report for a datapath inventory.
pub fn area_of(design: &str, inv: &Inventory, params: &AreaParams) -> AreaReport {
    let m = multiplier_cost(params);
    let c = complement_cost(params);
    let lb = logic_block_cost(params);
    let bits = rom_bits(params.table_p) * inv.roms as u64;
    let word = (params.frac + 2) as f64;
    // output registers: q, r, K (one word each) — both designs pipeline
    // through the same three architectural registers
    let registers = 3.0 * word * FF_GE;
    AreaReport {
        design: design.to_string(),
        multipliers: (inv.multipliers, inv.multipliers as f64 * m.gates),
        complements: (inv.complement_blocks, inv.complement_blocks as f64 * c.gates),
        rom: (bits, bits as f64 * ROM_GE_PER_BIT),
        logic_blocks: (inv.logic_blocks, inv.logic_blocks as f64 * lb.gates),
        registers,
    }
}

/// The paper's headline comparison: area of both designs plus savings.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Baseline (unrolled) report.
    pub baseline: AreaReport,
    /// Feedback (reduced) report.
    pub feedback: AreaReport,
}

impl Comparison {
    /// Compare the two designs at a given algorithm configuration.
    pub fn at(cfg: &crate::goldschmidt::Config) -> Self {
        use crate::sim::{BaselineDatapath, FeedbackDatapath};
        use crate::tables::ReciprocalTable;
        let params = AreaParams::from_config(cfg);
        let table = ReciprocalTable::new(cfg.table_p);
        let b = BaselineDatapath::new(table.clone(), *cfg).inventory();
        let f = FeedbackDatapath::new(table, *cfg).inventory();
        Self {
            baseline: area_of("baseline", &b, &params),
            feedback: area_of("feedback", &f, &params),
        }
    }

    /// Absolute GE saved by the feedback design.
    pub fn saved(&self) -> f64 {
        self.baseline.total() - self.feedback.total()
    }

    /// Fractional saving (0..1).
    pub fn saved_fraction(&self) -> f64 {
        self.saved() / self.baseline.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::goldschmidt::Config;

    #[test]
    fn multiplier_dominates() {
        let params = AreaParams::from_config(&Config::default());
        let m = multiplier_cost(&params);
        let c = complement_cost(&params);
        let lb = logic_block_cost(&params);
        assert!(m.gates > 10.0 * c.gates);
        assert!(m.gates > 10.0 * lb.gates);
    }

    #[test]
    fn feedback_saves_significant_area() {
        // paper claim A1: the q4 configuration saves ~3/7 of multiplier
        // area; total saving must be large and positive
        let cmp = Comparison::at(&Config::default());
        assert!(cmp.saved() > 0.0);
        assert!(
            cmp.saved_fraction() > 0.30,
            "saving fraction {} too small",
            cmp.saved_fraction()
        );
        assert!(cmp.saved_fraction() < 0.60);
    }

    #[test]
    fn unit_deltas_match_paper() {
        let cmp = Comparison::at(&Config::default());
        assert_eq!(cmp.baseline.multipliers.0 - cmp.feedback.multipliers.0, 3);
        assert_eq!(cmp.baseline.complements.0 - cmp.feedback.complements.0, 2);
        assert_eq!(cmp.feedback.logic_blocks.0, 1);
        assert_eq!(cmp.baseline.logic_blocks.0, 0);
    }

    #[test]
    fn logic_block_cost_is_small_vs_savings() {
        // §V: the logic block must cost far less than what it saves
        let cfg = Config::default();
        let params = AreaParams::from_config(&cfg);
        let lb = logic_block_cost(&params);
        let m = multiplier_cost(&params);
        assert!(lb.gates < 0.05 * (3.0 * m.gates));
    }

    #[test]
    fn rom_bits_counts() {
        assert_eq!(rom_bits(10), 1024 * 12);
        assert_eq!(rom_bits(8), 256 * 10);
    }

    #[test]
    fn format_rom_rows_price_the_bf16_shrink() {
        use crate::formats::FormatKind;
        let rows = format_rom_rows();
        assert_eq!(rows.len(), 4);
        let row = |k: FormatKind| *rows.iter().find(|r| r.format == k).unwrap();
        let bf16 = row(FormatKind::BF16);
        let f32r = row(FormatKind::F32);
        assert_eq!(bf16.table_p, 5);
        assert_eq!(bf16.entries, 32);
        assert_eq!(bf16.bits, 32 * 7);
        assert_eq!(f32r.bits, 1024 * 12);
        // the ROADMAP claim: ~30x (in fact ~55x) less ROM area for bf16
        assert!(f32r.gate_equivalents / bf16.gate_equivalents > 30.0);
        // every row's GE follows the shared per-bit cost
        for r in rows {
            assert!((r.gate_equivalents - r.bits as f64 * ROM_GE_PER_BIT).abs() < 1e-9);
        }
    }

    #[test]
    fn area_grows_with_width() {
        let narrow = Comparison::at(&Config::default().with_frac(20));
        let wide = Comparison::at(&Config::default().with_frac(40));
        assert!(wide.baseline.total() > narrow.baseline.total());
        // savings grow with width too (multipliers scale quadratically)
        assert!(wide.saved() > narrow.saved());
    }

    #[test]
    fn report_total_is_sum_of_parts() {
        let cmp = Comparison::at(&Config::default());
        let r = &cmp.baseline;
        let sum = r.multipliers.1 + r.complements.1 + r.rom.1 + r.logic_blocks.1 + r.registers;
        assert!((r.total() - sum).abs() < 1e-9);
    }
}
