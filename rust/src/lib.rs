//! # goldschmidt — Goldschmidt division with hardware reduction
//!
//! A full-system reproduction of T. Dutta Roy, *Implementation of
//! Goldschmidt's Algorithm with Hardware Reduction* (CS.AR 2019), built
//! as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's hardware contribution as a
//!   cycle-accurate simulator ([`sim`]) with an area model ([`area`],
//!   including per-format ROM sizing), plus the bit-accurate arithmetic
//!   substrate ([`arith`], [`tables`], [`goldschmidt`], [`baselines`]),
//!   the multi-precision format plane ([`formats`]: f16 / bf16 / f32 /
//!   f64 geometry, pack/unpack, format-tagged values, and per-format
//!   datapath configs down to ROM width), the batched SoA serving
//!   kernels ([`kernel`], monomorphized per format) and an FPU-service
//!   coordinator ([`coordinator`]) serving batched divide/sqrt/rsqrt
//!   through the v2 ticketed request plane: shared-slot completion
//!   tickets (no channel per request), vectored `submit_batch`
//!   group submissions, optional per-request deadlines with counted
//!   shedding, and a typed `ServiceError` for every failure. Backends
//!   plug in through a capability-negotiated executor contract
//!   ([`runtime`]: `BackendCaps` + allocation-free `execute_into`),
//!   implemented by the native batch kernels, a retained u128 divide
//!   baseline, a scalar reference datapath, and AOT-compiled XLA
//!   executables (behind the non-default `pjrt` feature) — and the
//!   [`dispatch`] plane merges several backends' capability tables
//!   into one routing table, serving each (op, format) batch through
//!   health-tracked per-backend worker pools (static or
//!   measured-latency preference, consecutive-failure circuit breakers
//!   with probe-based recovery, rider-invisible failover). The whole
//!   request path is observable through the [`obs`] trace plane:
//!   lock-free sampled lifecycle rings whose per-request stage spans
//!   (queue / batch / exec / failover) decompose rider-observed
//!   latency, always-captured error-class events (sheds, failovers,
//!   injected faults, worker deaths), Chrome-trace/JSONL export and a
//!   per-stage breakdown report. The [`net`] plane puts a socket in
//!   front of all of it: a compact length-prefixed wire protocol
//!   (HELLO/SUBMIT/TICKET/COMPLETE, CRC-framed like the journal) served
//!   by blocking per-connection reader threads and bounded writer
//!   handoff queues, driven at scenario scale by the open-loop
//!   [`workload`] generator (`goldschmidt loadgen`).
//! * **Layer 2** — `python/compile/model.py`: jax graphs, lowered once
//!   to HLO text under `artifacts/`.
//! * **Layer 1** — `python/compile/kernels/`: the Goldschmidt iteration
//!   as a Pallas kernel (interpret mode), validated against a pure-jnp
//!   oracle.
//!
//! Python never runs on the request path: `make artifacts` runs once at
//! build time and the rust binary is self-contained afterwards.
//!
//! The datapath is **limb-sliced and width-true** end to end: every
//! mantissa multiply is built from widening `u32 x u32 -> u64` limb
//! products ([`arith::limb`] — vectorizable, no `u128` on the hot
//! path), and every plane carries its format's native word (`u32`
//! lanes for f16/bf16, `u64` for f32/f64 — [`formats::plane`]), from
//! the vectored submission queue through the batcher's [`coordinator`]
//! planes to the [`kernel`] lane loops.
//!
//! See the top-level `README.md` for the module map
//! (arith -> formats -> kernel -> dispatch -> coordinator -> runtime), the
//! plane-word/limb design, and how to run the service and benches;
//! `DESIGN.md` for the per-experiment index (which module regenerates
//! which figure/table of the paper); and `EXPERIMENTS.md` for results.

pub mod area;
pub mod arith;
pub mod baselines;
pub mod bench;
pub mod check;
pub mod coordinator;
pub mod dispatch;
pub mod fault;
pub mod formats;
pub mod goldschmidt;
pub mod kernel;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod tables;
pub mod util;
pub mod workload;
