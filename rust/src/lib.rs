//! # goldschmidt — Goldschmidt division with hardware reduction
//!
//! A full-system reproduction of T. Dutta Roy, *Implementation of
//! Goldschmidt's Algorithm with Hardware Reduction* (CS.AR 2019), built
//! as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's hardware contribution as a
//!   cycle-accurate simulator ([`sim`]) with an area model ([`area`]),
//!   plus the bit-accurate arithmetic substrate ([`arith`], [`tables`],
//!   [`goldschmidt`], [`baselines`]), the multi-precision format plane
//!   ([`formats`]: f16 / bf16 / f32 / f64 geometry, pack/unpack, and
//!   format-tagged values), the batched SoA serving kernels ([`kernel`],
//!   monomorphized per format) and an FPU-service coordinator
//!   ([`coordinator`]) that serves batched divide/sqrt/rsqrt requests in
//!   any supported format through the native batch kernels or
//!   AOT-compiled XLA executables ([`runtime`], the latter behind the
//!   non-default `pjrt` feature).
//! * **Layer 2** — `python/compile/model.py`: jax graphs, lowered once
//!   to HLO text under `artifacts/`.
//! * **Layer 1** — `python/compile/kernels/`: the Goldschmidt iteration
//!   as a Pallas kernel (interpret mode), validated against a pure-jnp
//!   oracle.
//!
//! Python never runs on the request path: `make artifacts` runs once at
//! build time and the rust binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the per-experiment index (which module regenerates
//! which figure/table of the paper) and `EXPERIMENTS.md` for results.

pub mod area;
pub mod arith;
pub mod baselines;
pub mod bench;
pub mod check;
pub mod coordinator;
pub mod formats;
pub mod goldschmidt;
pub mod kernel;
pub mod runtime;
pub mod sim;
pub mod tables;
pub mod util;
pub mod workload;
