//! [`GoldschmidtContext`]: per-configuration precomputation for the
//! batched kernels and the context-threaded scalar paths.

use crate::arith::fixed::{q2_max, Fixed};
use crate::arith::twos::ComplementBlock;
use crate::formats::{self, FloatFormat};
use crate::goldschmidt::{division, sqrt, Config};
use crate::tables::{ReciprocalTable, RsqrtTable};

/// Everything the Goldschmidt datapath derives from a [`Config`],
/// computed once so the per-batch lane loops contain only shifts,
/// multiplies and table indexing.
///
/// Construction cost is dominated by the two ROMs (2^p entries each);
/// build one context per configuration and reuse it for the life of the
/// executor — exactly as the paper's hardware instantiates one ROM +
/// multiplier pair per divider unit, not one per operation.
pub struct GoldschmidtContext {
    pub(super) cfg: Config,
    pub(super) recip: ReciprocalTable,
    pub(super) rsqrt: RsqrtTable,
    /// The complement circuit, constructed once (the scalar hot path
    /// used to rebuild this on every call).
    pub(super) complement: ComplementBlock,
    /// `3/2` at the datapath width (the sqrt iteration constant).
    pub(super) three_half: Fixed,

    // ---- raw planes for the lane loops --------------------------------
    /// Fraction width of the datapath words.
    pub(super) frac: u32,
    /// Refinement step count.
    pub(super) steps: u32,
    /// Saturation bound `2^(frac+2) - 1` (also the one's-complement
    /// field mask).
    pub(super) sat: u64,
    /// `1.0` as raw bits (`1 << frac`).
    pub(super) one: u64,
    /// `2.0` as raw bits (`1 << (frac+1)`).
    pub(super) two: u64,
    /// `3/2` as raw bits.
    pub(super) three_half_bits: u64,
    /// Reciprocal ROM entries pre-shifted to `frac` fraction bits, so a
    /// lookup is a single array index (no per-call realignment).
    pub(super) recip_lanes: Vec<u64>,
    /// Rsqrt ROM entries pre-shifted to `frac` fraction bits.
    pub(super) rsqrt_lanes: Vec<u64>,
    /// Available hardware parallelism, read once at construction so the
    /// per-batch worker split never makes a syscall.
    pub(super) cores: usize,
}

impl GoldschmidtContext {
    /// Build a context (tables included) for a validated configuration.
    /// Panics on an invalid [`Config`], like the table constructors do.
    pub fn new(cfg: Config) -> Self {
        if let Err(e) = cfg.validate() {
            panic!("invalid Goldschmidt config: {e}");
        }
        let recip = ReciprocalTable::new(cfg.table_p);
        let rsqrt = RsqrtTable::new(cfg.table_p);
        Self::with_tables(cfg, recip, rsqrt)
    }

    /// Build a context around existing tables (they must match the
    /// configuration's ROM width).
    pub fn with_tables(cfg: Config, recip: ReciprocalTable, rsqrt: RsqrtTable) -> Self {
        if let Err(e) = cfg.validate() {
            panic!("invalid Goldschmidt config: {e}");
        }
        assert_eq!(recip.p(), cfg.table_p, "reciprocal table width != config");
        assert_eq!(rsqrt.p(), cfg.table_p, "rsqrt table width != config");
        let frac = cfg.frac;
        // Both ROMs store (p+2)-fraction-bit entries; left-align them to
        // the datapath width once (ReciprocalTable::lookup does this
        // shift on every call).
        let align = frac - (cfg.table_p + 2);
        let recip_lanes: Vec<u64> = (0..recip.len()).map(|j| recip.entry(j) << align).collect();
        let rsqrt_lanes: Vec<u64> = (0..rsqrt.len()).map(|j| rsqrt.entry(j) << align).collect();
        let three_half = Fixed::from_f64(1.5, frac);
        Self {
            complement: ComplementBlock::new(frac, cfg.complement),
            three_half,
            frac,
            steps: cfg.steps,
            sat: q2_max(frac),
            one: 1u64 << frac,
            two: 1u64 << (frac + 1),
            three_half_bits: three_half.bits(),
            recip_lanes,
            rsqrt_lanes,
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            cfg,
            recip,
            rsqrt,
        }
    }

    /// The configuration this context was built for.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The reciprocal ROM.
    pub fn reciprocal_table(&self) -> &ReciprocalTable {
        &self.recip
    }

    /// The rsqrt ROM.
    pub fn rsqrt_table(&self) -> &RsqrtTable {
        &self.rsqrt
    }

    // ---- context-threaded scalar paths --------------------------------
    //
    // Same signatures as the free functions minus the table/config
    // plumbing; these reuse the precomputed complement block and sqrt
    // constant instead of rebuilding them per call. The batch kernels
    // route special-class lanes through these (the datapath closure is
    // unreachable for specials, so results match the scalar path by
    // construction).

    /// Scalar f32 division with precomputed datapath state.
    pub fn divide_f32(&self, n: f32, d: f32) -> f32 {
        division::divide_f32_in(n, d, &self.recip, &self.cfg, &self.complement)
    }

    /// Scalar f64 division (requires `frac >= 56`).
    pub fn divide_f64(&self, n: f64, d: f64) -> f64 {
        division::divide_f64_in(n, d, &self.recip, &self.cfg, &self.complement)
    }

    /// Scalar f32 square root with precomputed datapath state.
    pub fn sqrt_f32(&self, x: f32) -> f32 {
        sqrt::sqrt_f32_in(x, &self.rsqrt, &self.cfg, &self.three_half)
    }

    /// Scalar f32 reciprocal square root with precomputed state.
    pub fn rsqrt_f32(&self, x: f32) -> f32 {
        sqrt::rsqrt_f32_in(x, &self.rsqrt, &self.cfg, &self.three_half)
    }

    /// Scalar mantissa division reusing the precomputed complement
    /// block (bit-identical to
    /// [`divide_mantissa_quick`](crate::goldschmidt::divide_mantissa_quick)).
    pub fn divide_mantissa(&self, n: &Fixed, d: &Fixed) -> Fixed {
        division::divide_mantissa_quick_in(n, d, &self.recip, &self.cfg, &self.complement)
    }

    // ---- format-generic scalar paths ----------------------------------
    //
    // The scalar reference implementations the batch kernels are pinned
    // against, monomorphized per IEEE format: the generic special-case
    // envelopes from `crate::formats` around the precomputed mantissa
    // datapath. For `F32`/`F64` these are bit-identical to the typed
    // entry points above (both delegate to the same envelopes).

    /// Scalar division on raw format words, any [`FloatFormat`].
    pub fn divide_bits<F: FloatFormat>(&self, n: u64, d: u64) -> u64 {
        formats::divide_via_bits::<F, _>(n, d, self.frac, |nm, dm| {
            division::divide_mantissa_quick_in(&nm, &dm, &self.recip, &self.cfg, &self.complement)
        })
    }

    /// Scalar square root on raw format words, any [`FloatFormat`].
    pub fn sqrt_bits<F: FloatFormat>(&self, x: u64) -> u64 {
        formats::sqrt_via_bits::<F, _>(x, self.frac, |d| {
            sqrt::sqrt_rsqrt_mantissa_quick_in(&d, &self.rsqrt, &self.cfg, &self.three_half).0
        })
    }

    /// Scalar reciprocal square root on raw format words, any
    /// [`FloatFormat`].
    pub fn rsqrt_bits<F: FloatFormat>(&self, x: u64) -> u64 {
        formats::rsqrt_via_bits::<F, _>(x, self.frac, |d| {
            let h = sqrt::sqrt_rsqrt_mantissa_quick_in(&d, &self.rsqrt, &self.cfg, &self.three_half)
                .1;
            Fixed::from_bits(h.bits() << 1, self.frac) // 2h: a shift
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rom_planes_match_table_lookup() {
        let ctx = GoldschmidtContext::new(Config::default());
        let frac = ctx.config().frac;
        // every interval representative: direct index == Fixed lookup
        for j in 0..ctx.recip.len() {
            let bits = (1u64 << frac) + ((j as u64) << (frac - ctx.cfg.table_p));
            let d = Fixed::from_bits(bits, frac);
            assert_eq!(ctx.recip_lanes[j], ctx.recip.lookup(&d).bits(), "recip j={j}");
        }
    }

    #[test]
    fn constants_match_fixed() {
        let ctx = GoldschmidtContext::new(Config::default());
        assert_eq!(ctx.one, Fixed::one(ctx.frac).bits());
        assert_eq!(ctx.two, Fixed::two(ctx.frac).bits());
        assert_eq!(ctx.three_half_bits, Fixed::from_f64(1.5, ctx.frac).bits());
        assert_eq!(ctx.sat, q2_max(ctx.frac));
    }

    #[test]
    fn scalar_wrappers_match_free_functions() {
        use crate::goldschmidt::{divide_f32, rsqrt_f32, sqrt_f32};
        let cfg = Config::default();
        let ctx = GoldschmidtContext::new(cfg);
        for &(n, d) in &[(355.0f32, 113.0f32), (1.0, 3.0), (-8.5, 2.0), (0.0, -0.0)] {
            let free = divide_f32(n, d, &ctx.recip, &cfg);
            let threaded = ctx.divide_f32(n, d);
            assert_eq!(free.to_bits(), threaded.to_bits(), "{n}/{d}");
        }
        for &x in &[2.0f32, 9.0, 1e-20, -4.0, f32::INFINITY] {
            assert_eq!(
                sqrt_f32(x, &ctx.rsqrt, &cfg).to_bits(),
                ctx.sqrt_f32(x).to_bits(),
                "sqrt({x})"
            );
            assert_eq!(
                rsqrt_f32(x, &ctx.rsqrt, &cfg).to_bits(),
                ctx.rsqrt_f32(x).to_bits(),
                "rsqrt({x})"
            );
        }
    }

    #[test]
    #[should_panic(expected = "invalid Goldschmidt config")]
    fn invalid_config_rejected() {
        GoldschmidtContext::new(Config::default().with_frac(8));
    }

    #[test]
    fn bits_paths_match_typed_scalar_wrappers() {
        use crate::formats::{F32 as Fmt32, F64 as Fmt64};
        let ctx = GoldschmidtContext::new(Config::default());
        for &(n, d) in &[(355.0f32, 113.0), (-8.5, 2.0), (1.0, 0.0), (f32::NAN, 1.0), (0.0, -0.0)]
        {
            assert_eq!(
                ctx.divide_bits::<Fmt32>(n.to_bits() as u64, d.to_bits() as u64) as u32,
                ctx.divide_f32(n, d).to_bits(),
                "{n} / {d}"
            );
        }
        for &x in &[2.0f32, 9.0, -4.0, 0.0, f32::INFINITY, f32::NAN] {
            assert_eq!(
                ctx.sqrt_bits::<Fmt32>(x.to_bits() as u64) as u32,
                ctx.sqrt_f32(x).to_bits(),
                "sqrt({x})"
            );
            assert_eq!(
                ctx.rsqrt_bits::<Fmt32>(x.to_bits() as u64) as u32,
                ctx.rsqrt_f32(x).to_bits(),
                "rsqrt({x})"
            );
        }
        let ctx = GoldschmidtContext::new(Config::double());
        assert_eq!(
            ctx.divide_bits::<Fmt64>(1.0f64.to_bits(), 3.0f64.to_bits()),
            ctx.divide_f64(1.0, 3.0).to_bits()
        );
    }
}
